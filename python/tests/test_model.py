"""L2 correctness: the fused graphs behave like their numpy references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import stencil_spmv_ref


def poisson_coeffs(g, kappa=None):
    """Cell-centered 5-point coefficients for -div(kappa grad u) = f, h=1/(g+1).

    Matches rust/src/sparse/poisson.rs assembly (harmonic-mean face
    coefficients); kappa=None means constant-1 conductivity.
    """
    if kappa is None:
        kappa = np.ones((g, g))
    kp = np.pad(kappa, 1, mode="edge")
    kc = kp[1:-1, 1:-1]

    def face(a, b):
        return 2.0 * a * b / (a + b)

    up = face(kc, kp[:-2, 1:-1])
    dn = face(kc, kp[2:, 1:-1])
    lf = face(kc, kp[1:-1, :-2])
    rt = face(kc, kp[1:-1, 2:])
    center = up + dn + lf + rt
    h2 = (1.0 / (g + 1)) ** 2
    return jnp.stack([jnp.asarray(center), -jnp.asarray(up), -jnp.asarray(dn),
                      -jnp.asarray(lf), -jnp.asarray(rt)]) / h2


@pytest.mark.parametrize("g", [8, 16, 32])
def test_cg_poisson_converges(g):
    fn, _ = model.build_cg_poisson(g)
    coeffs = poisson_coeffs(g)
    rng = np.random.default_rng(g)
    b = jnp.asarray(rng.standard_normal((g, g)))
    x, rr, iters = jax.jit(fn)(coeffs, b, jnp.asarray(5000, jnp.int32),
                               jnp.asarray(1e-10, jnp.float64))
    assert float(jnp.sqrt(rr)) <= 1e-10
    # residual check against the oracle operator
    res = np.asarray(b - stencil_spmv_ref(coeffs, x))
    assert np.linalg.norm(res) <= 1e-9
    assert int(iters) < 5000


def test_cg_respects_iteration_budget():
    g = 16
    fn, _ = model.build_cg_poisson(g)
    coeffs = poisson_coeffs(g)
    b = jnp.ones((g, g))
    _, rr, iters = jax.jit(fn)(coeffs, b, jnp.asarray(3, jnp.int32),
                               jnp.asarray(0.0, jnp.float64))
    assert int(iters) == 3
    assert float(rr) > 0.0


def test_cg_tol_zero_runs_full_budget():
    g = 8
    fn, _ = model.build_cg_poisson(g)
    coeffs = poisson_coeffs(g)
    b = jnp.ones((g, g))
    _, _, iters = jax.jit(fn)(coeffs, b, jnp.asarray(7, jnp.int32),
                              jnp.asarray(0.0, jnp.float64))
    assert int(iters) == 7


@pytest.mark.parametrize("n", [8, 32, 64])
def test_dense_solve_spd(n):
    fn, _ = model.build_dense_solve(n)
    rng = np.random.default_rng(n)
    m = rng.standard_normal((n, n))
    a = jnp.asarray(m @ m.T + n * np.eye(n))
    b = jnp.asarray(rng.standard_normal(n))
    (x,) = jax.jit(fn)(a, b)
    np.testing.assert_allclose(np.asarray(a) @ np.asarray(x), np.asarray(b),
                               rtol=1e-9, atol=1e-9)


def test_dense_solve_identity():
    fn, _ = model.build_dense_solve(8)
    b = jnp.arange(8, dtype=jnp.float64)
    (x,) = jax.jit(fn)(jnp.eye(8), b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(b), atol=1e-14)


@pytest.mark.parametrize("n,s", [(64, 8)])
def test_cg_ell_converges(n, s):
    fn, _ = model.build_cg_ell(n, s)
    # SPD ELL matrix: 1D Laplacian (tridiagonal) padded to s slots
    cols = np.zeros((n, s), np.int32)
    vals = np.zeros((n, s))
    for i in range(n):
        cols[i, 0], vals[i, 0] = i, 2.5
        k = 1
        if i > 0:
            cols[i, k], vals[i, k] = i - 1, -1.0
            k += 1
        if i < n - 1:
            cols[i, k], vals[i, k] = i + 1, -1.0
    diag = jnp.full(n, 2.5)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(n))
    x, rr, _ = jax.jit(fn)(jnp.asarray(cols), jnp.asarray(vals), diag, b,
                           jnp.asarray(1000, jnp.int32), jnp.asarray(1e-11, jnp.float64))
    a = np.zeros((n, n))
    for i in range(n):
        for k in range(s):
            a[i, cols[i, k]] += vals[i, k]
    np.testing.assert_allclose(a @ np.asarray(x), np.asarray(b), rtol=1e-8, atol=1e-8)


def test_stencil_grad_is_vjp():
    g = 8
    fn, _ = model.build_stencil_grad(g)
    rng = np.random.default_rng(5)
    lam = jnp.asarray(rng.standard_normal((g, g)))
    x = jnp.asarray(rng.standard_normal((g, g)))
    (got,) = jax.jit(fn)(lam, x)

    coeffs0 = jnp.asarray(rng.standard_normal((5, g, g)))

    def f(c):
        return stencil_spmv_ref(c, x)

    _, vjp = jax.vjp(f, coeffs0)
    (want,) = vjp(lam)
    np.testing.assert_allclose(np.asarray(got), -np.asarray(want), rtol=1e-13, atol=1e-13)


def test_stencil_residual():
    g = 8
    fn, _ = model.build_stencil_residual(g)
    rng = np.random.default_rng(9)
    coeffs = jnp.asarray(rng.standard_normal((5, g, g)))
    x = jnp.asarray(rng.standard_normal((g, g)))
    b = jnp.asarray(rng.standard_normal((g, g)))
    (r,) = jax.jit(fn)(coeffs, x, b)
    np.testing.assert_allclose(np.asarray(r),
                               np.asarray(b - stencil_spmv_ref(coeffs, x)),
                               rtol=1e-13, atol=1e-13)


def test_dot():
    fn, _ = model.build_dot(65536)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(65536)
    y = rng.standard_normal(65536)
    (d,) = jax.jit(fn)(jnp.asarray(x), jnp.asarray(y))
    assert float(d) == pytest.approx(float(x @ y), rel=1e-12)
