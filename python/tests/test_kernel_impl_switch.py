"""The kernel-impl switch contract (EXPERIMENTS.md §Perf L1/L2).

The AOT artifacts lower either the Pallas kernels (interpret mode; the
TPU-target authority) or the pure-jnp oracle formulation (what the CPU
testbed executes).  These tests pin the contract that makes the switch
sound: BOTH implementations produce identical f64 numerics on the same
inputs, for every artifact family that dispatches through the switch.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ell_spmv, stencil_spmv, ref

jax.config.update("jax_enable_x64", True)


@settings(max_examples=20, deadline=None)
@given(
    g=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_stencil_pallas_equals_jnp_oracle(g, seed):
    rng = np.random.default_rng(seed)
    coeffs = jnp.asarray(rng.normal(size=(5, g, g)))
    x = jnp.asarray(rng.normal(size=(g, g)))
    out_pallas = stencil_spmv(coeffs, x, g=g)
    out_jnp = ref.stencil_spmv_ref(coeffs, x)
    np.testing.assert_allclose(out_pallas, out_jnp, rtol=0, atol=1e-13)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 32, 128, 512]),
    s=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ell_pallas_equals_jnp_oracle(n, s, seed):
    rng = np.random.default_rng(seed)
    cols = jnp.asarray(rng.integers(0, n, size=(n, s)), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, s)))
    # zero out some slots like real padding
    mask = rng.random(size=(n, s)) < 0.3
    vals = jnp.where(jnp.asarray(mask), 0.0, vals)
    x = jnp.asarray(rng.normal(size=(n,)))
    out_pallas = ell_spmv(cols, vals, x, n=n, s=s)
    out_jnp = ref.ell_spmv_ref(cols, vals, x)
    np.testing.assert_allclose(out_pallas, out_jnp, rtol=0, atol=1e-12)


def _mv_with_impl(impl, monkeypatch, fn):
    monkeypatch.setattr(model, "KERNEL_IMPL", impl)
    return fn()


@pytest.mark.parametrize("g", [8, 16])
def test_cg_poisson_graph_identical_under_both_impls(monkeypatch, g):
    """The fused CG artifact semantics do not depend on the kernel impl."""
    rng = np.random.default_rng(0)
    kappa = 1.0 + 0.5 * rng.random(size=g * g)
    # assemble 5-point coefficients the same way the rust side does:
    # use random SPD-ish planes via the ref pattern of poisson -- here we
    # only need SOME well-conditioned stencil, so use the standard one.
    c = np.zeros((5, g, g))
    c[0] = 4.0 * kappa.reshape(g, g)
    c[1:] = -1.0
    coeffs = jnp.asarray(c)
    b = jnp.asarray(rng.normal(size=(g, g)))

    fn, _ = model.build_cg_poisson(g)
    outs = {}
    for impl in ("pallas", "jnp"):
        monkeypatch.setattr(model, "KERNEL_IMPL", impl)
        x, rr, iters = jax.jit(fn)(coeffs, b, jnp.int32(500), jnp.float64(1e-10))
        outs[impl] = (np.asarray(x), float(rr), int(iters))
    np.testing.assert_allclose(outs["pallas"][0], outs["jnp"][0], rtol=0, atol=1e-9)
    assert outs["pallas"][2] == outs["jnp"][2], "iteration counts must agree"


def test_blocked_cholesky_matches_unblocked():
    rng = np.random.default_rng(3)
    n = 256  # > _CHOL_BLOCK so the blocked path runs
    m = rng.normal(size=(n, n))
    a = jnp.asarray(m @ m.T + n * np.eye(n))
    l_blocked = jax.jit(model._cholesky)(a)
    l_unblocked = jax.jit(model._cholesky_unblocked)(a)
    np.testing.assert_allclose(
        np.tril(l_blocked), np.tril(l_unblocked), rtol=0, atol=1e-8
    )
    # and it actually factors A
    lb = np.tril(np.asarray(l_blocked))
    np.testing.assert_allclose(lb @ lb.T, np.asarray(a), rtol=1e-12, atol=1e-8 * n)


def test_dense_solve_artifact_solves_spd_system():
    n = 256
    rng = np.random.default_rng(4)
    m = rng.normal(size=(n, n))
    a = jnp.asarray(m @ m.T + n * np.eye(n))
    b = jnp.asarray(rng.normal(size=n))
    fn, _ = model.build_dense_solve(n)
    (x,) = jax.jit(fn)(a, b)
    np.testing.assert_allclose(np.asarray(a) @ np.asarray(x), np.asarray(b), atol=1e-8)
