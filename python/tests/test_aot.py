"""AOT pipeline: every artifact lowers to custom-call-free HLO text.

The xla_extension 0.5.1 runtime behind the Rust coordinator cannot
execute LAPACK/FFI custom-calls, so lowering any graph that contains one
is a build-time bug this test catches.
"""

import re

import jax
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model

# Lowering every artifact takes a while; test one representative of each
# family at the smallest size plus the whole-name inventory.
REPRESENTATIVE = [
    "stencil_spmv_g32",
    "stencil_residual_g32",
    "stencil_grad_g32",
    "cg_poisson_g32",
    "dense_solve_n64",
    "ell_spmv_n4096_s8",
    "cg_ell_n4096_s8",
    "dot_n65536",
]


@pytest.fixture(scope="module")
def builders():
    return model.artifact_builders()


def test_inventory_complete(builders):
    for name in REPRESENTATIVE:
        assert name in builders
    # every declared grid/dense/ell size is present
    for g in model.GRID_SIZES:
        assert f"cg_poisson_g{g}" in builders
    for n in model.DENSE_SIZES:
        assert f"dense_solve_n{n}" in builders


@pytest.mark.parametrize("name", REPRESENTATIVE)
def test_lowers_clean(builders, name):
    fn, args = builders[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule")
    assert "custom-call" not in text, f"{name} contains a custom call"
    # text parser needs parameter count to match the manifest
    nparams = len(re.findall(r"parameter\(\d+\)", text.split("ENTRY")[-1]))
    assert nparams == len(args)


def test_manifest_spec_roundtrip():
    fn, args = model.build_cg_poisson(32)
    specs = [aot._spec_str(a) for a in args]
    assert specs == ["float64:5x32x32", "float64:32x32", "int32:", "float64:"]
    outs = aot._out_specs(fn, args)
    assert outs == ["float64:32x32", "float64:", "int32:"]


def test_op_histogram_smoke():
    fn, args = model.build_dot(65536)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    hist = aot.op_histogram(text)
    assert sum(hist.values()) > 0
