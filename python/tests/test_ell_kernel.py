"""L1 correctness: Pallas ELL SpMV kernel vs oracle and scipy-style COO."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ell_spmv
from compile.kernels.ref import ell_spmv_ref


def _random_ell(rng, n, s, dtype=np.float64):
    """Random ELL matrix: each row gets 0..s entries, padded with zeros."""
    cols = np.zeros((n, s), dtype=np.int32)
    vals = np.zeros((n, s), dtype=dtype)
    for i in range(n):
        k = rng.integers(0, s + 1)
        if k:
            cols[i, :k] = rng.choice(n, size=k, replace=False)
            vals[i, :k] = rng.standard_normal(k)
    return jnp.asarray(cols), jnp.asarray(vals)


@pytest.mark.parametrize("n,s", [(16, 4), (64, 8), (256, 8), (1024, 5)])
def test_matches_ref(n, s):
    rng = np.random.default_rng(n + s)
    cols, vals = _random_ell(rng, n, s)
    x = jnp.asarray(rng.standard_normal(n))
    got = ell_spmv(cols, vals, x, n=n, s=s)
    want = ell_spmv_ref(cols, vals, x)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("n,s", [(64, 6)])
def test_matches_dense(n, s):
    rng = np.random.default_rng(7)
    cols, vals = _random_ell(rng, n, s)
    a = np.zeros((n, n))
    cn, vn = np.asarray(cols), np.asarray(vals)
    for i in range(n):
        for k in range(s):
            a[i, cn[i, k]] += vn[i, k]
    x = rng.standard_normal(n)
    got = np.asarray(ell_spmv(cols, vals, jnp.asarray(x), n=n, s=s))
    np.testing.assert_allclose(got, a @ x, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([16, 32, 64, 128]),
    s=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, s, seed):
    rng = np.random.default_rng(seed)
    cols, vals = _random_ell(rng, n, s)
    x = jnp.asarray(rng.standard_normal(n))
    got = ell_spmv(cols, vals, x, n=n, s=s)
    want = ell_spmv_ref(cols, vals, x)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([16, 64]), seed=st.integers(0, 2**31 - 1))
def test_float32(n, seed):
    rng = np.random.default_rng(seed)
    cols, vals = _random_ell(rng, n, 4, dtype=np.float32)
    x = jnp.asarray(rng.standard_normal(n), dtype=jnp.float32)
    got = ell_spmv(cols, vals, x, n=n, s=4)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, ell_spmv_ref(cols, vals, x), rtol=1e-5, atol=1e-5)


def test_empty_matrix():
    n, s = 32, 4
    cols = jnp.zeros((n, s), jnp.int32)
    vals = jnp.zeros((n, s))
    x = jnp.ones(n)
    got = ell_spmv(cols, vals, x, n=n, s=s)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_duplicate_slots_accumulate():
    """Two slots hitting the same column must sum, matching COO semantics."""
    n, s = 16, 3
    cols = jnp.zeros((n, s), jnp.int32).at[2].set(jnp.asarray([5, 5, 1]))
    vals = jnp.zeros((n, s)).at[2].set(jnp.asarray([2.0, 3.0, 1.0]))
    x = jnp.arange(n, dtype=jnp.float64)
    got = np.asarray(ell_spmv(cols, vals, x, n=n, s=s))
    assert got[2] == pytest.approx(5.0 * 5 + 1.0 * 1)


def test_resident_variant_matches_shipped_kernel():
    """The first-cut resident-x kernel (kept for the Perf/L1 ablation)
    must stay numerically identical to the shipped gather-hoisted one."""
    import numpy as np
    import jax.numpy as jnp
    from compile.kernels import ell_spmv, ell_spmv_resident

    rng = np.random.default_rng(7)
    n, s = 256, 8
    cols = jnp.asarray(rng.integers(0, n, size=(n, s)), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, s)))
    x = jnp.asarray(rng.normal(size=(n,)))
    a = ell_spmv(cols, vals, x, n=n, s=s)
    b = ell_spmv_resident(cols, vals, x, n=n, s=s)
    np.testing.assert_allclose(a, b, rtol=0, atol=1e-13)
