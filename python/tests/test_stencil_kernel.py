"""L1 correctness: Pallas stencil kernel vs pure-jnp oracle.

The CORE kernel-correctness signal: hypothesis sweeps grid sizes,
dtypes, and coefficient distributions; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import stencil_spmv
from compile.kernels.ref import stencil_spmv_ref, stencil_adjoint_grad_ref

GRIDS = [4, 8, 16, 32, 64]


def _rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("g", GRIDS)
def test_matches_ref_random(g):
    rng = np.random.default_rng(g)
    coeffs = _rand(rng, 5, g, g)
    x = _rand(rng, g, g)
    got = stencil_spmv(coeffs, x, g=g)
    want = stencil_spmv_ref(coeffs, x)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


@pytest.mark.parametrize("g", GRIDS)
def test_constant_poisson_matches_dense(g):
    """Against an explicitly assembled dense 5-point Laplacian."""
    rng = np.random.default_rng(g + 1)
    n = g * g
    a = np.zeros((n, n))
    for i in range(g):
        for j in range(g):
            k = i * g + j
            a[k, k] = 4.0
            if i > 0:
                a[k, k - g] = -1.0
            if i < g - 1:
                a[k, k + g] = -1.0
            if j > 0:
                a[k, k - 1] = -1.0
            if j < g - 1:
                a[k, k + 1] = -1.0
    coeffs = jnp.stack(
        [
            jnp.full((g, g), 4.0),
            jnp.full((g, g), -1.0),
            jnp.full((g, g), -1.0),
            jnp.full((g, g), -1.0),
            jnp.full((g, g), -1.0),
        ]
    )
    x = _rand(rng, g, g)
    got = np.asarray(stencil_spmv(coeffs, x, g=g)).ravel()
    want = a @ np.asarray(x).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    g=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_hypothesis_sweep(g, seed, scale):
    rng = np.random.default_rng(seed)
    coeffs = _rand(rng, 5, g, g) * scale
    x = _rand(rng, g, g)
    got = stencil_spmv(coeffs, x, g=g)
    want = stencil_spmv_ref(coeffs, x)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12 * scale)


@settings(max_examples=10, deadline=None)
@given(g=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_float32_sweep(g, seed):
    rng = np.random.default_rng(seed)
    coeffs = _rand(rng, 5, g, g, dtype=np.float32)
    x = _rand(rng, g, g, dtype=np.float32)
    got = stencil_spmv(coeffs, x, g=g)
    assert got.dtype == jnp.float32
    want = stencil_spmv_ref(coeffs, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_zero_input():
    g = 8
    coeffs = jnp.ones((5, g, g))
    got = stencil_spmv(coeffs, jnp.zeros((g, g)), g=g)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_dirichlet_boundary_is_zero_halo():
    """A one-hot at a corner only reaches in-domain neighbors."""
    g = 8
    rng = np.random.default_rng(0)
    coeffs = _rand(rng, 5, g, g)
    x = jnp.zeros((g, g)).at[0, 0].set(1.0)
    got = np.asarray(stencil_spmv(coeffs, x, g=g))
    # contributions: center at (0,0), dn at (1,0), rt-neighborhood at (0,1)
    nz = {(0, 0), (1, 0), (0, 1)}
    for i in range(g):
        for j in range(g):
            if (i, j) not in nz:
                assert got[i, j] == 0.0


def test_adjoint_grad_ref_matches_jax_vjp():
    """ref.stencil_adjoint_grad == -VJP of (coeffs -> A(coeffs)x)."""
    g = 8
    rng = np.random.default_rng(3)
    coeffs = _rand(rng, 5, g, g)
    x = _rand(rng, g, g)
    lam = _rand(rng, g, g)

    def f(c):
        return stencil_spmv_ref(c, x)

    _, vjp = jax.vjp(f, coeffs)
    (want,) = vjp(lam)
    got = -stencil_adjoint_grad_ref(lam, x)  # Eq. 3 carries the minus sign
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)
