"""L2: the JAX compute graphs AOT-lowered for the Rust coordinator.

Each public ``build_*`` function returns ``(fn, example_args)`` pairs
that aot.py lowers once (``jax.jit(fn).lower(*args)`` -> stablehlo ->
XlaComputation -> HLO text).  Python is build-time only; the Rust hot
path executes the resulting artifacts through PJRT.

Graph inventory (see DESIGN.md artifact set):

* ``stencil_spmv_g{g}``      — one Pallas stencil SpMV (the xla-hybrid
                               backend's per-iteration kernel call).
* ``cg_poisson_g{g}``        — the *fused* Jacobi-PCG loop: Pallas SpMV
                               inside ``lax.while_loop``; max_iters and
                               tol are runtime scalars, so one artifact
                               per grid size serves every solve/adjoint
                               call (the pytorch-native-CUDA-CG analog).
* ``stencil_residual_g{g}``  — b - A x (adjoint-framework residual probe).
* ``stencil_grad_g{g}``      — paper Eq. 3 matrix-gradient outer product
                               on the stencil pattern.
* ``dense_solve_n{n}``       — hand-written Cholesky + triangular solves
                               (the cuDSS analog; jnp.linalg would lower
                               to lapack FFI custom-calls the 0.5.1 PJRT
                               runtime cannot execute).
* ``ell_spmv_n{n}_s{s}``     — general ELL SpMV.
* ``cg_ell_n{n}_s{s}``       — fused Jacobi-PCG over an ELL matrix.
* ``dot_n{n}``               — runtime-call-overhead probe.

All f64: the paper's experiments are float64 end to end.
"""

from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ell_spmv, stencil_spmv, ref

jax.config.update("jax_enable_x64", True)

# --------------------------------------------------------------------------
# Kernel implementation switch (EXPERIMENTS.md §Perf L1/L2).
#
# The Pallas kernels are the TPU-target authority: their BlockSpec
# structure IS the paper's hot-spot contribution re-thought for a
# TPU memory hierarchy, and pytest proves them equal to the pure-jnp
# oracles over hypothesis sweeps.  But `interpret=True` (the only mode
# the CPU PJRT runtime can execute) lowers each grid program to a
# while_loop step with full-buffer dynamic-update-slices — measured
# 28x (stencil g=512) to 160x (ell n=65536) slower than the SAME
# semantics expressed as plain jnp ops, which XLA:CPU fuses into tight
# vector loops.  Since interpret-mode wallclock is NOT a TPU proxy
# (DESIGN.md §Hardware-Adaptation), the artifacts this CPU testbed
# executes lower the oracle formulation by default; set
# RSLA_KERNEL_IMPL=pallas to embed the interpret-mode kernels instead
# (identical numerics, bit-for-bit in f64 — the pytest contract).
# --------------------------------------------------------------------------
KERNEL_IMPL = os.environ.get("RSLA_KERNEL_IMPL", "jnp")


def _stencil_mv(coeffs, x, *, g: int):
    if KERNEL_IMPL == "pallas":
        return stencil_spmv(coeffs, x, g=g)
    return ref.stencil_spmv_ref(coeffs, x)


def _ell_mv(cols, vals, x, *, n: int, s: int):
    if KERNEL_IMPL == "pallas":
        return ell_spmv(cols, vals, x, n=n, s=s)
    return ref.ell_spmv_ref(cols, vals, x)

F64 = jnp.float64
I32 = jnp.int32

GRID_SIZES = (32, 64, 128, 256, 512)
DENSE_SIZES = (64, 256, 1024, 2048, 4096)
ELL_SIZES = ((4096, 8), (16384, 8), (65536, 8))
DOT_SIZES = (65536,)


# --------------------------------------------------------------------------
# Stencil graphs (2D Poisson family)
# --------------------------------------------------------------------------


def build_stencil_spmv(g: int):
    def fn(coeffs, x):
        return (_stencil_mv(coeffs, x, g=g),)

    args = (
        jax.ShapeDtypeStruct((5, g, g), F64),
        jax.ShapeDtypeStruct((g, g), F64),
    )
    return fn, args


def build_stencil_residual(g: int):
    def fn(coeffs, x, b):
        return (b - _stencil_mv(coeffs, x, g=g),)

    s = jax.ShapeDtypeStruct((g, g), F64)
    return fn, (jax.ShapeDtypeStruct((5, g, g), F64), s, s)


def build_stencil_grad(g: int):
    """Adjoint matrix gradient: (lam, x) -> dL/dcoeffs (paper Eq. 3)."""

    def fn(lam, x):
        xp = jnp.pad(x, 1)
        center = xp[1 : g + 1, 1 : g + 1]
        up = xp[0:g, 1 : g + 1]
        dn = xp[2 : g + 2, 1 : g + 1]
        lf = xp[1 : g + 1, 0:g]
        rt = xp[1 : g + 1, 2 : g + 2]
        return (
            jnp.stack([-lam * center, -lam * up, -lam * dn, -lam * lf, -lam * rt]),
        )

    s = jax.ShapeDtypeStruct((g, g), F64)
    return fn, (s, s)


def _pcg(matvec: Callable, diag_inv, b_flat, x0, max_iters, tol):
    """Jacobi-preconditioned CG with runtime iteration/tolerance control.

    The loop carry is donated by XLA (everything stays on-device); the
    whole solve is ONE artifact execution from Rust, which is the entire
    point of the xla-cg backend: no per-iteration host round trip.
    Returns (x, ||r||^2, iters).
    """
    r0 = b_flat - matvec(x0)
    z0 = diag_inv * r0
    rz0 = jnp.vdot(r0, z0)
    rr0 = jnp.vdot(r0, r0)
    tol2 = tol * tol

    def cond(carry):
        i, _x, _r, _p, _rz, rr = carry
        return jnp.logical_and(i < max_iters, rr > tol2)

    def body(carry):
        i, x, r, p, rz, _rr = carry
        ap = matvec(p)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        z = diag_inv * r
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (i + 1, x, r, p, rz_new, jnp.vdot(r, r))

    init = (jnp.asarray(0, I32), x0, r0, z0, rz0, rr0)
    i, x, r, _p, _rz, rr = jax.lax.while_loop(cond, body, init)
    return x, rr, i


def build_cg_poisson(g: int):
    """Fused Jacobi-PCG over the stencil operator; x0 = 0."""

    def fn(coeffs, b, max_iters, tol):
        diag_inv = 1.0 / coeffs[0].reshape(-1)

        def matvec(v):
            return _stencil_mv(coeffs, v.reshape(g, g), g=g).reshape(-1)

        x, rr, iters = _pcg(
            matvec,
            diag_inv,
            b.reshape(-1),
            jnp.zeros(g * g, F64),
            max_iters,
            tol,
        )
        return x.reshape(g, g), rr, iters

    args = (
        jax.ShapeDtypeStruct((5, g, g), F64),
        jax.ShapeDtypeStruct((g, g), F64),
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct((), F64),
    )
    return fn, args


# --------------------------------------------------------------------------
# Dense direct solve (the cuDSS stand-in)
# --------------------------------------------------------------------------


def _cholesky_unblocked(a):
    """Right-looking Cholesky via masked full-matrix updates.

    jnp.linalg.cholesky lowers to a LAPACK FFI custom call that the
    xla_extension 0.5.1 CPU runtime cannot execute, so the factorization
    is written in primitive HLO ops: n fori_loop steps, each a masked
    rank-1 update.  O(n^3) flops like LAPACK, fully fuseable by XLA.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, state):
        l, w = state
        d = jnp.sqrt(w[j, j])
        col = jnp.where(idx > j, w[:, j] / d, 0.0)
        col_with_diag = col.at[j].set(d)
        l = l.at[:, j].set(col_with_diag)
        w = w - jnp.outer(col, col)
        return (l, w)

    l0 = jnp.zeros_like(a)
    l, _ = jax.lax.fori_loop(0, n, body, (l0, a))
    return l


def _trsm_right_lt(b, l):
    """Solve X L^T = B for X, with L (nb, nb) lower-triangular, B (m, nb).

    Column sweep via fori_loop: X[:, j] = (B[:, j] - X @ masked L[j, :]) / L[j, j].
    The masked matvec reads garbage in columns >= j of X but multiplies
    them by zero, keeping every shape static.
    """
    nb = l.shape[0]
    col_idx = jnp.arange(nb)

    def body(j, x):
        lrow = jax.lax.dynamic_slice(l, (j, 0), (1, nb))[0]
        lmask = jnp.where(col_idx < j, lrow, 0.0)
        ljj = jax.lax.dynamic_slice(l, (j, j), (1, 1))[0, 0]
        bcol = jax.lax.dynamic_slice(b, (0, j), (b.shape[0], 1))[:, 0]
        xcol = (bcol - x @ lmask) / ljj
        return jax.lax.dynamic_update_slice(x, xcol[:, None], (0, j))

    return jax.lax.fori_loop(0, nb, body, b)


_CHOL_BLOCK = 128


def _cholesky(a):
    """Blocked right-looking Cholesky (EXPERIMENTS.md §Perf L2).

    The unblocked fori_loop version serializes n rank-1 updates, which
    XLA:CPU executes at <1 GFLOP/s (measured 57 s at n=4096).  The
    blocked form does (2/3)n^3 of its flops inside `l21 @ l21.T` panel
    matmuls — the op XLA:CPU actually optimizes — with only nb-step
    loops left on the critical path.  The k-loop runs at trace time
    (static shapes, ~n/nb unrolled blocks in the HLO).
    """
    n = a.shape[0]
    nb = _CHOL_BLOCK
    if n <= nb:
        return _cholesky_unblocked(a)
    assert n % nb == 0, "dense artifact sizes are multiples of the block"
    l = jnp.zeros_like(a)
    for k in range(0, n, nb):
        akk = jax.lax.dynamic_slice(a, (k, k), (nb, nb))
        lkk = _cholesky_unblocked(akk)
        l = jax.lax.dynamic_update_slice(l, lkk, (k, k))
        m = n - k - nb
        if m > 0:
            a21 = jax.lax.dynamic_slice(a, (k + nb, k), (m, nb))
            l21 = _trsm_right_lt(a21, lkk)
            l = jax.lax.dynamic_update_slice(l, l21, (k + nb, k))
            a22 = jax.lax.dynamic_slice(a, (k + nb, k + nb), (m, m))
            a22 = a22 - l21 @ l21.T
            a = jax.lax.dynamic_update_slice(a, a22, (k + nb, k + nb))
    return l


def _tri_lower_solve(l, b):
    n = l.shape[0]

    def body(j, y):
        dot = jnp.vdot(l[j, :], y)  # uses only y[<j]; y[j] is still 0
        return y.at[j].set((b[j] - dot) / l[j, j])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _tri_upper_solve_lt(l, y):
    """Solve L^T x = y."""
    n = l.shape[0]

    def body(k, x):
        j = n - 1 - k
        dot = jnp.vdot(l[:, j], x)  # uses only x[>j]
        return x.at[j].set((y[j] - dot) / l[j, j])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))


def build_dense_solve(n: int):
    """SPD dense solve: Cholesky factor + two triangular solves."""

    def fn(a, b):
        l = _cholesky(a)
        y = _tri_lower_solve(l, b)
        x = _tri_upper_solve_lt(l, y)
        return (x,)

    return fn, (
        jax.ShapeDtypeStruct((n, n), F64),
        jax.ShapeDtypeStruct((n,), F64),
    )


# --------------------------------------------------------------------------
# ELL graphs (general sparsity)
# --------------------------------------------------------------------------


def build_ell_spmv(n: int, s: int):
    def fn(cols, vals, x):
        return (_ell_mv(cols, vals, x, n=n, s=s),)

    return fn, (
        jax.ShapeDtypeStruct((n, s), I32),
        jax.ShapeDtypeStruct((n, s), F64),
        jax.ShapeDtypeStruct((n,), F64),
    )


def build_cg_ell(n: int, s: int):
    """Fused Jacobi-PCG over an ELL matrix; diag passed explicitly."""

    def fn(cols, vals, diag, b, max_iters, tol):
        def matvec(v):
            return _ell_mv(cols, vals, v, n=n, s=s)

        x, rr, iters = _pcg(
            matvec, 1.0 / diag, b, jnp.zeros(n, F64), max_iters, tol
        )
        return x, rr, iters

    return fn, (
        jax.ShapeDtypeStruct((n, s), I32),
        jax.ShapeDtypeStruct((n, s), F64),
        jax.ShapeDtypeStruct((n,), F64),
        jax.ShapeDtypeStruct((n,), F64),
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct((), F64),
    )


def build_dot(n: int):
    def fn(x, y):
        return (jnp.vdot(x, y),)

    s = jax.ShapeDtypeStruct((n,), F64)
    return fn, (s, s)


# --------------------------------------------------------------------------
# Artifact manifest
# --------------------------------------------------------------------------


def artifact_builders():
    """name -> (fn, example_args) for every artifact aot.py emits."""
    out = {}
    for g in GRID_SIZES:
        out[f"stencil_spmv_g{g}"] = build_stencil_spmv(g)
        out[f"stencil_residual_g{g}"] = build_stencil_residual(g)
        out[f"stencil_grad_g{g}"] = build_stencil_grad(g)
        out[f"cg_poisson_g{g}"] = build_cg_poisson(g)
    for n in DENSE_SIZES:
        out[f"dense_solve_n{n}"] = build_dense_solve(n)
    for n, s in ELL_SIZES:
        out[f"ell_spmv_n{n}_s{s}"] = build_ell_spmv(n, s)
        out[f"cg_ell_n{n}_s{s}"] = build_cg_ell(n, s)
    for n in DOT_SIZES:
        out[f"dot_n{n}"] = build_dot(n)
    return out
