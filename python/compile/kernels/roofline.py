"""Structural performance model for the L1 kernels.

``interpret=True`` wallclock is CPU-numpy time, not a TPU proxy, so the
perf pass (EXPERIMENTS.md §Perf / L1) optimizes *structure*: VMEM
footprint per program, bytes moved HBM<->VMEM, and arithmetic intensity.
This module computes those numbers from the BlockSpec parameters so the
block-shape sweep is quantitative.

Run ``python -m compile.kernels.roofline`` for the report.
"""

from __future__ import annotations

from dataclasses import dataclass

F64 = 8
I32 = 4
VMEM_BYTES = 16 * 2 ** 20  # v4-class core: 16 MiB usable VMEM


@dataclass
class KernelModel:
    name: str
    vmem_bytes: int
    hbm_read_bytes: int
    hbm_write_bytes: int
    flops: int
    programs: int

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1, self.hbm_read_bytes + self.hbm_write_bytes)

    def row(self) -> str:
        return (
            f"{self.name:<28} programs={self.programs:<6} "
            f"vmem/prog={self.vmem_bytes/2**10:8.1f} KiB "
            f"({100*self.vmem_bytes/VMEM_BYTES:5.2f}% of 16MiB)  "
            f"HBM r+w={(self.hbm_read_bytes+self.hbm_write_bytes)/2**20:8.2f} MiB  "
            f"AI={self.arithmetic_intensity:6.3f} flop/B"
        )


def stencil_model(g: int, br: int) -> KernelModel:
    """VMEM/HBM model of stencil_spmv with row-strip height br."""
    programs = g // br
    # per program: halo window (br+2)(g+2) + 5 coeff strips + out strip
    vmem = F64 * ((br + 2) * (g + 2) + 5 * br * g + br * g)
    # HBM traffic: coeffs+out exactly once; x rows re-read by the halo
    # overlap factor (br+2)/br.
    hbm_r = F64 * (5 * g * g + (g + 2) * (g + 2) * (br + 2) // br)
    hbm_w = F64 * g * g
    flops = 9 * g * g  # 5 mul + 4 add per cell
    return KernelModel(f"stencil_spmv g={g} br={br}", vmem, hbm_r, hbm_w, flops, programs)


def ell_model(n: int, s: int, br: int) -> KernelModel:
    programs = n // br
    vmem = F64 * (n + br * s + br) + I32 * br * s
    # x is resident per program -> re-read n/br times (the structural cost
    # of the gather; a real TPU kernel would shard x when n is huge).
    hbm_r = F64 * (n * s + n * programs) + I32 * n * s
    hbm_w = F64 * n
    flops = 2 * n * s
    return KernelModel(f"ell_spmv n={n} s={s} br={br}", vmem, hbm_r, hbm_w, flops, programs)


def ell_model_v2(n: int, s: int, br: int) -> KernelModel:
    """The shipped ELL structure (Perf/L1): gather hoisted out of the
    kernel, dense (br, s) tiles streamed through VMEM.

    Per-program VMEM drops from O(n) to O(br*s); HBM traffic is one pass
    over xg, vals, y plus the gather's own O(n*s) read -- flat
    arithmetic intensity in n, unlike ell_model (the `resident` first
    cut kept for the ablation).
    """
    programs = n // br
    vmem = F64 * (2 * br * s + br)
    # gather reads x (n) + cols (i32 n*s), writes xg (n*s); kernel reads
    # xg + vals once, writes y once.
    hbm_r = F64 * (n + 3 * n * s) + I32 * n * s
    hbm_w = F64 * (n * s + n)
    flops = 2 * n * s
    return KernelModel(
        f"ell_spmv(v2) n={n} s={s} br={br}", vmem, hbm_r, hbm_w, flops, programs
    )


def report() -> str:
    from .stencil import _block_rows as stencil_br
    from .ell import _block_rows as ell_br

    lines = ["== L1 kernel structural roofline model =="]
    for g in (32, 64, 128, 256, 512):
        lines.append(stencil_model(g, stencil_br(g)).row())
    lines.append("-- resident first cut (ablation; x re-streamed per strip) --")
    for n in (4096, 16384, 65536):
        lines.append(ell_model(n, 8, ell_br(n)).row())
    lines.append("-- shipped v2 (gather hoisted; dense tiles) --")
    for n in (4096, 16384, 65536):
        lines.append(ell_model_v2(n, 8, ell_br(n)).row())
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
