from .stencil import stencil_spmv
from .ell import ell_spmv, ell_spmv_resident
from . import ref

__all__ = ["stencil_spmv", "ell_spmv", "ell_spmv_resident", "ref"]
