"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but shifts/pads/gathers; pytest (python/tests) asserts
allclose between kernel and oracle over a hypothesis sweep of shapes,
dtypes, and coefficient distributions.  These are also the semantics the
Rust substrate (rust/src/sparse) re-implements natively, so the oracle
doubles as the cross-language contract.
"""

from __future__ import annotations

import jax.numpy as jnp


def stencil_spmv_ref(coeffs: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """(5, g, g) coefficients x (g, g) grid -> (g, g); Dirichlet halo."""
    xp = jnp.pad(x, 1)
    g = x.shape[0]
    center = xp[1 : g + 1, 1 : g + 1]
    up = xp[0:g, 1 : g + 1]
    dn = xp[2 : g + 2, 1 : g + 1]
    lf = xp[1 : g + 1, 0:g]
    rt = xp[1 : g + 1, 2 : g + 2]
    return (
        coeffs[0] * center
        + coeffs[1] * up
        + coeffs[2] * dn
        + coeffs[3] * lf
        + coeffs[4] * rt
    )


def ell_spmv_ref(cols: jnp.ndarray, vals: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """ELL SpMV oracle: padded slots must carry vals == 0."""
    return jnp.sum(vals * x[cols], axis=1)


def stencil_adjoint_grad_ref(lam: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """dL/d(coeffs) for L with adjoint lam at solution x (paper Eq. 3).

    For y = A(c) x, dL/dc_plane[i,j] = -lam[i,j] * (shifted x)[i,j]:
    the matrix-gradient outer product -lam_i x_j materialized only on the
    5-point pattern, returned as (5, g, g) planes.
    """
    g = x.shape[0]
    xp = jnp.pad(x, 1)
    center = xp[1 : g + 1, 1 : g + 1]
    up = xp[0:g, 1 : g + 1]
    dn = xp[2 : g + 2, 1 : g + 1]
    lf = xp[1 : g + 1, 0:g]
    rt = xp[1 : g + 1, 2 : g + 2]
    return jnp.stack(
        [-lam * center, -lam * up, -lam * dn, -lam * lf, -lam * rt]
    )
