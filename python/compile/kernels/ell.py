"""L1 Pallas kernel: general SpMV in ELL (padded row-major) layout.

The paper's general-sparsity workloads (GNN graph Laplacians,
SparseTensorList batches) need an SpMV whose layout is accelerator
friendly.  CSR's ragged rows map poorly onto a systolic/vector unit, so
we use ELLPACK: every row stores exactly ``s`` (column, value) slots,
short rows padded with (0, 0.0).  The (n, s) slot matrix is dense, tiles
cleanly into VMEM row strips, and the row reduction is a short dense
axis — the TPU re-think of the CUDA one-warp-per-row pattern.

The gather ``x[cols]`` is the only irregular access; the whole x vector
is resident per program (BlockSpec over rows only), matching how a TPU
kernel would pin the multiplicand in VMEM while streaming the slots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_rows(n: int) -> int:
    br = 1
    while br * 2 <= min(n, 512) and n % (br * 2) == 0:
        br *= 2
    return br


def _ell_kernel_resident(x_ref, cols_ref, vals_ref, y_ref):
    x = x_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...]
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


@functools.partial(jax.jit, static_argnames=("n", "s"))
def ell_spmv_resident(
    cols: jax.Array, vals: jax.Array, x: jax.Array, *, n: int, s: int
) -> jax.Array:
    """First-cut ELL SpMV: the WHOLE x vector resident per program.

    Kept for the Perf/L1 ablation: the roofline model shows its
    HBM traffic scaling as O(n^2 / br) -- x is re-streamed by every row
    strip -- with arithmetic intensity collapsing from 0.095 to 0.014
    flop/B between n=4k and n=64k.  See ``ell_spmv`` for the fixed
    structure.
    """
    br = _block_rows(n)
    slot_spec = pl.BlockSpec((br, s), lambda i: (i, 0))
    return pl.pallas_call(
        _ell_kernel_resident,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),  # whole x resident
            slot_spec,
            slot_spec,
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, cols, vals)


def _ell_kernel(xg_ref, vals_ref, y_ref):
    # dense (br, s) tiles: pure VPU multiply + short-axis reduce
    y_ref[...] = jnp.sum(vals_ref[...] * xg_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("n", "s"))
def ell_spmv(cols: jax.Array, vals: jax.Array, x: jax.Array, *, n: int, s: int) -> jax.Array:
    """y = A x for A in ELL layout (Perf/L1 structure).

    The irregular gather ``x[cols]`` runs OUTSIDE the kernel as one
    XLA-native gather (on TPU: a sparsecore/XLA gather into an (n, s)
    buffer); the Pallas kernel then streams perfectly dense (br, s)
    tiles -- multiply + short-axis reduce on the VPU -- so per-program
    VMEM is O(br*s), HBM traffic is one pass over each operand, and
    arithmetic intensity stays flat in n (see kernels/roofline.py,
    ``ell_model_v2``).

    Args:
      cols: (n, s) int32 column indices; padding slots must point at any
        valid index (0 by convention) with ``vals == 0``.
      vals: (n, s) f64 values.
      x: (n,) multiplicand.
      n, s: static row count and slots per row.

    Returns:
      (n,) product vector.
    """
    br = _block_rows(n)
    xg = x[cols]  # XLA-native gather, O(n*s)
    slot_spec = pl.BlockSpec((br, s), lambda i: (i, 0))
    return pl.pallas_call(
        _ell_kernel,
        grid=(n // br,),
        in_specs=[slot_spec, slot_spec],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(xg, vals)
