"""L1 Pallas kernel: variable-coefficient 5-point stencil SpMV.

This is the compute hot-spot of every Poisson-family experiment in the
paper (Tables 3-4, Fig. 2-3): ``y = A(c) x`` where ``A`` is the 5-point
finite-difference operator with per-cell coefficients.  On the paper's
hardware this is a CUDA SpMV; here it is re-thought for a TPU-style
memory hierarchy:

* the (g, g) interior grid is tiled into row strips of ``br`` rows; each
  program instance streams one strip of the five coefficient planes
  through VMEM (``BlockSpec((br, g), lambda i: (i, 0))``),
* the zero-padded input ``xp`` of shape (g+2, g+2) is kept whole and each
  program loads its (br+2, g+2) halo window with one dynamic-slice row
  load — the halo rows are re-read by at most two programs, i.e. the
  HBM->VMEM schedule that CUDA expressed with overlapping threadblocks,
* all arithmetic is elementwise VPU work on dense (br, g) tiles; there is
  no gather, so the tile shape is MXU/VPU friendly.

Dirichlet boundaries are encoded by the zero padding, so the kernel body
is branch-free.  ``interpret=True`` everywhere: the CPU PJRT runtime used
by the Rust coordinator cannot execute Mosaic custom-calls (see
DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_rows(g: int) -> int:
    """Row-strip height: largest power-of-two divisor of g capped at 64.

    Keeps the per-program VMEM window (br+2)*(g+2) + 6*br*g f64 within a
    ~1 MiB budget for the grid sizes we AOT (g <= 512); see
    kernels/roofline.py for the exact footprint accounting.
    """
    br = 1
    while br * 2 <= min(g, 64) and g % (br * 2) == 0:
        br *= 2
    return br


def _stencil_kernel(xp_ref, c_ref, up_ref, dn_ref, lf_ref, rt_ref, y_ref, *, br, g):
    i = pl.program_id(0)
    # (br+2, g+2) halo window: rows [i*br, i*br + br + 2) of the padded grid.
    xs = pl.load(xp_ref, (pl.dslice(i * br, br + 2), slice(None)))
    center = xs[1 : br + 1, 1 : g + 1]
    up = xs[0:br, 1 : g + 1]
    dn = xs[2 : br + 2, 1 : g + 1]
    lf = xs[1 : br + 1, 0:g]
    rt = xs[1 : br + 1, 2 : g + 2]
    y_ref[...] = (
        c_ref[...] * center
        + up_ref[...] * up
        + dn_ref[...] * dn
        + lf_ref[...] * lf
        + rt_ref[...] * rt
    )


@functools.partial(jax.jit, static_argnames=("g",))
def stencil_spmv(coeffs: jax.Array, x: jax.Array, *, g: int) -> jax.Array:
    """Apply the variable-coefficient 5-point operator.

    Args:
      coeffs: (5, g, g) coefficient planes, ordered (center, up, down,
        left, right); ``up`` multiplies x[i-1, j] etc.
      x: (g, g) interior grid values.
      g: grid side (static).

    Returns:
      (g, g) result of ``A(coeffs) @ vec(x)`` reshaped to the grid.
    """
    br = _block_rows(g)
    xp = jnp.pad(x, 1)  # homogeneous Dirichlet halo
    c, up, dn, lf, rt = coeffs[0], coeffs[1], coeffs[2], coeffs[3], coeffs[4]
    kern = functools.partial(_stencil_kernel, br=br, g=g)
    coeff_spec = pl.BlockSpec((br, g), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(g // br,),
        in_specs=[
            pl.BlockSpec((g + 2, g + 2), lambda i: (0, 0)),  # whole padded x
            coeff_spec,
            coeff_spec,
            coeff_spec,
            coeff_spec,
            coeff_spec,
        ],
        out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, g), x.dtype),
        interpret=True,
    )(xp, c, up, dn, lf, rt)
