"""AOT lowering: jax -> stablehlo -> XlaComputation -> HLO *text*.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla_extension 0.5.1 runtime behind the Rust
``xla`` crate rejects (``proto.id() <= INT_MAX``); the HLO text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per artifact NAME in model.artifact_builders():
  artifacts/NAME.hlo.txt   — the HLO module
  artifacts/manifest.tsv   — one line per artifact:
                             NAME <TAB> param0;param1;... <TAB> out0;out1;...
                             where each entry is dtype:dim0xdim1x...
                             (scalar dims field empty -> "f64:")

``--report`` additionally prints an HLO fusion/op-count audit used by the
L2 perf pass (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import argparse
import collections
import os
import re
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    dt = str(s.dtype)
    dims = "x".join(str(d) for d in s.shape)
    return f"{dt}:{dims}"


def _out_specs(fn, args):
    outs = jax.eval_shape(fn, *args)
    return [_spec_str(o) for o in outs]


def op_histogram(hlo_text: str) -> collections.Counter:
    """Rough opcode histogram of an HLO module (perf audit)."""
    hist: collections.Counter = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s]+?\s([a-z\-]+)\(", line)
        if m:
            hist[m.group(1)] += 1
    return hist


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--report", action="store_true", help="print HLO op-count audit")
    ns = ap.parse_args()

    os.makedirs(ns.out, exist_ok=True)
    builders = model.artifact_builders()
    if ns.only:
        pat = re.compile(ns.only)
        builders = {k: v for k, v in builders.items() if pat.search(k)}

    manifest_lines = []
    for name, (fn, args) in sorted(builders.items()):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(ns.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        params = ";".join(_spec_str(a) for a in args)
        outs = ";".join(_out_specs(fn, args))
        manifest_lines.append(f"{name}\t{params}\t{outs}")
        msg = f"  {name}: {len(text) / 1024:.0f} KiB"
        if ns.report:
            hist = op_histogram(text)
            total = sum(hist.values())
            top = ", ".join(f"{k}x{v}" for k, v in hist.most_common(6))
            msg += f"  ops={total} [{top}]"
        print(msg)

    if not ns.only:  # partial runs must not clobber the full manifest
        with open(os.path.join(ns.out, "manifest.tsv"), "w") as f:
            f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(builders)} artifacts to {ns.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
