//! Sparse matrix substrate: storage formats, kernels, and assemblers.
//!
//! The paper builds on `torch.sparse` COO/CSR storage; this module is the
//! from-scratch equivalent.  [`Coo`] is the assembly format (duplicate
//! entries sum), [`Csr`] the compute format (SpMV/SpMM/transpose), and
//! [`pattern::Pattern`] the shared sparsity-structure handle that lets a
//! batch of matrices reuse one symbolic analysis (paper §3.1,
//! `SparseTensor` with a leading batch dimension).
//!
//! Assemblers ([`poisson`], [`graphs`]) generate every workload used by
//! the paper's evaluation: variable-coefficient 2D Poisson operators and
//! graph Laplacians.

pub mod coo;
pub mod csr;
pub mod graphs;
pub mod key;
pub mod pattern;
pub mod poisson;

pub use coo::Coo;
pub use csr::Csr;
pub use key::{PatternKey, StructureKey};
pub use pattern::Pattern;
