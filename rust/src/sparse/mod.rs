//! Sparse matrix substrate: storage formats, kernels, and assemblers.
//!
//! The paper builds on `torch.sparse` COO/CSR storage; this module is the
//! from-scratch equivalent.  [`Coo`] is the assembly format (duplicate
//! entries sum), [`Csr`] the compute format (SpMV/SpMM/transpose), and
//! [`pattern::Pattern`] the shared sparsity-structure handle that lets a
//! batch of matrices reuse one symbolic analysis (paper §3.1,
//! `SparseTensor` with a leading batch dimension).
//!
//! Assemblers ([`poisson`], [`graphs`]) generate every workload used by
//! the paper's evaluation: variable-coefficient 2D Poisson operators and
//! graph Laplacians.

//! The vectorized kernel layer ([`align`], [`sell`], [`kernels`],
//! [`cost`]) adds 64-byte-aligned storage, the SELL-C-σ format, fused
//! multi-vector kernels, and the roofline cost model that picks a
//! format per matrix — see `docs/kernels.md`.

pub mod align;
pub mod coo;
pub mod cost;
pub mod csr;
pub mod graphs;
pub mod kernels;
pub mod key;
pub mod pattern;
pub mod poisson;
pub mod sell;

pub use align::{Align64, AlignedVec};
pub use coo::Coo;
pub use cost::{choose_format, CostReport, FormatChoice, TunedOp};
pub use csr::Csr;
pub use key::{PatternKey, StructureKey};
pub use pattern::Pattern;
pub use sell::Sell;
