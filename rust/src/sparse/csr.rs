//! CSR (compressed sparse row) — the compute format.
//!
//! SpMV here is the L3-native hot path (the XLA backends run the Pallas
//! kernels instead); see EXPERIMENTS.md §Perf for the optimization log.

use crate::error::{Error, Result};

/// CSR sparse matrix with f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    /// Row start offsets, length nrows + 1.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<usize>,
    pub vals: Vec<f64>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Structural invariants of the CSR format: indptr shape and
    /// monotone coverage of indices/vals, and per-row strictly
    /// increasing, in-range column indices.  Returns the first violated
    /// invariant so corrupt assembly fails loudly instead of
    /// mis-solving.
    pub fn validate(&self) -> Result<()> {
        if self.indptr.len() != self.nrows + 1 {
            return Err(Error::InvalidProblem(format!(
                "csr: indptr length {} != nrows + 1 ({})",
                self.indptr.len(),
                self.nrows + 1
            )));
        }
        if self.indptr.first() != Some(&0) {
            return Err(Error::InvalidProblem("csr: indptr[0] != 0".into()));
        }
        if self.indices.len() != self.vals.len() {
            return Err(Error::InvalidProblem(format!(
                "csr: indices length {} != vals length {}",
                self.indices.len(),
                self.vals.len()
            )));
        }
        if self.indptr.last() != Some(&self.vals.len()) {
            return Err(Error::InvalidProblem(format!(
                "csr: indptr end {:?} != nnz {}",
                self.indptr.last(),
                self.vals.len()
            )));
        }
        for (r, w) in self.indptr.windows(2).enumerate() {
            let (lo, hi) = (w[0], w[1]);
            if lo > hi || hi > self.indices.len() {
                return Err(Error::InvalidProblem(format!(
                    "csr: indptr not monotone within nnz at row {r}"
                )));
            }
            let mut prev: Option<usize> = None;
            for &c in &self.indices[lo..hi] {
                if c >= self.ncols {
                    return Err(Error::InvalidProblem(format!(
                        "csr: column {c} out of range at row {r} (ncols {})",
                        self.ncols
                    )));
                }
                if prev.is_some_and(|p| p >= c) {
                    return Err(Error::InvalidProblem(format!(
                        "csr: columns not strictly increasing at row {r}"
                    )));
                }
                prev = Some(c);
            }
        }
        Ok(())
    }

    /// Debug-build invariant gate used by every constructor: release
    /// builds pay nothing, debug builds fail fast on corrupt assembly.
    #[inline]
    pub fn debug_validate(self) -> Self {
        debug_assert!(
            self.validate().is_ok(),
            "invalid CSR from constructor: {:?}",
            self.validate()
        );
        self
    }

    pub fn identity(n: usize) -> Self {
        Csr {
            nrows: n,
            ncols: n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            vals: vec![1.0; n],
        }
        .debug_validate()
    }

    /// Entry (r, c), 0.0 if not stored.  O(log row_nnz).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.indices[lo..hi].binary_search(&c) {
            Ok(k) => self.vals[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Row view: (indices, vals).
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        (&self.indices[lo..hi], &self.vals[lo..hi])
    }

    /// y = A x.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.vals[k] * x[self.indices[k]];
            }
            y[r] = acc;
        }
    }

    /// Allocating SpMV.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.nrows];
        self.spmv(x, &mut y);
        y
    }

    /// y = A^T x without materializing the transpose (scatter form).
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        y.fill(0.0);
        for r in 0..self.nrows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            for k in lo..hi {
                y[self.indices[k]] += self.vals[k] * xr;
            }
        }
    }

    /// Materialized transpose (CSR of A^T), sorted columns.
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &c in &self.indices {
            counts[c + 1] += 1;
        }
        for i in 0..self.ncols {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0usize; self.nnz()];
        let mut vals = vec![0f64; self.nnz()];
        let mut next = counts.clone();
        for r in 0..self.nrows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[k];
                let slot = next[c];
                next[c] += 1;
                indices[slot] = r;
                vals[slot] = self.vals[k];
            }
        }
        // rows were visited in order, so each transposed row is sorted
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr: counts,
            indices,
            vals,
        }
        .debug_validate()
    }

    /// Main diagonal (length min(nrows, ncols)).
    pub fn diag(&self) -> Vec<f64> {
        let n = self.nrows.min(self.ncols);
        (0..n).map(|i| self.get(i, i)).collect()
    }

    /// Structural + numerical symmetry check (|a_ij - a_ji| <= tol).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let t = self.transpose();
        if t.indptr != self.indptr || t.indices != self.indices {
            // patterns differ; fall back to value comparison via get
            for r in 0..self.nrows {
                let (cols, vals) = self.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    if (v - self.get(*c, r)).abs() > tol {
                        return false;
                    }
                }
            }
            return true;
        }
        self.vals
            .iter()
            .zip(&t.vals)
            .all(|(a, b)| (a - b).abs() <= tol)
    }

    /// SPD heuristic used by auto-dispatch (paper §3.1: "symmetry and SPD
    /// are detected on the matrix values"): symmetric, positive diagonal.
    /// Definiteness is confirmed by the Cholesky attempt itself; backends
    /// fall back to LU on breakdown.
    pub fn looks_spd(&self) -> bool {
        self.nrows == self.ncols
            && self.diag().iter().all(|&d| d > 0.0)
            && self.is_symmetric(1e-12)
    }

    /// C = A B (classical Gustavson row-merge SpMM).
    pub fn spmm(&self, b: &Csr) -> Result<Csr> {
        if self.ncols != b.nrows {
            return Err(Error::InvalidProblem(format!(
                "spmm shape mismatch: ({}, {}) x ({}, {})",
                self.nrows, self.ncols, b.nrows, b.ncols
            )));
        }
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        indptr.push(0usize);
        let mut indices: Vec<usize> = Vec::new();
        let mut vals: Vec<f64> = Vec::new();
        // sparse accumulator
        let mut marker = vec![usize::MAX; b.ncols];
        let mut acc = vec![0f64; b.ncols];
        let mut active: Vec<usize> = Vec::new();
        for r in 0..self.nrows {
            active.clear();
            for ka in self.indptr[r]..self.indptr[r + 1] {
                let j = self.indices[ka];
                let va = self.vals[ka];
                for kb in b.indptr[j]..b.indptr[j + 1] {
                    let c = b.indices[kb];
                    if marker[c] != r {
                        marker[c] = r;
                        acc[c] = 0.0;
                        active.push(c);
                    }
                    acc[c] += va * b.vals[kb];
                }
            }
            active.sort_unstable();
            for &c in &active {
                indices.push(c);
                vals.push(acc[c]);
            }
            indptr.push(indices.len());
        }
        Ok(Csr {
            nrows: self.nrows,
            ncols: b.ncols,
            indptr,
            indices,
            vals,
        }
        .debug_validate())
    }

    /// Dense materialization (tests / tiny systems only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                d[r][*c] += v;
            }
        }
        d
    }

    /// Apply a symmetric permutation: B = P A P^T where new index
    /// `i` holds old index `perm[i]` (perm is new->old).
    pub fn permute_sym(&self, perm: &[usize]) -> Csr {
        assert_eq!(self.nrows, self.ncols);
        assert_eq!(perm.len(), self.nrows);
        let n = self.nrows;
        let mut inv = vec![0usize; n];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let mut coo = super::Coo::with_capacity(n, n, self.nnz());
        for r in 0..n {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(inv[r], inv[*c], *v);
            }
        }
        coo.to_csr()
    }

    /// Frobenius-norm relative difference to another matrix (tests).
    pub fn rel_diff(&self, other: &Csr) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for r in 0..self.nrows {
            let mut cols: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
            cols.extend(self.row(r).0.iter().copied());
            cols.extend(other.row(r).0.iter().copied());
            for c in cols {
                let a = self.get(r, c);
                let b = other.get(r, c);
                num += (a - b) * (a - b);
                den += b * b;
            }
        }
        if den == 0.0 {
            num.sqrt()
        } else {
            (num / den).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Prng;

    fn random_csr(rng: &mut Prng, n: usize, per_row: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for r in 0..n {
            for c in rng.choose_distinct(n, per_row) {
                coo.push(r, c, rng.normal());
            }
        }
        coo.to_csr()
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Prng::new(1);
        let a = random_csr(&mut rng, 40, 5);
        let x = rng.normal_vec(40);
        let y = a.matvec(&x);
        let d = a.to_dense();
        for r in 0..40 {
            let want: f64 = (0..40).map(|c| d[r][c] * x[c]).sum();
            assert!((y[r] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn spmv_t_matches_transpose_spmv() {
        let mut rng = Prng::new(2);
        let a = random_csr(&mut rng, 30, 4);
        let x = rng.normal_vec(30);
        let mut y1 = vec![0.0; 30];
        a.spmv_t(&x, &mut y1);
        let y2 = a.transpose().matvec(&x);
        for i in 0..30 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Prng::new(3);
        let a = random_csr(&mut rng, 25, 3);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn symmetric_detection() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 2.0);
        coo.push(2, 2, 2.0);
        coo.push(0, 1, -1.0);
        coo.push(1, 0, -1.0);
        let a = coo.to_csr();
        assert!(a.is_symmetric(0.0));
        assert!(a.looks_spd());

        let mut coo2 = Coo::new(2, 2);
        coo2.push(0, 1, 1.0);
        coo2.push(1, 0, 2.0);
        let b = coo2.to_csr();
        assert!(!b.is_symmetric(1e-15));
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Prng::new(4);
        let a = random_csr(&mut rng, 15, 3);
        let b = random_csr(&mut rng, 15, 3);
        let c = a.spmm(&b).unwrap();
        let da = a.to_dense();
        let db = b.to_dense();
        for r in 0..15 {
            for j in 0..15 {
                let want: f64 = (0..15).map(|k| da[r][k] * db[k][j]).sum();
                assert!((c.get(r, j) - want).abs() < 1e-12, "({r},{j})");
            }
        }
    }

    #[test]
    fn spmm_shape_mismatch_errors() {
        let a = Csr::identity(3);
        let b = Csr::identity(4);
        assert!(a.spmm(&b).is_err());
    }

    #[test]
    fn permute_sym_preserves_spectrum_action() {
        let mut rng = Prng::new(5);
        let a = random_csr(&mut rng, 10, 3);
        let perm: Vec<usize> = {
            let mut p: Vec<usize> = (0..10).collect();
            rng.shuffle(&mut p);
            p
        };
        let b = a.permute_sym(&perm);
        // b[new_i][new_j] == a[perm[new_i]][perm[new_j]]
        for i in 0..10 {
            for j in 0..10 {
                assert!((b.get(i, j) - a.get(perm[i], perm[j])).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn identity_spmv_is_identity() {
        let a = Csr::identity(5);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(a.matvec(&x), x);
    }

    #[test]
    fn diag_extraction() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 2, 5.0);
        coo.push(2, 2, 3.0);
        let a = coo.to_csr();
        assert_eq!(a.diag(), vec![1.0, 0.0, 3.0]);
    }

    #[test]
    fn validate_accepts_every_generated_matrix() {
        crate::util::proptest::check("csr validate accepts", 32, |rng| {
            let n = 2 + rng.below(14);
            let a = random_csr(rng, n, 1 + rng.below(4));
            a.validate().map_err(|e| format!("{e:?}"))
        });
    }

    #[test]
    fn validate_rejects_every_corruption() {
        crate::util::proptest::check("csr validate rejects", 64, |rng| {
            let n = 3 + rng.below(12);
            let mut m = random_csr(rng, n, 2);
            let which = rng.below(6);
            match which {
                0 => {
                    // wrong indptr length
                    m.indptr.pop();
                }
                1 => {
                    // indptr escapes the nnz range mid-array
                    m.indptr[n / 2] = m.vals.len() + 1;
                }
                2 => {
                    // out-of-range column
                    let k = rng.below(m.indices.len());
                    m.indices[k] = m.ncols;
                }
                3 => {
                    // duplicate column within a row (rows have 2 entries)
                    m.indices[1] = m.indices[0];
                }
                4 => {
                    // indices/vals length mismatch
                    m.vals.pop();
                }
                _ => {
                    // indptr must start at zero
                    m.indptr[0] = 1;
                }
            }
            match m.validate() {
                Err(_) => Ok(()),
                Ok(()) => Err(format!("corruption {which} passed validate")),
            }
        });
    }
}
