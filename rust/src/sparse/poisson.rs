//! 2D Poisson assembly — the paper's benchmark workload family.
//!
//! `-div(kappa(x) grad u) = f` on the unit square, homogeneous Dirichlet,
//! discretized with a cell-centered 5-point scheme on a g x g interior
//! grid (h = 1/(g+1)); face conductivities are harmonic means, exactly
//! matching `python/tests/test_model.py::poisson_coeffs` so that the
//! native CSR operator and the AOT stencil artifacts implement the SAME
//! matrix (cross-checked in rust/tests/runtime_integration.rs).

use super::{Coo, Csr};

/// Stencil-form operator: five (g*g)-length coefficient planes in row-major
/// grid order — the layout the L1 Pallas kernel consumes.
/// `up` multiplies u[i-1, j], `dn` u[i+1, j], `lf` u[i, j-1], `rt` u[i, j+1].
#[derive(Clone, Debug)]
pub struct StencilCoeffs {
    pub g: usize,
    pub center: Vec<f64>,
    pub up: Vec<f64>,
    pub dn: Vec<f64>,
    pub lf: Vec<f64>,
    pub rt: Vec<f64>,
}

impl StencilCoeffs {
    pub fn n(&self) -> usize {
        self.g * self.g
    }

    /// Flatten into the (5, g, g) layout of the AOT artifacts.
    pub fn to_planes(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(5 * self.n());
        out.extend_from_slice(&self.center);
        out.extend_from_slice(&self.up);
        out.extend_from_slice(&self.dn);
        out.extend_from_slice(&self.lf);
        out.extend_from_slice(&self.rt);
        out
    }

    /// Inverse of [`StencilCoeffs::to_planes`].
    pub fn from_planes(g: usize, planes: &[f64]) -> Self {
        let n = g * g;
        assert_eq!(planes.len(), 5 * n);
        StencilCoeffs {
            g,
            center: planes[0..n].to_vec(),
            up: planes[n..2 * n].to_vec(),
            dn: planes[2 * n..3 * n].to_vec(),
            lf: planes[3 * n..4 * n].to_vec(),
            rt: planes[4 * n..5 * n].to_vec(),
        }
    }

    /// y = A x applied natively in stencil form (no CSR materialization).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let g = self.g;
        debug_assert_eq!(x.len(), g * g);
        for i in 0..g {
            for j in 0..g {
                let k = i * g + j;
                let mut acc = self.center[k] * x[k];
                if i > 0 {
                    acc += self.up[k] * x[k - g];
                }
                if i + 1 < g {
                    acc += self.dn[k] * x[k + g];
                }
                if j > 0 {
                    acc += self.lf[k] * x[k - 1];
                }
                if j + 1 < g {
                    acc += self.rt[k] * x[k + 1];
                }
                y[k] = acc;
            }
        }
    }

    /// Assemble the equivalent CSR matrix (row-major grid ordering).
    pub fn to_csr(&self) -> Csr {
        let g = self.g;
        let n = g * g;
        let mut coo = Coo::with_capacity(n, n, 5 * n);
        for i in 0..g {
            for j in 0..g {
                let k = i * g + j;
                coo.push(k, k, self.center[k]);
                if i > 0 {
                    coo.push(k, k - g, self.up[k]);
                }
                if i + 1 < g {
                    coo.push(k, k + g, self.dn[k]);
                }
                if j > 0 {
                    coo.push(k, k - 1, self.lf[k]);
                }
                if j + 1 < g {
                    coo.push(k, k + 1, self.rt[k]);
                }
            }
        }
        coo.to_csr()
    }
}

/// A fully assembled Poisson problem.
#[derive(Clone, Debug)]
pub struct PoissonSystem {
    pub g: usize,
    pub coeffs: StencilCoeffs,
    pub matrix: Csr,
    /// Node coordinates (x, y) per unknown, for coordinate partitioners.
    pub coords: Vec<(f64, f64)>,
}

/// Build the variable-coefficient 5-point operator.  `kappa` is a g*g
/// row-major conductivity field (None = constant 1).
pub fn poisson2d(g: usize, kappa: Option<&[f64]>) -> PoissonSystem {
    let coeffs = stencil_coeffs(g, kappa);
    let matrix = coeffs.to_csr();
    let h = 1.0 / (g as f64 + 1.0);
    let coords = (0..g * g)
        .map(|k| {
            let i = k / g;
            let j = k % g;
            ((j as f64 + 1.0) * h, (i as f64 + 1.0) * h)
        })
        .collect();
    PoissonSystem {
        g,
        coeffs,
        matrix,
        coords,
    }
}

/// Harmonic-mean face coefficients; mirrors python poisson_coeffs exactly.
pub fn stencil_coeffs(g: usize, kappa: Option<&[f64]>) -> StencilCoeffs {
    let n = g * g;
    let kap = |i: isize, j: isize| -> f64 {
        // edge-padded lookup
        let ic = i.clamp(0, g as isize - 1) as usize;
        let jc = j.clamp(0, g as isize - 1) as usize;
        match kappa {
            Some(k) => k[ic * g + jc],
            None => 1.0,
        }
    };
    let face = |a: f64, b: f64| 2.0 * a * b / (a + b);
    let h = 1.0 / (g as f64 + 1.0);
    let inv_h2 = 1.0 / (h * h);
    let mut up = vec![0.0; n];
    let mut dn = vec![0.0; n];
    let mut lf = vec![0.0; n];
    let mut rt = vec![0.0; n];
    let mut center = vec![0.0; n];
    for i in 0..g as isize {
        for j in 0..g as isize {
            let k = (i as usize) * g + j as usize;
            let kc = kap(i, j);
            let fu = face(kc, kap(i - 1, j));
            let fd = face(kc, kap(i + 1, j));
            let fl = face(kc, kap(i, j - 1));
            let fr = face(kc, kap(i, j + 1));
            center[k] = (fu + fd + fl + fr) * inv_h2;
            up[k] = -fu * inv_h2;
            dn[k] = -fd * inv_h2;
            lf[k] = -fl * inv_h2;
            rt[k] = -fr * inv_h2;
        }
    }
    StencilCoeffs {
        g,
        center,
        up,
        dn,
        lf,
        rt,
    }
}

/// The paper's ground-truth conductivity for the inverse problem (Fig. 3):
/// kappa*(x, y) = 1 + 0.5 sin(2 pi x) sin(2 pi y) on cell centers.
pub fn kappa_star(g: usize) -> Vec<f64> {
    let h = 1.0 / (g as f64 + 1.0);
    (0..g * g)
        .map(|k| {
            let i = k / g;
            let j = k % g;
            let x = (j as f64 + 1.0) * h;
            let y = (i as f64 + 1.0) * h;
            1.0 + 0.5
                * (2.0 * std::f64::consts::PI * x).sin()
                * (2.0 * std::f64::consts::PI * y).sin()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{self, Prng};

    #[test]
    fn constant_coefficient_is_classic_laplacian() {
        let g = 8;
        let sys = poisson2d(g, None);
        let h2 = (1.0 / (g as f64 + 1.0)).powi(2);
        // interior node: center 4/h^2, neighbors -1/h^2
        let k = (g / 2) * g + g / 2;
        assert!((sys.matrix.get(k, k) - 4.0 / h2).abs() < 1e-9);
        assert!((sys.matrix.get(k, k - 1) + 1.0 / h2).abs() < 1e-9);
        assert!((sys.matrix.get(k, k - g) + 1.0 / h2).abs() < 1e-9);
    }

    #[test]
    fn csr_and_stencil_spmv_agree() {
        let g = 12;
        let kappa = kappa_star(g);
        let sys = poisson2d(g, Some(&kappa));
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(g * g);
        let y_csr = sys.matrix.matvec(&x);
        let mut y_st = vec![0.0; g * g];
        sys.coeffs.spmv(&x, &mut y_st);
        assert!(util::max_abs_diff(&y_csr, &y_st) < 1e-11);
    }

    #[test]
    fn matrix_is_spd() {
        let g = 8;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        assert!(sys.matrix.looks_spd());
        // Gershgorin: rows strictly diagonally dominant or weakly with
        // positive diagonal => positive semidefinite; Dirichlet rows make
        // it definite. x^T A x > 0 spot check:
        let mut rng = Prng::new(1);
        for _ in 0..5 {
            let x = rng.normal_vec(g * g);
            let ax = sys.matrix.matvec(&x);
            assert!(util::dot(&x, &ax) > 0.0);
        }
    }

    #[test]
    fn planes_roundtrip() {
        let g = 6;
        let c = stencil_coeffs(g, Some(&kappa_star(g)));
        let planes = c.to_planes();
        let c2 = StencilCoeffs::from_planes(g, &planes);
        assert_eq!(c.center, c2.center);
        assert_eq!(c.rt, c2.rt);
    }

    #[test]
    fn kappa_star_range() {
        let k = kappa_star(64);
        let lo = k.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = k.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(lo >= 0.5 - 1e-9 && hi <= 1.5 + 1e-9, "range [{lo}, {hi}]");
    }

    #[test]
    fn nnz_is_five_point() {
        let g = 10;
        let sys = poisson2d(g, None);
        // 5n - 4g boundary-truncated entries
        assert_eq!(sys.matrix.nnz(), 5 * g * g - 4 * g);
    }
}
