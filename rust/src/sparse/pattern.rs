//! Shared sparsity patterns — the structural half of a CSR matrix.
//!
//! The paper's `SparseTensor` batches matrices over ONE pattern so that a
//! single symbolic factorization / halo plan is reused across the batch
//! (§3.1).  [`Pattern`] is that shared handle: `Arc`-backed indptr/indices
//! plus per-batch value planes.

use std::sync::Arc;

use super::Csr;

/// Immutable sparsity structure shared across a batch of matrices.
#[derive(Clone, Debug)]
pub struct Pattern {
    pub nrows: usize,
    pub ncols: usize,
    pub indptr: Arc<Vec<usize>>,
    pub indices: Arc<Vec<usize>>,
}

impl Pattern {
    pub fn of(m: &Csr) -> Self {
        Pattern {
            nrows: m.nrows,
            ncols: m.ncols,
            indptr: Arc::new(m.indptr.clone()),
            indices: Arc::new(m.indices.clone()),
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Bind values to the pattern, producing a full CSR view (cheap clone
    /// of the Arc'd structure).
    pub fn with_vals(&self, vals: Vec<f64>) -> Csr {
        assert_eq!(vals.len(), self.nnz(), "value count != pattern nnz");
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr: (*self.indptr).clone(),
            indices: (*self.indices).clone(),
            vals,
        }
    }

    /// True if two patterns are the same structure (pointer or content).
    pub fn same_as(&self, other: &Pattern) -> bool {
        if Arc::ptr_eq(&self.indptr, &other.indptr) && Arc::ptr_eq(&self.indices, &other.indices)
        {
            return true;
        }
        self.nrows == other.nrows
            && self.ncols == other.ncols
            && *self.indptr == *other.indptr
            && *self.indices == *other.indices
    }

    /// Position of (r, c) in the value array, if stored.
    pub fn find(&self, r: usize, c: usize) -> Option<usize> {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].binary_search(&c).ok().map(|k| lo + k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn sample() -> Csr {
        let mut c = Coo::new(3, 3);
        c.push(0, 0, 1.0);
        c.push(0, 2, 2.0);
        c.push(1, 1, 3.0);
        c.push(2, 0, 4.0);
        c.to_csr()
    }

    #[test]
    fn roundtrip_with_vals() {
        let m = sample();
        let p = Pattern::of(&m);
        let m2 = p.with_vals(m.vals.clone());
        assert_eq!(m, m2);
    }

    #[test]
    fn same_as_by_content_and_ptr() {
        let m = sample();
        let p1 = Pattern::of(&m);
        let p2 = p1.clone();
        let p3 = Pattern::of(&m);
        assert!(p1.same_as(&p2));
        assert!(p1.same_as(&p3));
    }

    #[test]
    fn find_positions() {
        let p = Pattern::of(&sample());
        assert_eq!(p.find(0, 2), Some(1));
        assert_eq!(p.find(1, 1), Some(2));
        assert_eq!(p.find(1, 0), None);
    }

    #[test]
    #[should_panic(expected = "value count")]
    fn with_vals_checks_len() {
        let p = Pattern::of(&sample());
        p.with_vals(vec![1.0]);
    }
}
