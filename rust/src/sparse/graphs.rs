//! Graph workload generators: Laplacians of random graphs and random SPD
//! matrices — the paper's "GNN minibatches / neural operators on irregular
//! meshes" batched workloads (§3.1, SparseTensorList) and eigensolver
//! benchmarks.

use super::{Coo, Csr};
use crate::util::Prng;

/// Laplacian L = D - W of a random connected graph with `n` nodes and
/// roughly `avg_degree` edges per node (ring + random chords, so it is
/// always connected).  SPD after the +eps*I shift.
pub fn random_graph_laplacian(rng: &mut Prng, n: usize, avg_degree: usize, shift: f64) -> Csr {
    assert!(n >= 3);
    let mut edges: std::collections::BTreeSet<(usize, usize)> = std::collections::BTreeSet::new();
    for i in 0..n {
        let j = (i + 1) % n; // ring keeps it connected
        edges.insert((i.min(j), i.max(j)));
    }
    let extra = n * avg_degree.saturating_sub(2) / 2;
    while edges.len() < n + extra {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            edges.insert((a.min(b), a.max(b)));
        }
    }
    let mut coo = Coo::with_capacity(n, n, 2 * edges.len() + n);
    let mut deg = vec![0.0f64; n];
    for &(a, b) in &edges {
        let w = rng.range(0.5, 1.5);
        coo.push(a, b, -w);
        coo.push(b, a, -w);
        deg[a] += w;
        deg[b] += w;
    }
    for (i, d) in deg.iter().enumerate() {
        coo.push(i, i, d + shift);
    }
    coo.to_csr()
}

/// Like [`random_graph_laplacian`] but with a hard per-node degree cap
/// (so rows fit an ELL layout with `max_degree + 1` slots).
pub fn bounded_degree_laplacian(rng: &mut Prng, n: usize, max_degree: usize, shift: f64) -> Csr {
    assert!(n >= 3 && max_degree >= 2);
    let mut edges: std::collections::BTreeSet<(usize, usize)> = std::collections::BTreeSet::new();
    let mut deg = vec![0usize; n];
    for i in 0..n {
        let j = (i + 1) % n;
        if edges.insert((i.min(j), i.max(j))) {
            deg[i] += 1;
            deg[j] += 1;
        }
    }
    let attempts = n * max_degree * 4;
    for _ in 0..attempts {
        let a = rng.below(n);
        let b = rng.below(n);
        if a == b || deg[a] >= max_degree || deg[b] >= max_degree {
            continue;
        }
        if edges.insert((a.min(b), a.max(b))) {
            deg[a] += 1;
            deg[b] += 1;
        }
    }
    let mut coo = Coo::with_capacity(n, n, 2 * edges.len() + n);
    let mut wdeg = vec![0.0f64; n];
    for &(a, b) in &edges {
        let w = rng.range(0.5, 1.5);
        coo.push(a, b, -w);
        coo.push(b, a, -w);
        wdeg[a] += w;
        wdeg[b] += w;
    }
    for (i, d) in wdeg.iter().enumerate() {
        coo.push(i, i, d + shift);
    }
    coo.to_csr()
}

/// Random sparse SPD matrix: A = B B^T + shift I where B is a random
/// sparse matrix with `per_row` entries per row.  Pattern differs per
/// call — the "distinct patterns" batched workload.
pub fn random_spd(rng: &mut Prng, n: usize, per_row: usize, shift: f64) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * per_row);
    for r in 0..n {
        for c in rng.choose_distinct(n, per_row) {
            coo.push(r, c, rng.normal());
        }
    }
    let b = coo.to_csr();
    let bt = b.transpose();
    let mut a = b.spmm(&bt).expect("square"); // rsla-lint: allow(L1, b and bt are n x n by construction so spmm agrees)
    // add shift on the diagonal (pattern may lack some diagonal entries)
    let mut coo2 = Coo::with_capacity(n, n, a.nnz() + n);
    for r in 0..n {
        let (cols, vals) = a.row(r);
        for (c, v) in cols.iter().zip(vals) {
            coo2.push(r, *c, *v);
        }
    }
    for i in 0..n {
        coo2.push(i, i, shift);
    }
    a = coo2.to_csr();
    a
}

/// Random diagonally-dominant nonsymmetric matrix (BiCGStab / LU tests).
pub fn random_nonsymmetric(rng: &mut Prng, n: usize, per_row: usize) -> Csr {
    let mut coo = Coo::with_capacity(n, n, n * (per_row + 1));
    for r in 0..n {
        let mut off = 0.0;
        for c in rng.choose_distinct(n, per_row) {
            if c == r {
                continue;
            }
            let v = rng.normal();
            off += v.abs();
            coo.push(r, c, v);
        }
        coo.push(r, r, off + 1.0 + rng.uniform());
    }
    coo.to_csr()
}

/// Convert a CSR matrix to ELL slots (cols, vals) padded to `s` per row.
/// Returns None if some row exceeds `s` nonzeros.
pub fn to_ell(m: &Csr, s: usize) -> Option<(Vec<i32>, Vec<f64>)> {
    let n = m.nrows;
    let mut cols = vec![0i32; n * s];
    let mut vals = vec![0f64; n * s];
    for r in 0..n {
        let (ci, vi) = m.row(r);
        if ci.len() > s {
            return None;
        }
        for (k, (c, v)) in ci.iter().zip(vi).enumerate() {
            cols[r * s + k] = *c as i32;
            vals[r * s + k] = *v;
        }
    }
    Some((cols, vals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{self};

    #[test]
    fn laplacian_rows_sum_to_shift() {
        let mut rng = Prng::new(1);
        let l = random_graph_laplacian(&mut rng, 50, 4, 0.1);
        for r in 0..50 {
            let (_, vals) = l.row(r);
            let s: f64 = vals.iter().sum();
            assert!((s - 0.1).abs() < 1e-10, "row {r} sums to {s}");
        }
        assert!(l.looks_spd());
    }

    #[test]
    fn random_spd_is_spd() {
        let mut rng = Prng::new(2);
        let a = random_spd(&mut rng, 30, 3, 0.5);
        assert!(a.looks_spd());
        let x = rng.normal_vec(30);
        let ax = a.matvec(&x);
        assert!(util::dot(&x, &ax) > 0.0);
    }

    #[test]
    fn nonsymmetric_is_diagonally_dominant() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 40, 5);
        for r in 0..40 {
            let (cols, vals) = a.row(r);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                if *c == r {
                    diag = *v;
                } else {
                    off += v.abs();
                }
            }
            assert!(diag > off, "row {r}: {diag} <= {off}");
        }
    }

    #[test]
    fn ell_roundtrip_spmv() {
        let mut rng = Prng::new(4);
        let a = random_graph_laplacian(&mut rng, 20, 3, 0.2);
        let s = (0..20).map(|r| a.row(r).0.len()).max().unwrap();
        let (cols, vals) = to_ell(&a, s).unwrap();
        let x = rng.normal_vec(20);
        let mut y_ell = vec![0.0; 20];
        for r in 0..20 {
            for k in 0..s {
                y_ell[r] += vals[r * s + k] * x[cols[r * s + k] as usize];
            }
        }
        let y = a.matvec(&x);
        assert!(util::max_abs_diff(&y, &y_ell) < 1e-12);
    }

    #[test]
    fn ell_overflow_returns_none() {
        let mut rng = Prng::new(5);
        let a = random_graph_laplacian(&mut rng, 20, 6, 0.1);
        assert!(to_ell(&a, 1).is_none());
    }
}
