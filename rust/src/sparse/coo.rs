//! COO (coordinate) format — the assembly-side representation.

use crate::error::{Error, Result};

/// Coordinate-format sparse matrix.  Duplicate (row, col) entries are
/// legal and **sum** on conversion to CSR (matching `torch.sparse` /
//  scipy assembly semantics).
#[derive(Clone, Debug)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
    pub vals: Vec<f64>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        Coo {
            nrows,
            ncols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            vals: Vec::with_capacity(cap),
        }
    }

    /// Build from parallel triplet arrays.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        rows: Vec<usize>,
        cols: Vec<usize>,
        vals: Vec<f64>,
    ) -> Result<Self> {
        if rows.len() != cols.len() || rows.len() != vals.len() {
            return Err(Error::InvalidProblem(format!(
                "triplet length mismatch: rows {} cols {} vals {}",
                rows.len(),
                cols.len(),
                vals.len()
            )));
        }
        if let Some(&r) = rows.iter().max() {
            if r >= nrows {
                return Err(Error::InvalidProblem(format!("row {r} >= nrows {nrows}")));
            }
        }
        if let Some(&c) = cols.iter().max() {
            if c >= ncols {
                return Err(Error::InvalidProblem(format!("col {c} >= ncols {ncols}")));
            }
        }
        Ok(Coo {
            nrows,
            ncols,
            rows,
            cols,
            vals,
        })
    }

    #[inline]
    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.nrows && c < self.ncols);
        self.rows.push(r);
        self.cols.push(c);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Convert to CSR, summing duplicates; drops explicit zeros created by
    /// cancellation only if `drop_zeros`.
    pub fn to_csr(&self) -> super::Csr {
        // counting sort by row
        let mut counts = vec![0usize; self.nrows + 1];
        for &r in &self.rows {
            counts[r + 1] += 1;
        }
        for i in 0..self.nrows {
            counts[i + 1] += counts[i];
        }
        let mut order_cols = vec![0usize; self.nnz()];
        let mut order_vals = vec![0f64; self.nnz()];
        let mut next = counts.clone();
        for k in 0..self.nnz() {
            let r = self.rows[k];
            let slot = next[r];
            next[r] += 1;
            order_cols[slot] = self.cols[k];
            order_vals[slot] = self.vals[k];
        }
        // sort within each row by column, then merge duplicates
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        indptr.push(0);
        let mut rowbuf: Vec<(usize, f64)> = Vec::new();
        for r in 0..self.nrows {
            rowbuf.clear();
            for k in counts[r]..counts[r + 1] {
                rowbuf.push((order_cols[k], order_vals[k]));
            }
            rowbuf.sort_unstable_by_key(|e| e.0);
            let mut i = 0;
            while i < rowbuf.len() {
                let c = rowbuf[i].0;
                let mut v = rowbuf[i].1;
                let mut j = i + 1;
                while j < rowbuf.len() && rowbuf[j].0 == c {
                    v += rowbuf[j].1;
                    j += 1;
                }
                indices.push(c);
                vals.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        super::Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            vals,
        }
        .debug_validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicates_sum_in_csr() {
        let mut a = Coo::new(2, 2);
        a.push(0, 1, 2.0);
        a.push(0, 1, 3.0);
        a.push(1, 0, 1.0);
        let c = a.to_csr();
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.get(0, 1), 5.0);
        assert_eq!(c.get(1, 0), 1.0);
    }

    #[test]
    fn rows_sorted_by_column() {
        let mut a = Coo::new(1, 5);
        a.push(0, 4, 4.0);
        a.push(0, 0, 1.0);
        a.push(0, 2, 2.0);
        let c = a.to_csr();
        assert_eq!(c.indices, vec![0, 2, 4]);
        assert_eq!(c.vals, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn from_triplets_validates() {
        assert!(Coo::from_triplets(2, 2, vec![0], vec![0, 1], vec![1.0]).is_err());
        assert!(Coo::from_triplets(2, 2, vec![5], vec![0], vec![1.0]).is_err());
        assert!(Coo::from_triplets(2, 2, vec![1], vec![1], vec![1.0]).is_ok());
    }

    #[test]
    fn empty_matrix() {
        let a = Coo::new(3, 3);
        let c = a.to_csr();
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.indptr, vec![0, 0, 0, 0]);
    }
}
