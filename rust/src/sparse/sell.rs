//! SELL-C-σ — the vectorization-friendly sliced-ELLPACK format.
//!
//! Rows are grouped into chunks of height `C`; within each chunk every
//! row is padded to the chunk's widest row and entries are stored
//! column-major (`chunk_ptr[c] + j * C + lane`), so an SpMV processes
//! `C` rows in lock-step with unit-stride loads over `vals`/`indices` —
//! the layout CPUs vectorize and GPUs coalesce.  To keep the padding
//! small on irregular matrices, rows are pre-sorted by descending
//! length within windows of `σ` consecutive rows (a *local* sort, so
//! locality of the original ordering survives); `σ = 1` is the unsorted
//! degenerate case (classic ELLPACK when `C` spans all rows, see
//! [`Sell::ell`]).
//!
//! Value/index arrays live in 64-byte [`AlignedVec`] storage
//! (`docs/kernels.md#alignment-contract`).  Padding entries are
//! `(val = 0.0, index = 0)`: the gather they feed contributes `+0.0`
//! per padded slot, so per-row results match the CSR kernel exactly up
//! to `-0.0`/non-finite edge cases (covered by the parity property
//! tests in `tests/sell_parity.rs`, which pin 1-ulp-scale agreement).
//!
//! Whether a given matrix is worth converting is the cost model's call
//! ([`super::cost`]): SELL wins when occupancy (nnz / padded-nnz) is
//! high, CSR when padding would swamp the bandwidth saving.

use super::align::AlignedVec;
use super::csr::Csr;
use crate::error::{Error, Result};

/// Default chunk height: 8 f64 lanes = one cache line per column step.
pub const DEFAULT_CHUNK: usize = 8;
/// Default sort window: local enough to keep x-gather locality.
pub const DEFAULT_SIGMA: usize = 64;

/// SELL-C-σ sparse matrix with f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Sell {
    pub nrows: usize,
    pub ncols: usize,
    /// Chunk height C (rows processed in lock-step).
    pub chunk: usize,
    /// Sort-window σ (rows length-sorted within windows of σ).
    pub sigma: usize,
    /// `perm[slot]` = original row stored at sorted slot `slot`.
    pub perm: Vec<usize>,
    /// Chunk start offsets into `vals`/`indices`, length nchunks + 1;
    /// chunk `c` occupies `widths[c] * chunk` entries.
    pub chunk_ptr: Vec<usize>,
    /// Width (widest row) per chunk, length nchunks.
    pub widths: Vec<usize>,
    /// True (unpadded) row length per slot, length nrows.
    pub lens: Vec<usize>,
    /// Column indices, column-major per chunk; padding entries are 0.
    pub indices: AlignedVec<usize>,
    /// Values, column-major per chunk; padding entries are 0.0.
    pub vals: AlignedVec<f64>,
}

impl Sell {
    /// Convert from CSR.  `chunk`/`sigma` are clamped to >= 1; pass
    /// [`DEFAULT_CHUNK`]/[`DEFAULT_SIGMA`] unless the cost model says
    /// otherwise.
    pub fn from_csr(a: &Csr, chunk: usize, sigma: usize) -> Sell {
        let chunk = chunk.max(1);
        let sigma = sigma.max(1);
        let n = a.nrows;
        let row_len: Vec<usize> = (0..n).map(|r| a.indptr[r + 1] - a.indptr[r]).collect();
        let mut perm: Vec<usize> = (0..n).collect();
        if sigma > 1 {
            for win in perm.chunks_mut(sigma) {
                // stable sort: ties keep original order, deterministic
                win.sort_by_key(|&r| std::cmp::Reverse(row_len[r]));
            }
        }
        let nchunks = n.div_ceil(chunk);
        let mut widths = vec![0usize; nchunks];
        let mut lens = vec![0usize; n];
        for (slot, &r) in perm.iter().enumerate() {
            lens[slot] = row_len[r];
            let c = slot / chunk;
            widths[c] = widths[c].max(row_len[r]);
        }
        let mut chunk_ptr = vec![0usize; nchunks + 1];
        for c in 0..nchunks {
            chunk_ptr[c + 1] = chunk_ptr[c] + widths[c] * chunk;
        }
        let total = chunk_ptr[nchunks];
        let mut vals: AlignedVec<f64> = AlignedVec::zeroed(total);
        let mut indices: AlignedVec<usize> = AlignedVec::zeroed(total);
        for (slot, &r) in perm.iter().enumerate() {
            let c = slot / chunk;
            let lane = slot - c * chunk;
            let base = chunk_ptr[c];
            let lo = a.indptr[r];
            for j in 0..row_len[r] {
                vals[base + j * chunk + lane] = a.vals[lo + j];
                indices[base + j * chunk + lane] = a.indices[lo + j];
            }
        }
        Sell {
            nrows: n,
            ncols: a.ncols,
            chunk,
            sigma,
            perm,
            chunk_ptr,
            widths,
            lens,
            indices,
            vals,
        }
        .debug_validate()
    }

    /// Classic ELLPACK: one chunk spanning every row, no sorting — the
    /// σ = 1 degenerate case with C = nrows.
    pub fn ell(a: &Csr) -> Sell {
        Sell::from_csr(a, a.nrows.max(1), 1)
    }

    pub fn nchunks(&self) -> usize {
        self.widths.len()
    }

    /// Stored (unpadded) entry count.
    pub fn nnz(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Allocated entry count including padding.
    pub fn padded_nnz(&self) -> usize {
        self.chunk_ptr.last().copied().unwrap_or(0)
    }

    /// nnz / padded-nnz in [0, 1]; 1.0 for an empty matrix.
    pub fn occupancy(&self) -> f64 {
        let padded = self.padded_nnz();
        if padded == 0 {
            1.0
        } else {
            self.nnz() as f64 / padded as f64
        }
    }

    /// Structural invariants of the SELL-C-σ format, first violation
    /// reported — the [`Csr::validate`] counterpart, gated in every
    /// constructor via [`Sell::debug_validate`].
    pub fn validate(&self) -> Result<()> {
        if self.chunk == 0 || self.sigma == 0 {
            return Err(Error::InvalidProblem(
                "sell: chunk and sigma must be >= 1".into(),
            ));
        }
        let nchunks = self.nrows.div_ceil(self.chunk);
        if self.widths.len() != nchunks {
            return Err(Error::InvalidProblem(format!(
                "sell: widths length {} != nchunks {nchunks}",
                self.widths.len()
            )));
        }
        if self.chunk_ptr.len() != nchunks + 1 || self.chunk_ptr.first() != Some(&0) {
            return Err(Error::InvalidProblem(format!(
                "sell: chunk_ptr length {} / start {:?} malformed",
                self.chunk_ptr.len(),
                self.chunk_ptr.first()
            )));
        }
        for c in 0..nchunks {
            if self.chunk_ptr[c + 1] != self.chunk_ptr[c] + self.widths[c] * self.chunk {
                return Err(Error::InvalidProblem(format!(
                    "sell: chunk_ptr step at chunk {c} != widths[{c}] * chunk"
                )));
            }
        }
        let total = self.padded_nnz();
        if self.vals.len() != total || self.indices.len() != total {
            return Err(Error::InvalidProblem(format!(
                "sell: vals/indices lengths {}/{} != padded nnz {total}",
                self.vals.len(),
                self.indices.len()
            )));
        }
        if self.perm.len() != self.nrows || self.lens.len() != self.nrows {
            return Err(Error::InvalidProblem(format!(
                "sell: perm/lens lengths {}/{} != nrows {}",
                self.perm.len(),
                self.lens.len(),
                self.nrows
            )));
        }
        let mut seen = vec![false; self.nrows];
        for &r in &self.perm {
            if r >= self.nrows || seen[r] {
                return Err(Error::InvalidProblem(format!(
                    "sell: perm is not a permutation (row {r})"
                )));
            }
            seen[r] = true;
        }
        for c in 0..nchunks {
            let lo = c * self.chunk;
            let hi = ((c + 1) * self.chunk).min(self.nrows);
            let widest = self.lens[lo..hi].iter().copied().max().unwrap_or(0);
            if self.widths[c] != widest {
                return Err(Error::InvalidProblem(format!(
                    "sell: widths[{c}] = {} != widest row {widest} in chunk",
                    self.widths[c]
                )));
            }
        }
        for (slot, &len) in self.lens.iter().enumerate() {
            let c = slot / self.chunk;
            let lane = slot - c * self.chunk;
            let base = self.chunk_ptr[c];
            let w = self.widths[c];
            if len > w {
                return Err(Error::InvalidProblem(format!(
                    "sell: row at slot {slot} longer ({len}) than its chunk width {w}"
                )));
            }
            let mut prev: Option<usize> = None;
            for j in 0..w {
                let p = base + j * self.chunk + lane;
                let col = self.indices[p];
                if j < len {
                    if col >= self.ncols {
                        return Err(Error::InvalidProblem(format!(
                            "sell: column {col} out of range at slot {slot} (ncols {})",
                            self.ncols
                        )));
                    }
                    if prev.is_some_and(|q| q >= col) {
                        return Err(Error::InvalidProblem(format!(
                            "sell: columns not strictly increasing at slot {slot}"
                        )));
                    }
                    prev = Some(col);
                } else if col != 0 || self.vals[p] != 0.0 {
                    return Err(Error::InvalidProblem(format!(
                        "sell: padding at slot {slot} pos {j} is not (0, 0.0)"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Debug-build invariant gate used by every constructor (mirrors
    /// [`Csr::debug_validate`]).
    #[inline]
    pub fn debug_validate(self) -> Self {
        debug_assert!(
            self.validate().is_ok(),
            "invalid SELL from constructor: {:?}",
            self.validate()
        );
        self
    }

    /// y = A x.  Chunk heights 4/8/16 take the lock-step vector path;
    /// anything else the per-slot scalar path (same operation order,
    /// same result).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        match self.chunk {
            4 => self.spmv_chunked::<4>(x, y),
            8 => self.spmv_chunked::<8>(x, y),
            16 => self.spmv_chunked::<16>(x, y),
            _ => self.spmv_generic(x, y),
        }
    }

    /// Lock-step SpMV over `C` lanes: the accumulator is a `[f64; C]`
    /// register file and each column step is one unit-stride load of
    /// `C` values + `C` indices — the auto-vectorizable shape.
    // rsla-lint: no_alloc
    fn spmv_chunked<const C: usize>(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(self.chunk, C);
        for c in 0..self.nchunks() {
            let base = self.chunk_ptr[c];
            let w = self.widths[c];
            let mut acc = [0.0f64; C];
            for j in 0..w {
                let off = base + j * C;
                let vs = &self.vals[off..off + C];
                let is = &self.indices[off..off + C];
                for l in 0..C {
                    acc[l] += vs[l] * x[is[l]];
                }
            }
            let row0 = c * C;
            let live = C.min(self.nrows - row0);
            for l in 0..live {
                y[self.perm[row0 + l]] = acc[l];
            }
        }
    }

    /// Per-slot scalar SpMV (any chunk height).  Walks the same padded
    /// width in the same j-order as the lock-step path, so the two are
    /// bitwise interchangeable.
    // rsla-lint: no_alloc
    fn spmv_generic(&self, x: &[f64], y: &mut [f64]) {
        let chunk = self.chunk;
        for (slot, &r) in self.perm.iter().enumerate() {
            let c = slot / chunk;
            let lane = slot - c * chunk;
            let base = self.chunk_ptr[c];
            let w = self.widths[c];
            let mut acc = 0.0;
            for j in 0..w {
                let p = base + j * chunk + lane;
                acc += self.vals[p] * x[self.indices[p]];
            }
            y[r] = acc;
        }
    }

    /// y = A^T x without materializing the transpose (scatter form,
    /// skips zero entries of x like [`Csr::spmv_t`]).
    // rsla-lint: no_alloc
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        y.fill(0.0);
        let chunk = self.chunk;
        for (slot, &r) in self.perm.iter().enumerate() {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            let c = slot / chunk;
            let lane = slot - c * chunk;
            let base = self.chunk_ptr[c];
            for j in 0..self.lens[slot] {
                let p = base + j * chunk + lane;
                y[self.indices[p]] += self.vals[p] * xr;
            }
        }
    }

    /// Multi-RHS SpMV over `k` interleaved columns (layout as in
    /// [`super::kernels::spmv_block`]): one pass over the matrix.
    // rsla-lint: no_alloc
    pub fn spmv_block(&self, x: &[f64], y: &mut [f64], k: usize) {
        debug_assert_eq!(x.len(), self.ncols * k);
        debug_assert_eq!(y.len(), self.nrows * k);
        let chunk = self.chunk;
        for (slot, &r) in self.perm.iter().enumerate() {
            let c = slot / chunk;
            let lane = slot - c * chunk;
            let base = self.chunk_ptr[c];
            let yr = &mut y[r * k..r * k + k];
            yr.fill(0.0);
            for j in 0..self.lens[slot] {
                let p = base + j * chunk + lane;
                let v = self.vals[p];
                let col = self.indices[p];
                let xb = &x[col * k..col * k + k];
                for (yj, &xj) in yr.iter_mut().zip(xb) {
                    *yj += v * xj;
                }
            }
        }
    }

    /// Exact conversion back to CSR (padding dropped, original row
    /// order restored) — the round-trip inverse of [`Sell::from_csr`].
    pub fn to_csr(&self) -> Csr {
        let mut indptr = vec![0usize; self.nrows + 1];
        for (slot, &r) in self.perm.iter().enumerate() {
            indptr[r + 1] = self.lens[slot];
        }
        for i in 0..self.nrows {
            indptr[i + 1] += indptr[i];
        }
        let nnz = indptr[self.nrows];
        let mut indices = vec![0usize; nnz];
        let mut vals = vec![0f64; nnz];
        let chunk = self.chunk;
        for (slot, &r) in self.perm.iter().enumerate() {
            let c = slot / chunk;
            let lane = slot - c * chunk;
            let base = self.chunk_ptr[c];
            let out = indptr[r];
            for j in 0..self.lens[slot] {
                let p = base + j * chunk + lane;
                indices[out + j] = self.indices[p];
                vals[out + j] = self.vals[p];
            }
        }
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            indptr,
            indices,
            vals,
        }
        .debug_validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn poisson(n: usize) -> Csr {
        crate::sparse::poisson::poisson2d(n, None).matrix
    }

    #[test]
    fn round_trips_exactly_for_all_chunk_sigma_combos() {
        let a = poisson(7);
        for chunk in [1usize, 3, 4, 8, 16, 64] {
            for sigma in [1usize, 4, 32] {
                let s = Sell::from_csr(&a, chunk, sigma);
                assert!(s.validate().is_ok(), "chunk={chunk} sigma={sigma}");
                assert_eq!(s.to_csr(), a, "chunk={chunk} sigma={sigma}");
                assert_eq!(s.nnz(), a.nnz());
                assert!(s.occupancy() > 0.0 && s.occupancy() <= 1.0);
            }
        }
    }

    #[test]
    fn spmv_matches_csr_on_every_path() {
        let a = poisson(9);
        let mut rng = Prng::new(3);
        let x = rng.normal_vec(a.ncols);
        let mut yref = vec![0.0; a.nrows];
        a.spmv(&x, &mut yref);
        for chunk in [1usize, 5, 8, 16] {
            let s = Sell::from_csr(&a, chunk, DEFAULT_SIGMA);
            let mut y = vec![1.0; a.nrows];
            s.spmv(&x, &mut y);
            for (yi, ri) in y.iter().zip(&yref) {
                assert!((yi - ri).abs() <= 1e-13 * ri.abs().max(1.0), "chunk={chunk}");
            }
        }
    }

    #[test]
    fn spmv_t_matches_csr() {
        let a = poisson(6);
        let mut rng = Prng::new(4);
        let x = rng.normal_vec(a.nrows);
        let mut yref = vec![0.0; a.ncols];
        a.spmv_t(&x, &mut yref);
        let s = Sell::from_csr(&a, DEFAULT_CHUNK, DEFAULT_SIGMA);
        let mut y = vec![0.0; a.ncols];
        s.spmv_t(&x, &mut y);
        for (yi, ri) in y.iter().zip(&yref) {
            assert!((yi - ri).abs() <= 1e-12 * ri.abs().max(1.0));
        }
    }

    #[test]
    fn ell_is_single_chunk_unsorted() {
        let a = poisson(5);
        let e = Sell::ell(&a);
        assert_eq!(e.nchunks(), 1);
        assert_eq!(e.sigma, 1);
        assert_eq!(e.perm, (0..a.nrows).collect::<Vec<_>>());
        assert_eq!(e.to_csr(), a);
    }

    #[test]
    fn empty_matrix_is_fine() {
        let a = Csr {
            nrows: 0,
            ncols: 0,
            indptr: vec![0],
            indices: vec![],
            vals: vec![],
        };
        let s = Sell::from_csr(&a, 8, 64);
        assert!(s.validate().is_ok());
        assert_eq!(s.padded_nnz(), 0);
        assert_eq!(s.occupancy(), 1.0);
        assert_eq!(s.to_csr(), a);
    }

    #[test]
    fn validate_catches_corruption() {
        let a = poisson(4);
        let good = Sell::from_csr(&a, 4, 16);

        let mut bad = good.clone();
        bad.perm[0] = bad.perm[1];
        assert!(bad.validate().is_err(), "duplicate perm entry");

        let mut bad = good.clone();
        if let Some(w) = bad.widths.first_mut() {
            *w += 1;
        }
        assert!(bad.validate().is_err(), "width != widest row");

        let mut bad = good.clone();
        // corrupt a padding slot (first chunk has ragged rows)
        let w = bad.widths[0];
        let lane = (0..bad.chunk.min(bad.nrows))
            .find(|&l| bad.lens[l] < w)
            .expect("poisson chunk has a padded lane");
        let p = bad.chunk_ptr[0] + (w - 1) * bad.chunk + lane;
        bad.vals[p] = 1.0;
        assert!(bad.validate().is_err(), "nonzero padding value");

        let mut bad = good.clone();
        bad.chunk_ptr[1] += bad.chunk;
        assert!(bad.validate().is_err(), "chunk_ptr step mismatch");
    }

    #[test]
    fn sigma_sorting_reduces_padding_on_skewed_rows() {
        // one dense row among short ones: with sigma covering the
        // window the dense row lands in one chunk instead of widening
        // its neighbors'.
        let n = 64usize;
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for r in 0..n {
            let cols: Vec<usize> = if r == 37 {
                (0..n).collect()
            } else {
                vec![r]
            };
            for &c in &cols {
                indices.push(c);
                vals.push(1.0 + c as f64);
            }
            indptr.push(indices.len());
        }
        let a = Csr {
            nrows: n,
            ncols: n,
            indptr,
            indices,
            vals,
        }
        .debug_validate();
        let unsorted = Sell::from_csr(&a, 8, 1);
        let sorted = Sell::from_csr(&a, 8, n);
        assert!(sorted.padded_nnz() <= unsorted.padded_nnz());
        assert!(sorted.occupancy() >= unsorted.occupancy());
        assert_eq!(sorted.to_csr(), a);
        assert_eq!(unsorted.to_csr(), a);
    }
}
