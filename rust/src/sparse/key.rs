//! Pattern/value fingerprints — the cache and batching keys.
//!
//! [`PatternKey`] started life inside the coordinator's batcher; it is
//! promoted here because the factor cache ([`crate::factor_cache`])
//! keys on the same fingerprint.  Two tiers:
//!
//! * [`StructureKey`] — pattern only (indptr/indices).  Matching means
//!   a symbolic factorization (ordering, elimination structure, fill
//!   allocation) can be reused and only the numeric phase re-runs.
//! * [`PatternKey`] — pattern + values.  Matching means the full
//!   numeric factorization can be reused.
//!
//! Keys are cheap 64-bit fingerprints.  Collisions only cost a missed
//! reuse opportunity / an extra equality comparison, never a wrong
//! answer: every consumer (the batcher's worker path, the factor
//! cache) re-checks full equality before acting on a key match.

use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};

use super::Csr;

/// Process-wide count of [`PatternKey::of`] executions.  Each call is a
/// full O(nnz) pass over the matrix, so the engine is expected to hash
/// every linear job exactly once (in the scheduler) and thread the key
/// to the worker's cache shard — `tests/hash_count.rs` pins that
/// contract against this counter.
static PATTERN_HASHES: AtomicU64 = AtomicU64::new(0);

/// Monotone snapshot of how many times [`PatternKey::of`] has run in
/// this process.
pub fn pattern_hash_count() -> u64 {
    PATTERN_HASHES.load(Ordering::Relaxed)
}

/// Cheap structural fingerprint of a sparsity pattern + values.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PatternKey {
    pub nrows: usize,
    pub nnz: usize,
    pub structure_hash: u64,
    pub values_hash: u64,
}

/// Pattern-only fingerprint (the symbolic-reuse tier).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct StructureKey {
    pub nrows: usize,
    pub nnz: usize,
    pub structure_hash: u64,
}

impl PatternKey {
    pub fn of(m: &Csr) -> Self {
        PATTERN_HASHES.fetch_add(1, Ordering::Relaxed);
        let mut h = std::collections::hash_map::DefaultHasher::new();
        m.indptr.hash(&mut h);
        m.indices.hash(&mut h);
        let structure_hash = h.finish();
        let mut hv = std::collections::hash_map::DefaultHasher::new();
        for v in &m.vals {
            v.to_bits().hash(&mut hv);
        }
        PatternKey {
            nrows: m.nrows,
            nnz: m.nnz(),
            structure_hash,
            values_hash: hv.finish(),
        }
    }

    /// The pattern-only projection of this key.
    pub fn structure(&self) -> StructureKey {
        StructureKey {
            nrows: self.nrows,
            nnz: self.nnz,
            structure_hash: self.structure_hash,
        }
    }
}

impl StructureKey {
    /// Pattern-only fingerprint: hashes indptr/indices and never
    /// touches the values (callers on hot pre-checks use this, so it
    /// must not pay the O(nnz) value hash `PatternKey::of` does).
    pub fn of(m: &Csr) -> Self {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        m.indptr.hash(&mut h);
        m.indices.hash(&mut h);
        StructureKey {
            nrows: m.nrows,
            nnz: m.nnz(),
            structure_hash: h.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn same_matrix_same_key() {
        let a = poisson2d(6, None).matrix;
        let b = poisson2d(6, None).matrix;
        assert_eq!(PatternKey::of(&a), PatternKey::of(&b));
        assert_eq!(StructureKey::of(&a), StructureKey::of(&b));
    }

    #[test]
    fn different_values_different_key_same_structure() {
        let a = poisson2d(6, None).matrix;
        let mut b = a.clone();
        b.vals[0] += 1.0;
        let (ka, kb) = (PatternKey::of(&a), PatternKey::of(&b));
        assert_eq!(ka.structure_hash, kb.structure_hash);
        assert_ne!(ka.values_hash, kb.values_hash);
        assert_eq!(ka.structure(), kb.structure());
    }

    #[test]
    fn different_patterns_different_structure() {
        let a = poisson2d(4, None).matrix;
        let b = poisson2d(5, None).matrix;
        assert_ne!(StructureKey::of(&a), StructureKey::of(&b));
    }
}
