//! Fused vector and multi-vector kernels for the Krylov hot path.
//!
//! Two kernel families live here:
//!
//! 1. **Fused reductions with a pinned schedule.**  [`dot2`], [`dot3`]
//!    and [`sub_scaled_norm2sq`] combine what the solvers previously
//!    did as separate passes (two/three `util::dot` calls; an axpy-style
//!    update followed by `dot(out, out)`) into ONE pass over the
//!    operands — but each logical reduction keeps `util::dot`'s exact
//!    4-accumulator schedule, so the results are **bitwise identical**
//!    to the unfused code.  That property is what lets
//!    CG/pipelined-CG/BiCGStab adopt them without perturbing the FP
//!    pins in `tests/krylov_equivalence.rs` and the frozen-reference
//!    trajectory tests.  Do not "optimize" the accumulation order here;
//!    widen only the un-pinned paths (see [`dot_wide`]).
//!
//! 2. **Multi-vector SpMV.**  [`spmv_block`] applies a CSR matrix to
//!    `k` interleaved right-hand sides in one matrix pass (one read of
//!    `vals`/`indices` instead of `k`), the kernel behind
//!    `LinearOperator::apply_block`, LOBPCG block applies, and the
//!    engine's multi-RHS fused residuals.  Per column it accumulates in
//!    the same order as the scalar `Csr::spmv`, so column `j` of the
//!    result is bitwise identical to a scalar pass on column `j`.
//!
//! [`dot_wide`] is the runtime-dispatched 8-lane reduction for paths
//! with no bitwise pin (SELL-C-σ kernels, benches): AVX2-compiled when
//! the CPU has it, `util::dot` otherwise.  See `docs/kernels.md`.

use super::csr::Csr;
use crate::util::dot;

/// Two dot products fused into one pass: `[dot(x0, y0), dot(x1, y1)]`.
///
/// Bitwise identical to two separate [`crate::util::dot`] calls: each
/// pair gets its own 4-accumulator set and the per-pair operation
/// order is exactly `dot`'s.  All four slices must share one length.
// rsla-lint: no_alloc
pub fn dot2(x0: &[f64], y0: &[f64], x1: &[f64], y1: &[f64]) -> [f64; 2] {
    let n = x0.len();
    debug_assert_eq!(y0.len(), n);
    debug_assert_eq!(x1.len(), n);
    debug_assert_eq!(y1.len(), n);
    let mut a0 = [0.0f64; 4];
    let mut a1 = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        a0[0] += x0[b] * y0[b];
        a0[1] += x0[b + 1] * y0[b + 1];
        a0[2] += x0[b + 2] * y0[b + 2];
        a0[3] += x0[b + 3] * y0[b + 3];
        a1[0] += x1[b] * y1[b];
        a1[1] += x1[b + 1] * y1[b + 1];
        a1[2] += x1[b + 2] * y1[b + 2];
        a1[3] += x1[b + 3] * y1[b + 3];
    }
    let mut s0 = a0[0] + a0[1] + a0[2] + a0[3];
    let mut s1 = a1[0] + a1[1] + a1[2] + a1[3];
    for i in chunks * 4..n {
        s0 += x0[i] * y0[i];
        s1 += x1[i] * y1[i];
    }
    [s0, s1]
}

/// Three dot products fused into one pass (the pipelined-CG triple).
///
/// Bitwise identical to three separate [`crate::util::dot`] calls; see
/// [`dot2`] for the schedule contract.
// rsla-lint: no_alloc
pub fn dot3(
    x0: &[f64],
    y0: &[f64],
    x1: &[f64],
    y1: &[f64],
    x2: &[f64],
    y2: &[f64],
) -> [f64; 3] {
    let n = x0.len();
    debug_assert_eq!(y0.len(), n);
    debug_assert_eq!(x1.len(), n);
    debug_assert_eq!(y1.len(), n);
    debug_assert_eq!(x2.len(), n);
    debug_assert_eq!(y2.len(), n);
    let mut a0 = [0.0f64; 4];
    let mut a1 = [0.0f64; 4];
    let mut a2 = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        a0[0] += x0[b] * y0[b];
        a0[1] += x0[b + 1] * y0[b + 1];
        a0[2] += x0[b + 2] * y0[b + 2];
        a0[3] += x0[b + 3] * y0[b + 3];
        a1[0] += x1[b] * y1[b];
        a1[1] += x1[b + 1] * y1[b + 1];
        a1[2] += x1[b + 2] * y1[b + 2];
        a1[3] += x1[b + 3] * y1[b + 3];
        a2[0] += x2[b] * y2[b];
        a2[1] += x2[b + 1] * y2[b + 1];
        a2[2] += x2[b + 2] * y2[b + 2];
        a2[3] += x2[b + 3] * y2[b + 3];
    }
    let mut s0 = a0[0] + a0[1] + a0[2] + a0[3];
    let mut s1 = a1[0] + a1[1] + a1[2] + a1[3];
    let mut s2 = a2[0] + a2[1] + a2[2] + a2[3];
    for i in chunks * 4..n {
        s0 += x0[i] * y0[i];
        s1 += x1[i] * y1[i];
        s2 += x2[i] * y2[i];
    }
    [s0, s1, s2]
}

/// Fused update + norm: `out = x - alpha * y`, returning
/// `dot(out, out)` — the BiCGStab `s = r - alpha v` / `r = s - omega t`
/// step and its residual reduction in ONE pass instead of a write loop
/// followed by a re-read.
///
/// Bitwise identical to the unfused two-step: the update is computed
/// elementwise first (same expression as the scalar loop) and the
/// squares accumulate in [`crate::util::dot`]'s schedule over the
/// freshly written values.
// rsla-lint: no_alloc
pub fn sub_scaled_norm2sq(x: &[f64], alpha: f64, y: &[f64], out: &mut [f64]) -> f64 {
    let n = x.len();
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(out.len(), n);
    let mut acc = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        out[b] = x[b] - alpha * y[b];
        out[b + 1] = x[b + 1] - alpha * y[b + 1];
        out[b + 2] = x[b + 2] - alpha * y[b + 2];
        out[b + 3] = x[b + 3] - alpha * y[b + 3];
        acc[0] += out[b] * out[b];
        acc[1] += out[b + 1] * out[b + 1];
        acc[2] += out[b + 2] * out[b + 2];
        acc[3] += out[b + 3] * out[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..n {
        out[i] = x[i] - alpha * y[i];
        s += out[i] * out[i];
    }
    s
}

/// 8-accumulator dot body.  Not schedule-compatible with `util::dot`
/// (different reduction tree) — for un-pinned paths only.
// rsla-lint: no_alloc
#[inline(always)]
fn dot8(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; 8];
    let chunks = x.len() / 8;
    for i in 0..chunks {
        let b = i * 8;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
        acc[4] += x[b + 4] * y[b + 4];
        acc[5] += x[b + 5] * y[b + 5];
        acc[6] += x[b + 6] * y[b + 6];
        acc[7] += x[b + 7] * y[b + 7];
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// The same 8-lane body compiled with AVX2 enabled, so 256-bit vector
/// loads/adds are emitted even when the crate's baseline target does
/// not assume AVX2.  Callers must have verified the feature at runtime.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_avx2(x: &[f64], y: &[f64]) -> f64 {
    dot8(x, y)
}

#[cfg(target_arch = "x86_64")]
pub(crate) fn avx2_available() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn avx2_available() -> bool {
    false
}

/// Runtime-dispatched wide dot product: 8 unrolled accumulator lanes
/// compiled for AVX2 when the CPU supports it (detected once per
/// process), `util::dot`'s 4-lane loop otherwise.
///
/// NOT bitwise compatible with [`crate::util::dot`] on the wide path —
/// use it only where no FP-schedule pin applies (SELL kernels, benches,
/// cost probes), never inside the pinned solver recurrences or
/// `gdot`/`gnorm`.
pub fn dot_wide(x: &[f64], y: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: gated on runtime AVX2 detection above.
        return unsafe { dot8_avx2(x, y) };
    }
    dot(x, y)
}

/// Panel dot for the supernodal factorization kernels: [`dot8`]'s
/// fixed 8-lane schedule, `#[inline(always)]` so callers compiled
/// under `target_feature(avx2)` (the supernodal numeric bodies) get
/// 256-bit lanes without per-call dispatch.  The schedule depends only
/// on the operand length — deterministic for the refactor-vs-cold
/// bitwise pin.
// rsla-lint: no_alloc
#[inline(always)]
pub fn panel_dot(x: &[f64], y: &[f64]) -> f64 {
    dot8(x, y)
}

/// Two dots sharing the `x` operand in one pass (the supernodal rank-k
/// update walks one descendant row against two target rows so the
/// shared operand is loaded once).  4 accumulator lanes per output —
/// 8 live accumulators total, which still fits the AVX2 register file.
///
/// NOT schedule-compatible with [`panel_dot`]; the supernodal kernels
/// pick dot-vs-dot2 purely from index parity, so every (target, source)
/// pair always runs one fixed schedule.
// rsla-lint: no_alloc
#[inline(always)]
pub fn panel_dot2(x: &[f64], ya: &[f64], yb: &[f64]) -> (f64, f64) {
    let n = x.len();
    debug_assert_eq!(ya.len(), n);
    debug_assert_eq!(yb.len(), n);
    let mut aa = [0.0f64; 4];
    let mut ab = [0.0f64; 4];
    let chunks = n / 4;
    for i in 0..chunks {
        let b = i * 4;
        aa[0] += x[b] * ya[b];
        aa[1] += x[b + 1] * ya[b + 1];
        aa[2] += x[b + 2] * ya[b + 2];
        aa[3] += x[b + 3] * ya[b + 3];
        ab[0] += x[b] * yb[b];
        ab[1] += x[b + 1] * yb[b + 1];
        ab[2] += x[b + 2] * yb[b + 2];
        ab[3] += x[b + 3] * yb[b + 3];
    }
    let mut sa = (aa[0] + aa[1]) + (aa[2] + aa[3]);
    let mut sb = (ab[0] + ab[1]) + (ab[2] + ab[3]);
    for i in chunks * 4..n {
        sa += x[i] * ya[i];
        sb += x[i] * yb[i];
    }
    (sa, sb)
}

/// Panel axpy `dst -= alpha * src` — the blocked LU rank-1 row update.
/// Plain elementwise loop; under the AVX2-compiled caller bodies it
/// vectorizes to fused 256-bit lanes.
// rsla-lint: no_alloc
#[inline(always)]
pub fn panel_sub_scaled(dst: &mut [f64], alpha: f64, src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d -= alpha * s;
    }
}

/// Multi-RHS SpMV: `Y = A X` for `k` interleaved columns, ONE pass over
/// the matrix.  `x[i * k + j]` is row `i` of column `j`; `x` has length
/// `ncols * k`, `y` length `nrows * k`.
///
/// Per column the accumulation order is exactly [`Csr::spmv`]'s
/// (entries in row order), so column `j` of `y` is bitwise identical to
/// a scalar `spmv` on column `j` — the property the engine's
/// fused-equals-per-request pin relies on.
pub fn spmv_block(a: &Csr, x: &[f64], y: &mut [f64], k: usize) {
    debug_assert_eq!(x.len(), a.ncols * k);
    debug_assert_eq!(y.len(), a.nrows * k);
    match k {
        1 => a.spmv(x, y),
        2 => spmv_block_fixed::<2>(a, x, y),
        4 => spmv_block_fixed::<4>(a, x, y),
        8 => spmv_block_fixed::<8>(a, x, y),
        _ => spmv_block_any(a, x, y, k),
    }
}

/// Fixed-width block SpMV: the column accumulator is a `[f64; K]`
/// register file, so the inner `K`-loop fully unrolls and vectorizes.
// rsla-lint: no_alloc
fn spmv_block_fixed<const K: usize>(a: &Csr, x: &[f64], y: &mut [f64]) {
    for r in 0..a.nrows {
        let lo = a.indptr[r];
        let hi = a.indptr[r + 1];
        let mut acc = [0.0f64; K];
        for p in lo..hi {
            let v = a.vals[p];
            let xb = &x[a.indices[p] * K..a.indices[p] * K + K];
            for (aj, &xj) in acc.iter_mut().zip(xb) {
                *aj += v * xj;
            }
        }
        y[r * K..r * K + K].copy_from_slice(&acc);
    }
}

/// Arbitrary-width block SpMV, accumulating directly into `y` (no
/// scratch, same per-column operation order as the fixed path).
// rsla-lint: no_alloc
fn spmv_block_any(a: &Csr, x: &[f64], y: &mut [f64], k: usize) {
    for r in 0..a.nrows {
        let lo = a.indptr[r];
        let hi = a.indptr[r + 1];
        let yr = &mut y[r * k..r * k + k];
        yr.fill(0.0);
        for p in lo..hi {
            let v = a.vals[p];
            let xb = &x[a.indices[p] * k..a.indices[p] * k + k];
            for (yj, &xj) in yr.iter_mut().zip(xb) {
                *yj += v * xj;
            }
        }
    }
}

// ---------------------------------------------------------------------
// CA-CG block-basis kernels.  Column-major blocks (`s` columns of
// length `n`, column `j` at `v[j*n..(j+1)*n]`).  Every reduction entry
// is a `util::dot` over contiguous columns — the pinned 4-accumulator
// schedule — so the packed Gram construction of `krylov::ca_cg` is
// bitwise identical to per-column `dot` loops (pinned by tests below).

/// Upper triangle of `V^T AV` in row-major packed order
/// (`(0,0),(0,1),..,(0,s-1),(1,1),..`): `out` must have length
/// `s*(s+1)/2`.
// rsla-lint: no_alloc
pub fn gram_upper(v: &[f64], av: &[f64], n: usize, s: usize, out: &mut [f64]) {
    debug_assert_eq!(v.len(), n * s);
    debug_assert_eq!(av.len(), n * s);
    debug_assert_eq!(out.len(), s * (s + 1) / 2);
    let mut k = 0;
    for i in 0..s {
        for j in i..s {
            out[k] = dot(&v[i * n..(i + 1) * n], &av[j * n..(j + 1) * n]);
            k += 1;
        }
    }
}

/// Full cross-Gram `U^T V` row-major (`out[i*s + j] = <u_i, v_j>`);
/// `out` must have length `s*s`.
// rsla-lint: no_alloc
pub fn gram_cross(u: &[f64], v: &[f64], n: usize, s: usize, out: &mut [f64]) {
    debug_assert_eq!(u.len(), n * s);
    debug_assert_eq!(v.len(), n * s);
    debug_assert_eq!(out.len(), s * s);
    for i in 0..s {
        for j in 0..s {
            out[i * s + j] = dot(&u[i * n..(i + 1) * n], &v[j * n..(j + 1) * n]);
        }
    }
}

/// Block projection `out[j] = <v_j, r>` for each column of `v`.
// rsla-lint: no_alloc
pub fn block_dot_vec(v: &[f64], n: usize, s: usize, r: &[f64], out: &mut [f64]) {
    debug_assert_eq!(v.len(), n * s);
    debug_assert_eq!(r.len(), n);
    debug_assert_eq!(out.len(), s);
    for j in 0..s {
        out[j] = dot(&v[j * n..(j + 1) * n], r);
    }
}

/// Block combine `out = v + pprev * bmat` (column-major blocks, `bmat`
/// row-major `s x s`): `out[:,j] = v[:,j] + sum_k bmat[k*s+j] *
/// pprev[:,k]`.  Streams each `pprev` column once; the accumulation
/// order over `k` is fixed (ascending), part of the deterministic
/// CA-CG schedule.
// rsla-lint: no_alloc
pub fn block_combine(v: &[f64], pprev: &[f64], bmat: &[f64], n: usize, s: usize, out: &mut [f64]) {
    debug_assert_eq!(v.len(), n * s);
    debug_assert_eq!(pprev.len(), n * s);
    debug_assert_eq!(bmat.len(), s * s);
    debug_assert_eq!(out.len(), n * s);
    out.copy_from_slice(v);
    for j in 0..s {
        let oj = &mut out[j * n..(j + 1) * n];
        for k in 0..s {
            let c = bmat[k * s + j];
            let pk = &pprev[k * n..(k + 1) * n];
            for (o, &p) in oj.iter_mut().zip(pk) {
                *o += c * p;
            }
        }
    }
}

/// Fused block iterate update: `x += P a`, `r -= AP a` in one pass per
/// column pair.  The column order (ascending `j`) is part of the
/// deterministic CA-CG schedule.
// rsla-lint: no_alloc
pub fn block_update_xr(
    p: &[f64],
    ap: &[f64],
    n: usize,
    s: usize,
    coef: &[f64],
    x: &mut [f64],
    r: &mut [f64],
) {
    debug_assert_eq!(p.len(), n * s);
    debug_assert_eq!(ap.len(), n * s);
    debug_assert_eq!(coef.len(), s);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(r.len(), n);
    for j in 0..s {
        let c = coef[j];
        let pj = &p[j * n..(j + 1) * n];
        let apj = &ap[j * n..(j + 1) * n];
        for i in 0..n {
            x[i] += c * pj[i];
            r[i] -= c * apj[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{norm2, Prng};

    fn bits(x: f64) -> u64 {
        x.to_bits()
    }

    #[test]
    fn dot2_is_bitwise_two_dots() {
        let mut rng = Prng::new(11);
        for n in [0usize, 1, 3, 4, 7, 64, 1003] {
            let x0 = rng.normal_vec(n);
            let y0 = rng.normal_vec(n);
            let x1 = rng.normal_vec(n);
            let y1 = rng.normal_vec(n);
            let f = dot2(&x0, &y0, &x1, &y1);
            assert_eq!(bits(f[0]), bits(dot(&x0, &y0)), "n={n}");
            assert_eq!(bits(f[1]), bits(dot(&x1, &y1)), "n={n}");
        }
    }

    #[test]
    fn dot3_is_bitwise_three_dots() {
        let mut rng = Prng::new(12);
        for n in [0usize, 2, 5, 8, 130, 1001] {
            let v: Vec<Vec<f64>> = (0..6).map(|_| rng.normal_vec(n)).collect();
            let f = dot3(&v[0], &v[1], &v[2], &v[3], &v[4], &v[5]);
            assert_eq!(bits(f[0]), bits(dot(&v[0], &v[1])), "n={n}");
            assert_eq!(bits(f[1]), bits(dot(&v[2], &v[3])), "n={n}");
            assert_eq!(bits(f[2]), bits(dot(&v[4], &v[5])), "n={n}");
        }
    }

    #[test]
    fn sub_scaled_norm2sq_is_bitwise_update_then_dot() {
        let mut rng = Prng::new(13);
        for n in [0usize, 1, 4, 6, 17, 512, 999] {
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let alpha = rng.normal();
            let mut fused = vec![0.0; n];
            let ss = sub_scaled_norm2sq(&x, alpha, &y, &mut fused);
            let unfused: Vec<f64> = x.iter().zip(&y).map(|(xi, yi)| xi - alpha * yi).collect();
            assert_eq!(fused, unfused, "n={n}");
            assert_eq!(bits(ss), bits(dot(&unfused, &unfused)), "n={n}");
        }
    }

    #[test]
    fn dot_wide_matches_dot_numerically() {
        let mut rng = Prng::new(14);
        for n in [0usize, 5, 8, 9, 64, 1003, 4096] {
            let x = rng.normal_vec(n);
            let y = rng.normal_vec(n);
            let exact = dot(&x, &y);
            let wide = dot_wide(&x, &y);
            let scale = norm2(&x) * norm2(&y) + 1.0;
            assert!(
                (wide - exact).abs() <= 1e-12 * scale,
                "n={n}: wide {wide} vs dot {exact}"
            );
        }
    }

    #[test]
    fn spmv_block_columns_are_bitwise_scalar_spmv() {
        let mut rng = Prng::new(15);
        let sys = crate::sparse::poisson::poisson2d(9, None);
        let a = &sys.matrix;
        for k in [1usize, 2, 3, 4, 5, 8] {
            let cols: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(a.ncols)).collect();
            let mut x = vec![0.0; a.ncols * k];
            for (j, c) in cols.iter().enumerate() {
                for i in 0..a.ncols {
                    x[i * k + j] = c[i];
                }
            }
            let mut y = vec![0.0; a.nrows * k];
            spmv_block(a, &x, &mut y, k);
            for (j, c) in cols.iter().enumerate() {
                let mut yref = vec![0.0; a.nrows];
                a.spmv(c, &mut yref);
                for i in 0..a.nrows {
                    assert_eq!(
                        bits(y[i * k + j]),
                        bits(yref[i]),
                        "k={k} col={j} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn gram_kernels_are_bitwise_per_column_dots() {
        let mut rng = Prng::new(16);
        for (n, s) in [(7usize, 2usize), (64, 4), (129, 8)] {
            let v = rng.normal_vec(n * s);
            let av = rng.normal_vec(n * s);
            let r = rng.normal_vec(n);
            let mut up = vec![0.0; s * (s + 1) / 2];
            gram_upper(&v, &av, n, s, &mut up);
            let mut k = 0;
            for i in 0..s {
                for j in i..s {
                    let want = dot(&v[i * n..(i + 1) * n], &av[j * n..(j + 1) * n]);
                    assert_eq!(bits(up[k]), bits(want), "upper ({i},{j})");
                    k += 1;
                }
            }
            let mut cross = vec![0.0; s * s];
            gram_cross(&av, &v, n, s, &mut cross);
            for i in 0..s {
                for j in 0..s {
                    let want = dot(&av[i * n..(i + 1) * n], &v[j * n..(j + 1) * n]);
                    assert_eq!(bits(cross[i * s + j]), bits(want), "cross ({i},{j})");
                }
            }
            let mut proj = vec![0.0; s];
            block_dot_vec(&v, n, s, &r, &mut proj);
            for (j, &p) in proj.iter().enumerate() {
                assert_eq!(bits(p), bits(dot(&v[j * n..(j + 1) * n], &r)), "proj {j}");
            }
        }
    }

    #[test]
    fn block_combine_and_update_match_naive_loops() {
        let mut rng = Prng::new(17);
        let (n, s) = (53usize, 4usize);
        let v = rng.normal_vec(n * s);
        let pprev = rng.normal_vec(n * s);
        let bmat = rng.normal_vec(s * s);
        let coef = rng.normal_vec(s);
        let mut out = vec![0.0; n * s];
        block_combine(&v, &pprev, &bmat, n, s, &mut out);
        for j in 0..s {
            for i in 0..n {
                let mut want = v[j * n + i];
                for k in 0..s {
                    want += bmat[k * s + j] * pprev[k * n + i];
                }
                assert_eq!(bits(out[j * n + i]), bits(want), "combine ({i},{j})");
            }
        }
        let mut x = rng.normal_vec(n);
        let mut r = rng.normal_vec(n);
        let (x0, r0) = (x.clone(), r.clone());
        block_update_xr(&v, &pprev, n, s, &coef, &mut x, &mut r);
        for i in 0..n {
            let (mut xw, mut rw) = (x0[i], r0[i]);
            for j in 0..s {
                xw += coef[j] * v[j * n + i];
                rw -= coef[j] * pprev[j * n + i];
            }
            assert_eq!(bits(x[i]), bits(xw), "x {i}");
            assert_eq!(bits(r[i]), bits(rw), "r {i}");
        }
    }
}
