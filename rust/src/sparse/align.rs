//! 64-byte-aligned storage for the vectorized kernel layer.
//!
//! Every hot-path array in the SpMV/axpy/dot layer — matrix value and
//! index arrays ([`super::sell::Sell`]) and solver work vectors
//! (`metrics::mem::TrackedBuf`) — lives in an [`AlignedVec`]: a typed
//! view over a `Vec` of 64-byte [`Align64`] blocks.  64 bytes is one
//! cache line and one AVX-512 register, so kernels never straddle a
//! line on their first lane and the compiler's vector loads start
//! aligned regardless of allocator behavior.
//!
//! The idiom (an `align(64)` newtype over a byte block, reinterpreted
//! as the element type) follows neural-reversi's `Align64` buffers;
//! see `docs/kernels.md#alignment-contract` for the guarantees kernels
//! may assume.

use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};

/// One cache line / AVX-512 lane group: 64 bytes at 64-byte alignment.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
pub struct Align64(pub [u8; 64]);

impl Align64 {
    pub const ZERO: Align64 = Align64([0u8; 64]);
}

mod sealed {
    /// Element types an [`super::AlignedVec`] may hold: `Copy` types
    /// whose size divides 64 and for which the all-zero bit pattern is
    /// a valid value (so `zeroed` is sound).
    pub trait Sealed: Copy + 'static {}
    impl Sealed for f64 {}
    impl Sealed for usize {}
}

/// Plain-old-data marker, sealed to `f64` and `usize` — the only two
/// element types the kernel layer stores.
pub trait Pod: sealed::Sealed {}
impl Pod for f64 {}
impl Pod for usize {}

/// A growable-by-construction, 64-byte-aligned buffer of `T`.
///
/// Unlike `Vec<T>` (whose allocation is only `align_of::<T>()`-aligned,
/// 8 bytes for `f64`), the backing store here is a `Vec<Align64>`, so
/// `as_slice().as_ptr()` is always 64-byte aligned.  The buffer is
/// fixed-length after construction ([`AlignedVec::zeroed`] /
/// [`AlignedVec::from_slice`]); mutation happens through the `[T]`
/// deref, which is all the kernels need.
#[derive(Clone)]
pub struct AlignedVec<T: Pod> {
    blocks: Vec<Align64>,
    len: usize,
    _marker: PhantomData<T>,
}

impl<T: Pod> AlignedVec<T> {
    const fn per_block() -> usize {
        64 / std::mem::size_of::<T>()
    }

    /// An all-zero buffer of `len` elements (zero bytes are a valid
    /// `T` for every `Pod` type — that is what the seal guarantees).
    pub fn zeroed(len: usize) -> Self {
        let blocks = vec![Align64::ZERO; len.div_ceil(Self::per_block())];
        AlignedVec {
            blocks,
            len,
            _marker: PhantomData,
        }
    }

    /// Copy of `s` in aligned storage.
    pub fn from_slice(s: &[T]) -> Self {
        let mut v = Self::zeroed(s.len());
        v.as_mut_slice().copy_from_slice(s);
        v
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        // SAFETY: the backing Vec<Align64> holds at least
        // len.div_ceil(per_block()) * 64 bytes >= len * size_of::<T>(),
        // at alignment 64 >= align_of::<T>(); T is sealed Pod, so every
        // byte pattern in the store is a valid T.  An empty Vec's
        // dangling pointer is aligned to align_of::<Align64>() = 64,
        // which satisfies from_raw_parts for len == 0.
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const T, self.len) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as for `as_slice`; &mut self gives unique access.
        unsafe { std::slice::from_raw_parts_mut(self.blocks.as_mut_ptr() as *mut T, self.len) }
    }
}

impl<T: Pod> Default for AlignedVec<T> {
    fn default() -> Self {
        AlignedVec {
            blocks: Vec::new(),
            len: 0,
            _marker: PhantomData,
        }
    }
}

impl<T: Pod> Deref for AlignedVec<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> DerefMut for AlignedVec<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Pod + PartialEq> PartialEq for AlignedVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_is_zero_and_64_byte_aligned() {
        let v: AlignedVec<f64> = AlignedVec::zeroed(1003);
        assert_eq!(v.len(), 1003);
        assert!(v.iter().all(|&x| x == 0.0));
        assert_eq!(v.as_slice().as_ptr() as usize % 64, 0);

        let w: AlignedVec<usize> = AlignedVec::zeroed(7);
        assert_eq!(w.len(), 7);
        assert!(w.iter().all(|&x| x == 0));
        assert_eq!(w.as_slice().as_ptr() as usize % 64, 0);
    }

    #[test]
    fn from_slice_round_trips_and_compares() {
        let src: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let v = AlignedVec::from_slice(&src);
        assert_eq!(v.as_slice(), &src[..]);
        assert_eq!(v, AlignedVec::from_slice(&src));
        let u = v.clone();
        assert_eq!(u, v);
        assert_eq!(u.as_slice().as_ptr() as usize % 64, 0);
    }

    #[test]
    fn deref_mut_writes_through() {
        let mut v: AlignedVec<f64> = AlignedVec::zeroed(9);
        v[4] = 2.5;
        v[8] = -1.0;
        assert_eq!(v[4], 2.5);
        assert_eq!(v.iter().sum::<f64>(), 1.5);
        v.fill(3.0);
        assert!(v.iter().all(|&x| x == 3.0));
    }

    #[test]
    fn empty_and_take_via_default() {
        let mut v = AlignedVec::from_slice(&[1.0f64, 2.0]);
        let taken = std::mem::take(&mut v);
        assert_eq!(taken.as_slice(), &[1.0, 2.0]);
        assert!(v.is_empty());
        assert_eq!(v.as_slice(), &[] as &[f64]);
    }

    #[test]
    fn odd_lengths_do_not_bleed_between_blocks() {
        // 9 f64s span two Align64 blocks; writes at the seam stay put.
        let mut v: AlignedVec<f64> = AlignedVec::zeroed(9);
        v[7] = 7.0;
        v[8] = 8.0;
        assert_eq!(&v[6..], &[0.0, 7.0, 8.0]);
    }
}
