//! Roofline-style cost model: pick CSR or SELL-C-σ per matrix from its
//! row-length statistics, before any conversion work is spent.
//!
//! SpMV is bandwidth-bound on every matrix this crate serves, so the
//! model compares *bytes moved per multiply* instead of FLOPs (the
//! roofline's memory-side axis; the Python prototype in
//! `python/compile/kernels/roofline.py` does the same for the Pallas
//! kernels).  Per stored entry both formats stream 16 bytes
//! (`f64` value + index); they differ in overhead:
//!
//! * **CSR** pays a per-ROW cost — the `indptr` reads plus the short-row
//!   loop startup/drain that stalls the pipeline.  We charge it
//!   [`ROW_OVERHEAD`] entry-equivalents per row, so its effective
//!   traffic is `nnz * 16 * (1 + ROW_OVERHEAD / mean_row_len)`.
//! * **SELL-C-σ** pays a per-PADDING cost — every padded slot streams
//!   16 dead bytes: `padded_nnz * 16 = nnz * 16 / occupancy`.
//!
//! SELL wins iff `1 / occ < 1 + ROW_OVERHEAD / mean`, i.e. iff
//!
//! ```text
//!     occupancy > mean / (mean + ROW_OVERHEAD)
//! ```
//!
//! — high-occupancy matrices (regular stencils, bounded-degree graphs)
//! convert; long-tailed ones (power-law graphs, a few dense rows that
//! survive even the σ-window sort) stay CSR, where padding would swamp
//! the per-row saving.  Occupancy is computed by an exact dry run over
//! the row lengths (the σ-window sort on lengths only — no entry
//! movement), so the decision sees exactly the padding the conversion
//! would create.  Thresholds and the derivation are documented in
//! `docs/kernels.md#cost-model`.
//!
//! Every decision is recorded in the [`Registry`]
//! (`spmv.format.csr` / `spmv.format.sell`), so production output
//! (`rsla solve`, `serve-sim`) can report the chosen format per
//! pattern, not just the benches.

use super::csr::Csr;
use super::kernels;
use super::sell::{Sell, DEFAULT_CHUNK, DEFAULT_SIGMA};
use crate::krylov::LinearOperator;
use crate::metrics::{names, Registry};

/// Per-row overhead CSR is charged, in stored-entry equivalents: the
/// `indptr` access plus loop startup/drain.  Calibrated against the
/// `spmv_roofline` bench (short-row matrices sit near the break-even
/// this predicts); see `docs/kernels.md#cost-model` before changing.
pub const ROW_OVERHEAD: f64 = 4.0;

/// Row-length statistics of a CSR matrix, the cost model's input.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RowStats {
    pub nrows: usize,
    pub nnz: usize,
    pub min_len: usize,
    pub max_len: usize,
    /// Mean row length (0.0 for an empty matrix).
    pub mean: f64,
    /// Coefficient of variation of row length (stddev / mean).
    pub cv: f64,
}

/// One pass over `indptr`.
pub fn row_stats(a: &Csr) -> RowStats {
    let nrows = a.nrows;
    let nnz = a.nnz();
    let mut min_len = usize::MAX;
    let mut max_len = 0usize;
    let mut sum_sq = 0.0f64;
    for w in a.indptr.windows(2) {
        let len = w[1] - w[0];
        min_len = min_len.min(len);
        max_len = max_len.max(len);
        sum_sq += (len * len) as f64;
    }
    if nrows == 0 {
        min_len = 0;
    }
    let mean = if nrows == 0 {
        0.0
    } else {
        nnz as f64 / nrows as f64
    };
    let var = if nrows == 0 {
        0.0
    } else {
        (sum_sq / nrows as f64 - mean * mean).max(0.0)
    };
    let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
    RowStats {
        nrows,
        nnz,
        min_len,
        max_len,
        mean,
        cv,
    }
}

/// Exact SELL-C-σ occupancy (nnz / padded-nnz) the conversion would
/// produce, from row lengths alone: the σ-window sort runs on lengths,
/// widths accumulate per chunk, no entries move.
pub fn sell_occupancy(a: &Csr, chunk: usize, sigma: usize) -> f64 {
    let chunk = chunk.max(1);
    let sigma = sigma.max(1);
    let mut lens: Vec<usize> = a.indptr.windows(2).map(|w| w[1] - w[0]).collect();
    if sigma > 1 {
        for win in lens.chunks_mut(sigma) {
            win.sort_unstable_by(|x, y| y.cmp(x));
        }
    }
    let mut padded = 0usize;
    for chunk_rows in lens.chunks(chunk) {
        let width = chunk_rows.iter().copied().max().unwrap_or(0);
        padded += width * chunk;
    }
    if padded == 0 {
        1.0
    } else {
        a.nnz() as f64 / padded as f64
    }
}

/// The format the cost model picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FormatChoice {
    Csr,
    Sell,
}

impl FormatChoice {
    pub fn name(self) -> &'static str {
        match self {
            FormatChoice::Csr => "csr",
            FormatChoice::Sell => "sell",
        }
    }
}

/// The cost model's decision plus the numbers behind it, for
/// observability (benches print it; `TunedOp` exposes it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostReport {
    pub choice: FormatChoice,
    pub stats: RowStats,
    /// Dry-run SELL occupancy at the default (chunk, σ).
    pub occupancy: f64,
    /// `mean / (mean + ROW_OVERHEAD)`: SELL wins above this occupancy.
    pub threshold: f64,
    /// Effective bytes per SpMV the model charged each format.
    pub csr_bytes: f64,
    pub sell_bytes: f64,
}

/// Run the cost model at the default (chunk, σ).  Pure decision — no
/// conversion, no metrics; [`TunedOp::new`] is the recording wrapper.
pub fn choose_format(a: &Csr) -> CostReport {
    let stats = row_stats(a);
    let occupancy = sell_occupancy(a, DEFAULT_CHUNK, DEFAULT_SIGMA);
    let threshold = if stats.mean > 0.0 {
        stats.mean / (stats.mean + ROW_OVERHEAD)
    } else {
        1.0
    };
    let entry_bytes = (stats.nnz * 16) as f64;
    let csr_bytes = if stats.mean > 0.0 {
        entry_bytes * (1.0 + ROW_OVERHEAD / stats.mean)
    } else {
        0.0
    };
    let sell_bytes = if occupancy > 0.0 {
        entry_bytes / occupancy
    } else {
        0.0
    };
    let choice = if stats.nnz > 0 && occupancy > threshold {
        FormatChoice::Sell
    } else {
        FormatChoice::Csr
    };
    CostReport {
        choice,
        stats,
        occupancy,
        threshold,
        csr_bytes,
        sell_bytes,
    }
}

/// A CSR matrix behind the cost model: applies through SELL-C-σ when
/// the model says the conversion pays for itself, plain CSR otherwise.
/// Construction records the decision in the [`Registry`]
/// (`spmv.format.*`), making the per-matrix choice observable in
/// production output.
pub struct TunedOp<'a> {
    csr: &'a Csr,
    sell: Option<Sell>,
    pub report: CostReport,
}

impl<'a> TunedOp<'a> {
    pub fn new(a: &'a Csr, reg: Option<&Registry>) -> TunedOp<'a> {
        let report = choose_format(a);
        let sell = match report.choice {
            FormatChoice::Sell => Some(Sell::from_csr(a, DEFAULT_CHUNK, DEFAULT_SIGMA)),
            FormatChoice::Csr => None,
        };
        if let Some(reg) = reg {
            match report.choice {
                FormatChoice::Csr => reg.incr(names::SPMV_FORMAT_CSR, 1),
                FormatChoice::Sell => reg.incr(names::SPMV_FORMAT_SELL, 1),
            }
        }
        TunedOp { csr: a, sell, report }
    }

    /// Extra resident bytes the tuned form holds beyond the CSR it
    /// wraps (the SELL copy), for memory accounting.
    pub fn extra_bytes(&self) -> u64 {
        match &self.sell {
            Some(s) => (s.padded_nnz() * 16 + (s.nrows + s.nchunks() * 2) * 8) as u64,
            None => 0,
        }
    }

    pub fn format(&self) -> FormatChoice {
        self.report.choice
    }
}

impl LinearOperator for TunedOp<'_> {
    fn n_own(&self) -> usize {
        self.csr.nrows
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        match &self.sell {
            Some(s) => s.spmv(x_ext, y_own),
            None => self.csr.spmv(x_ext, y_own),
        }
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        match &self.sell {
            Some(s) => s.spmv_t(gy_own, gx_own),
            None => self.csr.spmv_t(gy_own, gx_own),
        }
    }

    fn apply_block(&self, x_own: &[f64], y_own: &mut [f64], k: usize) {
        match &self.sell {
            Some(s) => s.spmv_block(x_own, y_own, k),
            None => kernels::spmv_block(self.csr, x_own, y_own, k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn banded(n: usize, per_row: usize) -> Csr {
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for r in 0..n {
            for d in 0..per_row {
                let c = (r + d) % n;
                indices.push(c);
                vals.push(1.0 + d as f64);
            }
            let lo = indptr[r];
            indices[lo..].sort_unstable();
            indptr.push(indices.len());
        }
        Csr {
            nrows: n,
            ncols: n,
            indptr,
            indices,
            vals,
        }
        .debug_validate()
    }

    fn power_law(rng: &mut Prng, n: usize) -> Csr {
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for r in 0..n {
            // a few hubs with ~n/2 entries, most rows with 1-2
            let len = if r % 97 == 0 { n / 2 } else { 1 + r % 2 };
            let mut cols = rng.choose_distinct(n, len.min(n));
            cols.sort_unstable();
            for c in cols {
                indices.push(c);
                vals.push(rng.normal());
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: n,
            ncols: n,
            indptr,
            indices,
            vals,
        }
        .debug_validate()
    }

    #[test]
    fn regular_matrices_pick_sell_skewed_pick_csr() {
        let reg = Registry::default();
        let uniform = banded(512, 5);
        let t = TunedOp::new(&uniform, Some(&reg));
        assert_eq!(t.format(), FormatChoice::Sell, "{:?}", t.report);
        assert!(t.extra_bytes() > 0);

        let mut rng = Prng::new(8);
        let skewed = power_law(&mut rng, 400);
        let t2 = TunedOp::new(&skewed, Some(&reg));
        assert_eq!(t2.format(), FormatChoice::Csr, "{:?}", t2.report);
        assert_eq!(t2.extra_bytes(), 0);

        assert_eq!(reg.get(names::SPMV_FORMAT_SELL), 1);
        assert_eq!(reg.get(names::SPMV_FORMAT_CSR), 1);
    }

    #[test]
    fn poisson_picks_sell_and_occupancy_matches_conversion() {
        let a = crate::sparse::poisson::poisson2d(16, None).matrix;
        let report = choose_format(&a);
        assert_eq!(report.choice, FormatChoice::Sell, "{report:?}");
        let s = Sell::from_csr(&a, DEFAULT_CHUNK, DEFAULT_SIGMA);
        assert!((report.occupancy - s.occupancy()).abs() < 1e-12);
        assert!(report.sell_bytes < report.csr_bytes);
    }

    #[test]
    fn tuned_op_applies_like_csr_whatever_it_picked() {
        let mut rng = Prng::new(9);
        for a in [banded(300, 7), power_law(&mut rng, 301)] {
            let t = TunedOp::new(&a, None);
            let x = rng.normal_vec(a.ncols);
            let mut x_ext = x.clone();
            let mut y = vec![0.0; a.nrows];
            t.apply(&mut x_ext, &mut y);
            let yref = a.matvec(&x);
            for (yi, ri) in y.iter().zip(&yref) {
                assert!((yi - ri).abs() <= 1e-12 * ri.abs().max(1.0));
            }
            let mut gx = vec![0.0; a.ncols];
            t.apply_adjoint(&x, &mut gx);
            let mut gref = vec![0.0; a.ncols];
            a.spmv_t(&x, &mut gref);
            for (gi, ri) in gx.iter().zip(&gref) {
                assert!((gi - ri).abs() <= 1e-12 * ri.abs().max(1.0));
            }
        }
    }

    #[test]
    fn empty_matrix_stays_csr() {
        let a = Csr {
            nrows: 0,
            ncols: 0,
            indptr: vec![0],
            indices: vec![],
            vals: vec![],
        };
        assert_eq!(choose_format(&a).choice, FormatChoice::Csr);
        let stats = row_stats(&a);
        assert_eq!(stats.mean, 0.0);
        assert_eq!(stats.min_len, 0);
    }
}
