//! L3 coordinator — now a thin compatibility shim over the solve
//! [`crate::engine`].
//!
//! Historically this module owned the windowed batcher and the linear
//! worker pool.  Both grew into the engine (`rust/src/engine/`), which
//! serves EVERY solver family (linear, multi-RHS, nonlinear, eigen,
//! adjoint, distributed) with pattern-affinity scheduling, priority +
//! deadline queues, and admission control.  What remains here:
//!
//! * [`batcher`] — re-exports of the fusion policy from
//!   [`crate::engine::fuse`];
//! * [`service`] — [`SolveService`], the original linear-only API,
//!   implemented as a shim that submits [`crate::engine::JobSpec::Linear`]
//!   jobs and converts replies.  Its semantics (windowed same-pattern
//!   batching, factorize-once, per-request latency metrics) are
//!   preserved and its tests run unchanged.

pub mod batcher;
pub mod service;

pub use batcher::{BatchPolicy, PatternKey};
pub use service::{ServiceConfig, ServiceStats, SolveRequest, SolveResponse, SolveService};
