//! L3 coordinator: a solve *service* in the vLLM-router mold.
//!
//! torch-sla is a library, but its batched/auto-dispatch semantics are
//! exactly a serving problem: requests (solves) arrive, get grouped by
//! sparsity pattern (shared-pattern batches amortize one symbolic
//! factorization — paper §3.1), routed to a backend by the dispatch
//! policy, and executed on a worker pool.  This module is that runtime:
//!
//! * [`batcher`] — windowed intake that coalesces same-pattern,
//!   same-values requests into multi-RHS batches;
//! * [`service`] — worker pool + queue + per-request latency metrics.

pub mod batcher;
pub mod service;

pub use batcher::{BatchPolicy, PatternKey};
pub use service::{ServiceConfig, ServiceStats, SolveRequest, SolveResponse, SolveService};
