//! [`SolveService`]: the original linear solve-service API, kept
//! API-compatible as a thin shim over the [`crate::engine`].
//!
//! `submit` wraps the request in a [`JobSpec::Linear`] and converts the
//! engine's [`JobResult`] back into a [`SolveResponse`] through the
//! engine's callback-reply path (no forwarding thread per request).
//! Windowed same-pattern batching, the factorize-once multi-RHS path,
//! the hash-collision re-check, and the per-request latency fields all
//! live in the engine now; this file only adapts types.

use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

use super::batcher::BatchPolicy;
use crate::backend::{Dispatcher, SolveOpts, SolveOutcome};
use crate::engine::{Engine, EngineConfig, JobOutput, JobResult, JobSpec, SubmitOpts};
use crate::error::{Error, Result};
use crate::metrics;
use crate::sparse::Csr;

/// One solve request.
pub struct SolveRequest {
    pub id: u64,
    pub matrix: Csr,
    pub b: Vec<f64>,
    pub opts: SolveOpts,
}

/// The reply, with queueing/service latency for the metrics tables.
pub struct SolveResponse {
    pub id: u64,
    pub outcome: Result<SolveOutcome>,
    pub queue_seconds: f64,
    pub service_seconds: f64,
    /// How many requests shared the batch that served this one.
    pub batch_size: usize,
}

#[derive(Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
        }
    }
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
}

pub struct SolveService {
    engine: Engine,
    pub metrics: Arc<metrics::Registry>,
}

impl SolveService {
    pub fn start(dispatcher: Arc<Dispatcher>, config: ServiceConfig) -> Self {
        let engine = Engine::start(
            dispatcher,
            EngineConfig {
                workers: config.workers,
                fuse: config.batch,
                // the legacy service had no admission control and
                // default affinity routing
                ..Default::default()
            },
        );
        let metrics = engine.metrics.clone();
        SolveService { engine, metrics }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, matrix: Csr, b: Vec<f64>, opts: SolveOpts) -> Receiver<SolveResponse> {
        let (reply_tx, reply_rx) = channel::<SolveResponse>();
        let submit_err_tx = reply_tx.clone();
        let convert = Box::new(move |r: JobResult| {
            let JobResult {
                id,
                outcome,
                queue_seconds,
                service_seconds,
                batch_size,
                ..
            } = r;
            let outcome = outcome.and_then(|out| match out {
                JobOutput::Linear(o) => Ok(o),
                _ => Err(Error::WorkerPanic(
                    "linear job produced a non-linear output".into(),
                )),
            });
            let _ = reply_tx.send(SolveResponse {
                id,
                outcome,
                queue_seconds,
                service_seconds,
                batch_size,
            });
        });
        if let Err(e) = self.engine.submit_with_reply(
            JobSpec::Linear { matrix, b, opts },
            SubmitOpts::default(),
            convert,
        ) {
            // a stopped or saturated engine becomes an error reply on
            // the same channel, not a panic in the submitting thread
            let _ = submit_err_tx.send(SolveResponse {
                id: 0,
                outcome: Err(e),
                queue_seconds: 0.0,
                service_seconds: 0.0,
                batch_size: 1,
            });
        }
        reply_rx
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            completed: self.metrics.get(metrics::names::SERVICE_COMPLETED),
            batches: self.metrics.get(metrics::names::SERVICE_BATCHES),
            batched_requests: self.metrics.get(metrics::names::SERVICE_BATCHED_REQUESTS),
        }
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(&self) {
        self.engine.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn serves_single_request() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(64);
        let rx = svc.submit(sys.matrix.clone(), b.clone(), SolveOpts::default());
        let resp = rx.recv().unwrap();
        let out = resp.outcome.unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
        svc.shutdown();
    }

    #[test]
    fn batches_same_pattern_requests() {
        let svc = SolveService::start(
            Arc::new(Dispatcher::new(None)),
            ServiceConfig {
                workers: 1,
                batch: BatchPolicy {
                    max_batch: 16,
                    window: std::time::Duration::from_millis(50),
                },
            },
        );
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(1);
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for _ in 0..6 {
            let b = rng.normal_vec(64);
            rxs.push(svc.submit(sys.matrix.clone(), b.clone(), SolveOpts::default()));
            bs.push(b);
        }
        let mut batched = 0;
        for (rx, b) in rxs.into_iter().zip(&bs) {
            let resp = rx.recv().unwrap();
            let out = resp.outcome.unwrap();
            assert!(util::rel_l2(&sys.matrix.matvec(&out.x), b) < 1e-8);
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        assert!(batched >= 2, "expected some batching, got {batched}");
        let stats = svc.stats();
        assert_eq!(stats.completed, 6);
        svc.shutdown();
    }

    #[test]
    fn mixed_patterns_still_all_served() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let mut rng = Prng::new(2);
        let mut work = Vec::new();
        for i in 0..5 {
            let a = random_spd(&mut rng, 20 + i * 7, 3, 1.0);
            let b = rng.normal_vec(a.nrows);
            work.push((a.clone(), b.clone(), svc.submit(a, b, SolveOpts::default())));
        }
        for (a, b, rx) in work {
            let out = rx.recv().unwrap().outcome.unwrap();
            assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-7);
        }
        svc.shutdown();
    }

    #[test]
    fn bad_request_gets_error_not_hang() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let sys = poisson2d(6, None);
        let rx = svc.submit(sys.matrix.clone(), vec![1.0; 7], SolveOpts::default());
        let resp = rx.recv().unwrap();
        assert!(resp.outcome.is_err());
        svc.shutdown();
    }
}
