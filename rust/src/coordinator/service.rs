//! The solve service: intake thread (windowed batcher) + worker pool +
//! metrics.  Requests are routed by the [`Dispatcher`] policy; batches
//! of identical (pattern, values) matrices run factorize-once.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::batcher::{group_by_key, verify_groups, BatchPolicy, PatternKey};
use crate::backend::{Dispatcher, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::error::{Error, Result};
use crate::factor_cache::FactorCache;
use crate::metrics;
use crate::sparse::Csr;

/// One solve request.
pub struct SolveRequest {
    pub id: u64,
    pub matrix: Csr,
    pub b: Vec<f64>,
    pub opts: SolveOpts,
}

/// The reply, with queueing/service latency for the metrics tables.
pub struct SolveResponse {
    pub id: u64,
    pub outcome: Result<SolveOutcome>,
    pub queue_seconds: f64,
    pub service_seconds: f64,
    /// How many requests shared the batch that served this one.
    pub batch_size: usize,
}

#[derive(Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
        }
    }
}

struct Envelope {
    req: SolveRequest,
    key: PatternKey,
    enqueued: Instant,
    reply: Sender<SolveResponse>,
}

/// Aggregate statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
}

pub struct SolveService {
    intake_tx: Option<Sender<Envelope>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<metrics::Registry>,
    next_id: std::sync::atomic::AtomicU64,
}

impl SolveService {
    pub fn start(dispatcher: Arc<Dispatcher>, config: ServiceConfig) -> Self {
        let metrics = Arc::new(metrics::Registry::new());
        let (intake_tx, intake_rx) = channel::<Envelope>();
        let (work_tx, work_rx) = channel::<Vec<Envelope>>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let mut threads = Vec::new();

        // intake thread: windowed batching by pattern key
        {
            let policy = config.batch.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("rsla-intake".into())
                    .spawn(move || {
                        intake_loop(intake_rx, work_tx, policy, metrics);
                    })
                    .unwrap(),
            );
        }
        // worker pool
        for w in 0..config.workers.max(1) {
            let rx = work_rx.clone();
            let disp = dispatcher.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rsla-worker-{w}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = rx.lock().unwrap();
                            match guard.recv() {
                                Ok(b) => b,
                                Err(_) => break,
                            }
                        };
                        serve_batch(batch, &disp, &metrics);
                    })
                    .unwrap(),
            );
        }

        SolveService {
            intake_tx: Some(intake_tx),
            threads,
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, matrix: Csr, b: Vec<f64>, opts: SolveOpts) -> Receiver<SolveResponse> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply_tx, reply_rx) = channel();
        let key = PatternKey::of(&matrix);
        let env = Envelope {
            req: SolveRequest {
                id,
                matrix,
                b,
                opts,
            },
            key,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        self.intake_tx
            .as_ref()
            .expect("service stopped")
            .send(env)
            .expect("intake thread gone");
        reply_rx
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            completed: self.metrics.get("service.completed"),
            batches: self.metrics.get("service.batches"),
            batched_requests: self.metrics.get("service.batched_requests"),
        }
    }

    /// Graceful shutdown: drain queues, join threads.
    pub fn shutdown(mut self) {
        drop(self.intake_tx.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn intake_loop(
    rx: Receiver<Envelope>,
    work_tx: Sender<Vec<Envelope>>,
    policy: BatchPolicy,
    metrics: Arc<metrics::Registry>,
) {
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(e) => e,
            Err(_) => break,
        };
        let mut window: Vec<Envelope> = vec![first];
        let deadline = Instant::now() + policy.window;
        while window.len() < policy.max_batch * 4 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(e) => window.push(e),
                Err(_) => break,
            }
        }
        // group by key and dispatch groups to workers
        let keys: Vec<PatternKey> = window.iter().map(|e| e.key.clone()).collect();
        let groups = group_by_key(&keys, policy.max_batch);
        metrics.incr("service.batches", groups.len() as u64);
        // pull envelopes out by index, preserving group structure
        let mut slots: Vec<Option<Envelope>> = window.into_iter().map(Some).collect();
        for group in groups {
            let batch: Vec<Envelope> = group
                .into_iter()
                .map(|i| slots[i].take().unwrap())
                .collect();
            metrics.incr("service.batched_requests", batch.len() as u64);
            if work_tx.send(batch).is_err() {
                return;
            }
        }
    }
}

fn serve_batch(batch: Vec<Envelope>, disp: &Dispatcher, metrics: &Arc<metrics::Registry>) {
    let t0 = Instant::now();
    // Soundness re-check (PatternKey's contract): the intake groups by
    // 64-bit fingerprints, so before factorizing once for the whole
    // group we verify the matrices are actually equal and split out any
    // mismatches into their own uniform sub-batches.
    let uniform = {
        let mats: Vec<&Csr> = batch.iter().map(|e| &e.req.matrix).collect();
        verify_groups(&mats)
    };
    if uniform.len() > 1 {
        metrics.incr("service.key_collisions", (uniform.len() - 1) as u64);
    }
    let mut slots: Vec<Option<Envelope>> = batch.into_iter().map(Some).collect();
    for group in uniform {
        let sub: Vec<Envelope> = group.into_iter().map(|i| slots[i].take().unwrap()).collect();
        serve_uniform_batch(sub, t0, disp, metrics);
    }
}

/// Serve a batch whose matrices are verified identical: factorize once
/// through the pattern-keyed cache (which also reuses factors across
/// batches and windows), fall back to per-request dispatch when the
/// matrix cannot be factored (singular, over budget, rhs mismatch).
fn serve_uniform_batch(
    batch: Vec<Envelope>,
    t0: Instant,
    disp: &Dispatcher,
    metrics: &Arc<metrics::Registry>,
) {
    let n = batch.len();
    // Factorize-once applies when a direct solve is the right call:
    // every request runs the fully-auto policy (explicit backend /
    // method overrides must reach the dispatcher that honors them),
    // and the matrix is SPD-looking (the seed's gate — Cholesky
    // scales) or small enough that the dispatch policy would pick a
    // direct backend anyway.  Large non-SPD batches fall through to
    // per-request dispatch (iterative), as before.
    let auto_policy = batch
        .iter()
        .all(|e| e.req.opts.backend.is_none() && e.req.opts.method == Method::Auto);
    let direct_ok = auto_policy
        && (batch[0].req.matrix.looks_spd()
            || batch[0].req.matrix.nrows <= crate::backend::dispatch::DIRECT_CROSSOVER_N);
    if n > 1 && direct_ok && batch[0].req.matrix.nrows == batch[0].req.b.len() {
        let a = batch[0].req.matrix.clone();
        // honor the tightest budget in the group
        let budget = batch
            .iter()
            .map(|e| e.req.opts.host_mem_budget)
            .min()
            .unwrap_or(u64::MAX);
        if let Ok(f) = FactorCache::global().factor(&a, budget, Some(metrics)) {
            let bytes = f.bytes();
            let method: &'static str = match f.method() {
                "cholesky+rcm" => "cholesky+rcm(batched)",
                _ => "lu(batched)",
            };
            for env in batch {
                let ts = Instant::now();
                let outcome = f.solve(&env.req.b).map(|x| {
                    let residual = {
                        let ax = a.matvec(&x);
                        env.req
                            .b
                            .iter()
                            .zip(&ax)
                            .map(|(bi, ai)| (bi - ai) * (bi - ai))
                            .sum::<f64>()
                            .sqrt()
                    };
                    SolveOutcome {
                        x,
                        backend: "native-direct",
                        method,
                        iters: 0,
                        residual,
                        peak_bytes: bytes,
                    }
                });
                metrics.incr("service.completed", 1);
                let _ = env.reply.send(SolveResponse {
                    id: env.req.id,
                    outcome,
                    queue_seconds: (t0 - env.enqueued).as_secs_f64(),
                    service_seconds: ts.elapsed().as_secs_f64(),
                    batch_size: n,
                });
            }
            return;
        }
    }
    // per-request dispatch
    for env in batch {
        let ts = Instant::now();
        let outcome = if env.req.matrix.nrows != env.req.b.len() {
            Err(Error::InvalidProblem("rhs length mismatch".into()))
        } else {
            disp.solve(
                &Problem {
                    op: Operator::Csr(&env.req.matrix),
                    b: &env.req.b,
                },
                &env.req.opts,
            )
        };
        metrics.incr("service.completed", 1);
        let _ = env.reply.send(SolveResponse {
            id: env.req.id,
            outcome,
            queue_seconds: (t0 - env.enqueued).as_secs_f64(),
            service_seconds: ts.elapsed().as_secs_f64(),
            batch_size: n,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn serves_single_request() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(64);
        let rx = svc.submit(sys.matrix.clone(), b.clone(), SolveOpts::default());
        let resp = rx.recv().unwrap();
        let out = resp.outcome.unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
        svc.shutdown();
    }

    #[test]
    fn batches_same_pattern_requests() {
        let svc = SolveService::start(
            Arc::new(Dispatcher::new(None)),
            ServiceConfig {
                workers: 1,
                batch: BatchPolicy {
                    max_batch: 16,
                    window: std::time::Duration::from_millis(50),
                },
            },
        );
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(1);
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for _ in 0..6 {
            let b = rng.normal_vec(64);
            rxs.push(svc.submit(sys.matrix.clone(), b.clone(), SolveOpts::default()));
            bs.push(b);
        }
        let mut batched = 0;
        for (rx, b) in rxs.into_iter().zip(&bs) {
            let resp = rx.recv().unwrap();
            let out = resp.outcome.unwrap();
            assert!(util::rel_l2(&sys.matrix.matvec(&out.x), b) < 1e-8);
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        assert!(batched >= 2, "expected some batching, got {batched}");
        let stats = svc.stats();
        assert_eq!(stats.completed, 6);
        svc.shutdown();
    }

    #[test]
    fn mixed_patterns_still_all_served() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let mut rng = Prng::new(2);
        let mut work = Vec::new();
        for i in 0..5 {
            let a = random_spd(&mut rng, 20 + i * 7, 3, 1.0);
            let b = rng.normal_vec(a.nrows);
            work.push((a.clone(), b.clone(), svc.submit(a, b, SolveOpts::default())));
        }
        for (a, b, rx) in work {
            let out = rx.recv().unwrap().outcome.unwrap();
            assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-7);
        }
        svc.shutdown();
    }

    #[test]
    fn bad_request_gets_error_not_hang() {
        let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
        let sys = poisson2d(6, None);
        let rx = svc.submit(sys.matrix.clone(), vec![1.0; 7], SolveOpts::default());
        let resp = rx.recv().unwrap();
        assert!(resp.outcome.is_err());
        svc.shutdown();
    }
}
