//! Compatibility re-exports: the batching policy moved into
//! [`crate::engine::fuse`] when the engine became the one scheduling
//! layer for every solver family.  Existing callers keep importing
//! `coordinator::batcher::{BatchPolicy, PatternKey, group_by_key,
//! verify_groups}` unchanged.

pub use crate::engine::fuse::{group_by_key, verify_groups, BatchPolicy};
pub use crate::sparse::key::PatternKey;
