//! Pattern-keyed batching: requests whose matrices share (pattern,
//! values) coalesce into one factorize-once multi-RHS solve; requests
//! sharing only the pattern still reuse the dispatch decision.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use crate::sparse::Csr;

/// Cheap structural fingerprint of a sparsity pattern + values.
/// Collisions only cost a missed batching opportunity / an extra value
/// comparison, never a wrong answer (the service re-checks equality).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PatternKey {
    pub nrows: usize,
    pub nnz: usize,
    pub structure_hash: u64,
    pub values_hash: u64,
}

impl PatternKey {
    pub fn of(m: &Csr) -> Self {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        m.indptr.hash(&mut h);
        m.indices.hash(&mut h);
        let structure_hash = h.finish();
        let mut hv = std::collections::hash_map::DefaultHasher::new();
        for v in &m.vals {
            v.to_bits().hash(&mut hv);
        }
        PatternKey {
            nrows: m.nrows,
            nnz: m.nnz(),
            structure_hash,
            values_hash: hv.finish(),
        }
    }
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Max requests coalesced into one multi-RHS solve.
    pub max_batch: usize,
    /// Max time the intake thread waits to fill a batch.
    pub window: std::time::Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            window: std::time::Duration::from_millis(2),
        }
    }
}

/// Group indices of requests by pattern+values key, preserving arrival
/// order inside each group.
pub fn group_by_key(keys: &[PatternKey], max_batch: usize) -> Vec<Vec<usize>> {
    let mut groups: HashMap<&PatternKey, Vec<usize>> = HashMap::new();
    let mut order: Vec<&PatternKey> = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        let e = groups.entry(k).or_insert_with(|| {
            order.push(k);
            Vec::new()
        });
        e.push(i);
    }
    let mut out = Vec::new();
    for k in order {
        let idxs = &groups[k];
        for chunk in idxs.chunks(max_batch.max(1)) {
            out.push(chunk.to_vec());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn same_matrix_same_key() {
        let a = poisson2d(6, None).matrix;
        let b = poisson2d(6, None).matrix;
        assert_eq!(PatternKey::of(&a), PatternKey::of(&b));
    }

    #[test]
    fn different_values_different_key() {
        let a = poisson2d(6, None).matrix;
        let mut b = a.clone();
        b.vals[0] += 1.0;
        let (ka, kb) = (PatternKey::of(&a), PatternKey::of(&b));
        assert_eq!(ka.structure_hash, kb.structure_hash);
        assert_ne!(ka.values_hash, kb.values_hash);
    }

    #[test]
    fn grouping_respects_max_batch() {
        let a = poisson2d(4, None).matrix;
        let k = PatternKey::of(&a);
        let keys = vec![k.clone(); 7];
        let groups = group_by_key(&keys, 3);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0], vec![0, 1, 2]);
        assert_eq!(groups[2], vec![6]);
    }

    #[test]
    fn mixed_patterns_stay_separate() {
        let a = PatternKey::of(&poisson2d(4, None).matrix);
        let b = PatternKey::of(&poisson2d(5, None).matrix);
        let keys = vec![a.clone(), b.clone(), a.clone()];
        let groups = group_by_key(&keys, 8);
        assert_eq!(groups, vec![vec![0, 2], vec![1]]);
    }
}
