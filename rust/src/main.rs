//! rsla CLI — leader entrypoint for the coordinator.
//!
//! Subcommands:
//!   backends                     list backends + artifact inventory
//!   explain --n N [--accel]      show the dispatch decision for a size
//!   solve --g G [--backend B]    solve a 2D Poisson system, report stats
//!   serve-sim [--requests N]     run the solve service on a synthetic
//!                                request stream, report throughput
//!   dist --g G --ranks P [--precond jacobi|amg]   distributed CG demo

use std::sync::Arc;

use rsla::backend::{Device, Dispatcher, Operator, Problem, SolveOpts};
use rsla::coordinator::{ServiceConfig, SolveService};
use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::metrics::stopwatch::timed;
use rsla::runtime::RuntimeHandle;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;

/// Minimal flag parser: --key value / --flag.
struct Args {
    cmd: String,
    kv: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

fn parse_args() -> Args {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".into());
    let mut kv = std::collections::HashMap::new();
    let mut flags = std::collections::HashSet::new();
    let rest: Vec<String> = args.collect();
    let mut i = 0;
    while i < rest.len() {
        let a = rest[i].trim_start_matches("--").to_string();
        if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
            kv.insert(a, rest[i + 1].clone());
            i += 2;
        } else {
            flags.insert(a);
            i += 1;
        }
    }
    Args { cmd, kv, flags }
}

impl Args {
    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.kv
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn dispatcher(accel: bool) -> Arc<Dispatcher> {
    if accel {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Arc::new(Dispatcher::new(Some(h))),
            Err(e) => {
                eprintln!("warning: no artifacts ({e}); CPU backends only");
                Arc::new(Dispatcher::new(None))
            }
        }
    } else {
        Arc::new(Dispatcher::new(None))
    }
}

fn main() {
    let args = parse_args();
    match args.cmd.as_str() {
        "backends" => cmd_backends(),
        "explain" => cmd_explain(&args),
        "solve" => cmd_solve(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "dist" => cmd_dist(&args),
        _ => {
            println!(
                "rsla — differentiable sparse linear algebra (torch-sla reproduction)\n\n\
                 usage: rsla <backends|explain|solve|serve-sim|dist> [--key value]\n\
                 \x20 backends                      list backends + artifacts\n\
                 \x20 explain --n N [--accel]       dispatch decision for size N\n\
                 \x20 solve --g G [--backend B] [--accel]\n\
                 \x20 serve-sim [--requests N] [--workers W]\n\
                 \x20 dist --g G --ranks P"
            );
        }
    }
}

fn cmd_backends() {
    let d = dispatcher(true);
    println!("backends (dispatch order depends on device/problem):");
    for name in d.backend_names() {
        println!("  {name}");
    }
    if let Ok(h) = RuntimeHandle::spawn_default() {
        println!("\nAOT artifacts ({}):", h.names().len());
        for n in h.names() {
            println!("  {n}");
        }
    }
}

fn cmd_explain(args: &Args) {
    let n = args.usize_or("n", 10_000);
    let g = (n as f64).sqrt() as usize;
    let accel = args.flags.contains("accel");
    let d = dispatcher(accel);
    let sys = poisson2d(g.max(4), None);
    let b = vec![1.0; sys.matrix.nrows];
    let opts = SolveOpts {
        device: if accel { Device::Accel } else { Device::Cpu },
        ..Default::default()
    };
    let p = Problem {
        op: Operator::Stencil(&sys.coeffs),
        b: &b,
    };
    println!(
        "n={} device={:?} -> backend {:?}",
        sys.matrix.nrows,
        opts.device,
        d.select(&p, &opts)
    );
}

fn cmd_solve(args: &Args) {
    let g = args.usize_or("g", 64);
    let accel = args.flags.contains("accel");
    let d = dispatcher(accel);
    let kappa = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa));
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(g * g);
    let mut opts = SolveOpts {
        device: if accel { Device::Accel } else { Device::Cpu },
        tol: 1e-8,
        ..Default::default()
    };
    if let Some(be) = args.kv.get("backend") {
        opts.backend = Some(be.clone());
    }
    let p = Problem {
        op: Operator::Stencil(&sys.coeffs),
        b: &b,
    };
    let (out, secs) = timed(|| d.solve(&p, &opts));
    match out {
        Ok(out) => println!(
            "g={g} n={} backend={} method={} iters={} residual={:.2e} mem={:.1} MB time={:.1} ms",
            g * g,
            out.backend,
            out.method,
            out.iters,
            out.residual,
            out.peak_bytes as f64 / 1e6,
            secs * 1e3
        ),
        Err(e) => println!("solve failed: {e}"),
    }
}

fn cmd_serve_sim(args: &Args) {
    let requests = args.usize_or("requests", 64);
    let workers = args.usize_or("workers", 4);
    let d = dispatcher(false);
    let svc = SolveService::start(
        d,
        ServiceConfig {
            workers,
            ..Default::default()
        },
    );
    let mut rng = Prng::new(7);
    // mixed stream: 70% shared-pattern Poisson (batchable), 30% random SPD
    let poisson = poisson2d(24, None);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        let (a, b) = if i % 10 < 7 {
            (poisson.matrix.clone(), rng.normal_vec(poisson.matrix.nrows))
        } else {
            let a = rsla::sparse::graphs::random_spd(&mut rng, 100 + (i % 5) * 30, 3, 1.0);
            let b = rng.normal_vec(a.nrows);
            (a, b)
        };
        rxs.push(svc.submit(a, b, SolveOpts::default()));
    }
    let mut lat = Vec::new();
    let mut batched = 0u64;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        resp.outcome.expect("solve failed");
        lat.push(resp.queue_seconds + resp.service_seconds);
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = svc.stats();
    println!(
        "served {requests} solves in {:.1} ms ({:.0} req/s), workers={workers}",
        wall * 1e3,
        requests as f64 / wall
    );
    println!(
        "p50 latency {:.2} ms  p99 {:.2} ms  batched {batched}/{requests}  batches {}",
        lat[lat.len() / 2] * 1e3,
        lat[lat.len() * 99 / 100] * 1e3,
        stats.batches,
    );
    svc.shutdown();
}

fn cmd_dist(args: &Args) {
    let g = args.usize_or("g", 128);
    let ranks = args.usize_or("ranks", 4);
    // --precond jacobi (default, paper parity) | amg (block additive Schwarz)
    let precond = match args.kv.get("precond").map(|s| s.as_str()) {
        Some("amg") => rsla::distributed::DistPrecondKind::BlockAmg,
        _ => rsla::distributed::DistPrecondKind::Jacobi,
    };
    let sys = poisson2d(g, None);
    let t = DSparseTensor::from_global(&sys.matrix, Some(&sys.coords), ranks, PartitionStrategy::Rcb)
        .expect("partition");
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(g * g);
    let opts = DistIterOpts {
        precond,
        ..Default::default()
    };
    let ((x, reports), secs) = timed(|| t.solve(&b, &opts).unwrap());
    let res = {
        let ax = sys.matrix.matvec(&x);
        b.iter()
            .zip(&ax)
            .map(|(bi, ai)| (bi - ai) * (bi - ai))
            .sum::<f64>()
            .sqrt()
    };
    println!(
        "dist-cg g={g} n={} ranks={ranks} iters={} residual={:.2e} time={:.1} ms",
        g * g,
        reports[0].iters,
        res,
        secs * 1e3
    );
    for (p, r) in reports.iter().enumerate() {
        println!(
            "  rank {p}: mem {:.2} MB, sent {:.2} MB",
            r.peak_bytes as f64 / 1e6,
            r.bytes_sent as f64 / 1e6
        );
    }
}
