//! rsla CLI — leader entrypoint for the coordinator.
//!
//! Subcommands:
//!   backends                     list backends + artifact inventory
//!   explain --n N [--accel]      show the dispatch decision for a size
//!   solve --g G [--backend B]    solve a 2D Poisson system, report stats
//!   serve-sim [--requests N]     run the solve service on a synthetic
//!                                request stream, report throughput
//!   serve-sim --mixed            drive a mixed-family (linear/multi-rhs/
//!                                nonlinear/eig/adjoint/dist) open-loop
//!                                workload through the engine; print
//!                                per-kind p50/p95/p99 + affinity stats
//!   serve-sim --trace PATH       additionally record an rsla-trace
//!                                profile (chrome://tracing JSON, or
//!                                JSONL when PATH ends in .jsonl)
//!   trace [--out PATH]           run a small mixed workload with the
//!                                tracer on and export the profile
//!   metrics [--requests N]       run a small mixed workload and dump
//!                                every counter registry as JSON
//!   dist --g G --ranks P [--precond jacobi|amg]   distributed CG demo

use std::sync::Arc;

use rsla::backend::{Device, Dispatcher, Operator, Problem, SolveOpts};
use rsla::coordinator::{ServiceConfig, SolveService};
use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::engine::{workload::MixedWorkload, Engine, EngineConfig, Ticket};
use rsla::metrics::stopwatch::timed;
use rsla::runtime::RuntimeHandle;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;

/// Minimal flag parser: --key value / --flag.
struct Args {
    cmd: String,
    kv: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

fn parse_args() -> Args {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".into());
    parse_tokens(cmd, args.collect())
}

/// True when `tok` is a VALUE for the preceding `--key`, not a flag of
/// its own.  Tokens starting with `-` are flags — EXCEPT when the dash
/// is followed by a digit or `.`, which marks a negative number
/// (`rsla solve --shift -0.5` must bind `-0.5` to `shift` instead of
/// misreading it as a flag).
fn is_cli_value(tok: &str) -> bool {
    match tok.strip_prefix('-') {
        None => true,
        Some(rest) => rest
            .chars()
            .next()
            .map(|c| c.is_ascii_digit() || c == '.')
            .unwrap_or(false),
    }
}

fn parse_tokens(cmd: String, rest: Vec<String>) -> Args {
    let mut kv = std::collections::HashMap::new();
    let mut flags = std::collections::HashSet::new();
    let mut i = 0;
    while i < rest.len() {
        let a = rest[i].trim_start_matches("--").to_string();
        if i + 1 < rest.len() && is_cli_value(&rest[i + 1]) {
            kv.insert(a, rest[i + 1].clone());
            i += 2;
        } else {
            flags.insert(a);
            i += 1;
        }
    }
    Args { cmd, kv, flags }
}

impl Args {
    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.kv
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

/// Merge counter snapshots from several registries into one sorted
/// list — the single source every CLI stat report reads from, instead
/// of each command probing registries counter-by-counter.
fn merged_snapshot(regs: &[&rsla::metrics::Registry]) -> Vec<(String, u64)> {
    let mut m = std::collections::BTreeMap::new();
    for reg in regs {
        for (k, v) in reg.snapshot() {
            *m.entry(k).or_insert(0u64) += v;
        }
    }
    m.into_iter().collect()
}

fn counter(snap: &[(String, u64)], name: &str) -> u64 {
    snap.iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| *v)
        .unwrap_or(0)
}

/// Render a merged snapshot as a flat JSON object (sorted keys).
fn metrics_json(snap: &[(String, u64)]) -> String {
    let mut s = String::from("{");
    for (i, (k, v)) in snap.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("\n  \"{k}\": {v}"));
    }
    if !snap.is_empty() {
        s.push('\n');
    }
    s.push('}');
    s
}

/// Factor-cache effectiveness line, fed by a merged snapshot.
fn report_factor_cache(snap: &[(String, u64)]) {
    let hits = counter(snap, "factor_cache.hit.numeric") + counter(snap, "factor_cache.hit.symbolic");
    let misses = counter(snap, "factor_cache.miss");
    let lookups = hits + misses;
    println!(
        "factor cache: {:.0}% hit rate ({} numeric + {} symbolic hits, {} misses, {} evictions, {} refactorizations)",
        if lookups > 0 { 100.0 * hits as f64 / lookups as f64 } else { 0.0 },
        counter(snap, "factor_cache.hit.numeric"),
        counter(snap, "factor_cache.hit.symbolic"),
        misses,
        counter(snap, "factor_cache.eviction"),
        counter(snap, "factor_cache.numeric_factorizations"),
    );
}

/// Roofline format-selection line, fed by a merged snapshot; silent
/// when no decision was recorded.
fn report_spmv_formats(snap: &[(String, u64)], suffix: &str) {
    let (csr, sell) = (counter(snap, "spmv.format.csr"), counter(snap, "spmv.format.sell"));
    if csr + sell > 0 || !suffix.is_empty() {
        println!("spmv formats (roofline): csr={csr} sell={sell}{suffix}");
    }
}

/// Stop the tracer, export its snapshot to `path` (chrome://tracing
/// JSON, or JSONL when the path ends in `.jsonl`), and print the
/// shutdown summary.
fn export_trace(path: &str) {
    let tracer = rsla::trace::Tracer::global();
    tracer.disable();
    let snap = tracer.snapshot();
    let text = if path.ends_with(".jsonl") {
        rsla::trace::export::jsonl(&snap)
    } else {
        rsla::trace::export::chrome_trace_json(&snap)
    };
    match std::fs::write(path, &text) {
        Ok(()) => println!("trace: wrote {} records to {path}", snap.spans.len() + snap.convs.len()),
        Err(e) => eprintln!("trace: failed to write {path}: {e}"),
    }
    print!("{}", rsla::trace::TraceSummary::of(&snap));
}

fn dispatcher(accel: bool) -> Arc<Dispatcher> {
    if accel {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Arc::new(Dispatcher::new(Some(h))),
            Err(e) => {
                eprintln!("warning: no artifacts ({e}); CPU backends only");
                Arc::new(Dispatcher::new(None))
            }
        }
    } else {
        Arc::new(Dispatcher::new(None))
    }
}

fn main() {
    // process-transport worker re-exec: if the RSLA_PROC_* environment
    // marks this invocation as a rank-team worker, run the worker
    // protocol and exit before touching the CLI
    rsla::distributed::maybe_run_worker();
    let args = parse_args();
    match args.cmd.as_str() {
        "backends" => cmd_backends(),
        "explain" => cmd_explain(&args),
        "solve" => cmd_solve(&args),
        "serve-sim" => cmd_serve_sim(&args),
        "trace" => cmd_trace(&args),
        "metrics" => cmd_metrics(&args),
        "dist" => cmd_dist(&args),
        _ => {
            println!(
                "rsla — differentiable sparse linear algebra (torch-sla reproduction)\n\n\
                 usage: rsla <backends|explain|solve|serve-sim|trace|metrics|dist> [--key value]\n\
                 \x20 backends                      list backends + artifacts\n\
                 \x20 explain --n N [--accel]       dispatch decision for size N\n\
                 \x20 solve --g G [--backend B] [--accel] [--csr]\n\
                 \x20 serve-sim [--requests N] [--workers W] [--mixed] [--trace PATH]\n\
                 \x20 trace [--out PATH] [--requests N] [--workers W]\n\
                 \x20 metrics [--requests N] [--workers W]\n\
                 \x20 dist --g G --ranks P [--precond jacobi|amg]\n\
                 \x20      [--method cg|pipelined|ca] [--s S]\n\
                 \x20      [--backend local|proc] [--transport shm|socket]"
            );
        }
    }
}

fn cmd_backends() {
    let d = dispatcher(true);
    println!("backends (dispatch order depends on device/problem):");
    for name in d.backend_names() {
        println!("  {name}");
    }
    if let Ok(h) = RuntimeHandle::spawn_default() {
        println!("\nAOT artifacts ({}):", h.names().len());
        for n in h.names() {
            println!("  {n}");
        }
    }
}

fn cmd_explain(args: &Args) {
    let n = args.usize_or("n", 10_000);
    let g = (n as f64).sqrt() as usize;
    let accel = args.flags.contains("accel");
    let d = dispatcher(accel);
    let sys = poisson2d(g.max(4), None);
    let b = vec![1.0; sys.matrix.nrows];
    let opts = SolveOpts {
        device: if accel { Device::Accel } else { Device::Cpu },
        ..Default::default()
    };
    let p = Problem {
        op: Operator::Stencil(&sys.coeffs),
        b: &b,
    };
    println!(
        "n={} device={:?} -> backend {:?}",
        sys.matrix.nrows,
        opts.device,
        d.select(&p, &opts)
    );
}

fn cmd_solve(args: &Args) {
    let g = args.usize_or("g", 64);
    let accel = args.flags.contains("accel");
    let d = dispatcher(accel);
    let kappa = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa));
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(g * g);
    let mut opts = SolveOpts {
        device: if accel { Device::Accel } else { Device::Cpu },
        tol: 1e-8,
        ..Default::default()
    };
    if let Some(be) = args.kv.get("backend") {
        opts.backend = Some(be.clone());
    }
    // --csr assembles the operator instead of staying matrix-free, so
    // the iterative path runs the roofline format selection
    let op = if args.flags.contains("csr") {
        Operator::Csr(&sys.matrix)
    } else {
        Operator::Stencil(&sys.coeffs)
    };
    let p = Problem { op, b: &b };
    let (out, secs) = timed(|| d.solve(&p, &opts));
    match out {
        Ok(out) => println!(
            "g={g} n={} backend={} method={} iters={} residual={:.2e} mem={:.1} MB time={:.1} ms",
            g * g,
            out.backend,
            out.method,
            out.iters,
            out.residual,
            out.peak_bytes as f64 / 1e6,
            secs * 1e3
        ),
        Err(e) => println!("solve failed: {e}"),
    }
    // the roofline cost model records every per-matrix format decision
    let snap = merged_snapshot(&[rsla::metrics::Registry::global()]);
    report_spmv_formats(&snap, "");
}

fn cmd_serve_sim(args: &Args) {
    if args.kv.contains_key("trace") {
        rsla::trace::Tracer::global().enable();
    }
    if args.flags.contains("mixed") {
        return cmd_serve_mixed(args);
    }
    let requests = args.usize_or("requests", 64);
    let workers = args.usize_or("workers", 4);
    let d = dispatcher(false);
    let svc = SolveService::start(
        d.clone(),
        ServiceConfig {
            workers,
            ..Default::default()
        },
    );
    let mut rng = Prng::new(7);
    // mixed stream: 70% shared-pattern Poisson (batchable), 30% random SPD
    let poisson = poisson2d(24, None);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..requests {
        let (a, b) = if i % 10 < 7 {
            (poisson.matrix.clone(), rng.normal_vec(poisson.matrix.nrows))
        } else {
            let a = rsla::sparse::graphs::random_spd(&mut rng, 100 + (i % 5) * 30, 3, 1.0);
            let b = rng.normal_vec(a.nrows);
            (a, b)
        };
        rxs.push(svc.submit(a, b, SolveOpts::default()));
    }
    let mut lat = Vec::new();
    let mut batched = 0u64;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        resp.outcome.expect("solve failed");
        lat.push(resp.queue_seconds + resp.service_seconds);
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = svc.stats();
    println!(
        "served {requests} solves in {:.1} ms ({:.0} req/s), workers={workers}",
        wall * 1e3,
        requests as f64 / wall
    );
    println!(
        "p50 latency {:.2} ms  p99 {:.2} ms  batched {batched}/{requests}  batches {}",
        lat[lat.len() / 2] * 1e3,
        lat[lat.len() * 99 / 100] * 1e3,
        stats.batches,
    );
    // factor-cache effectiveness across the request stream.  Counters
    // land in TWO registries: the dispatcher's (single solves routed
    // through solver_fn / native-direct) and the service's (the
    // factorize-once batched path) — merge both or the report
    // undercounts the dominant batched traffic.
    let snap = merged_snapshot(&[&d.metrics, &svc.metrics]);
    report_factor_cache(&snap);
    svc.shutdown();
    if let Some(path) = args.kv.get("trace") {
        export_trace(path);
    }
}

/// Mixed-family open-loop workload through the engine: every JobKind,
/// per-kind latency histograms, affinity hit rate, shard cache stats.
fn cmd_serve_mixed(args: &Args) {
    let requests = args.usize_or("requests", 96);
    let workers = args.usize_or("workers", 4);
    let engine = Engine::start(
        dispatcher(false),
        EngineConfig {
            workers,
            // serving mode: generational latency histograms, so the
            // table's p99 tracks recent traffic instead of being pinned
            // forever by the cold-start burst
            hist_window: Some((64, 4)),
            ..Default::default()
        },
    );
    // the SAME generator the serve_mixed bench measures: a few small
    // recurring patterns so affinity has something to exploit, RCB
    // partitions for the dist jobs (the demo shows the coords path)
    let mut workload = MixedWorkload::new(&[16, 20, 24], 42);
    workload.dist_strategy = PartitionStrategy::Rcb;
    workload.dist_use_coords = true;
    workload.multi_rhs = 4;
    let t0 = std::time::Instant::now();
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..requests {
        tickets.push(engine.submit(workload.spec(i)).expect("admission"));
    }
    let mut failures = 0usize;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failures += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "served {requests} mixed-family jobs in {:.1} ms ({:.0} job/s), workers={workers}, failures={failures}",
        wall * 1e3,
        requests as f64 / wall
    );
    println!(
        "| {:>9} | {:>6} | {:>9} | {:>9} | {:>9} |",
        "kind", "count", "p50", "p95", "p99"
    );
    println!("|-----------|--------|-----------|-----------|-----------|");
    for k in &stats.kinds {
        if k.count == 0 {
            continue;
        }
        println!(
            "| {:>9} | {:>6} | {:>6.2} ms | {:>6.2} ms | {:>6.2} ms |",
            k.kind.name(),
            k.count,
            k.p50 * 1e3,
            k.p95 * 1e3,
            k.p99 * 1e3
        );
    }
    let aff_total = stats.affinity_hits + stats.affinity_misses;
    println!(
        "affinity: {:.0}% warm routing ({} hits / {} routed), queue depth now {}",
        if aff_total > 0 {
            100.0 * stats.affinity_hits as f64 / aff_total as f64
        } else {
            0.0
        },
        stats.affinity_hits,
        aff_total,
        stats.queue_depth
    );
    println!(
        "shard factor caches: {:.0}% hit rate ({} numeric + {} symbolic hits, {} misses, {} evictions)",
        100.0 * stats.cache_hit_rate(),
        stats.cache.hits_numeric,
        stats.cache.hits_symbolic,
        stats.cache.misses,
        stats.cache.evictions,
    );
    // format decisions land in the engine registry (engine-held
    // operators) and the process-global one (the backend dispatch
    // path); merge both so no decision goes missing
    let snap = merged_snapshot(&[&engine.metrics, rsla::metrics::Registry::global()]);
    report_spmv_formats(&snap, " (latency table windowed to the last 256 jobs/kind)");
    engine.shutdown();
    if let Some(path) = args.kv.get("trace") {
        export_trace(path);
    }
    if failures > 0 {
        std::process::exit(1);
    }
}

/// Run a small mixed workload with the tracer recording from the first
/// submission, then export the profile and print the span summary.
fn cmd_trace(args: &Args) {
    let out = args
        .kv
        .get("out")
        .cloned()
        .unwrap_or_else(|| "trace.json".into());
    rsla::trace::Tracer::global().enable();
    let (requests, workers) = (args.usize_or("requests", 48), args.usize_or("workers", 2));
    let failures = run_mixed_quiet(requests, workers);
    export_trace(&out);
    if failures > 0 {
        std::process::exit(1);
    }
}

/// Run a small mixed workload, then dump every counter (engine registry
/// merged with the process-global one) as JSON on stdout.
fn cmd_metrics(args: &Args) {
    let (requests, workers) = (args.usize_or("requests", 48), args.usize_or("workers", 2));
    let engine = Engine::start(
        dispatcher(false),
        EngineConfig {
            workers,
            ..Default::default()
        },
    );
    let mut workload = MixedWorkload::new(&[16, 20, 24], 42);
    workload.multi_rhs = 4;
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..requests {
        tickets.push(engine.submit(workload.spec(i)).expect("admission"));
    }
    let mut failures = 0usize;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failures += 1;
        }
    }
    let snap = merged_snapshot(&[&engine.metrics, rsla::metrics::Registry::global()]);
    engine.shutdown();
    println!("{}", metrics_json(&snap));
    if failures > 0 {
        std::process::exit(1);
    }
}

/// Drive `requests` mixed-family jobs through a fresh engine without
/// printing the latency table; returns the failure count.
fn run_mixed_quiet(requests: usize, workers: usize) -> usize {
    let engine = Engine::start(
        dispatcher(false),
        EngineConfig {
            workers,
            ..Default::default()
        },
    );
    let mut workload = MixedWorkload::new(&[16, 20, 24], 42);
    workload.multi_rhs = 4;
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..requests {
        tickets.push(engine.submit(workload.spec(i)).expect("admission"));
    }
    let mut failures = 0usize;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failures += 1;
        }
    }
    engine.shutdown();
    failures
}

fn cmd_dist(args: &Args) {
    use rsla::distributed::{CommBackend, DistMethod, ProcOpts, TransportKind};

    let g = args.usize_or("g", 128);
    let ranks = args.usize_or("ranks", 4);
    // --precond jacobi (default, paper parity) | amg (block additive Schwarz)
    let precond = match args.kv.get("precond").map(|s| s.as_str()) {
        Some("amg") => rsla::distributed::DistPrecondKind::BlockAmg,
        _ => rsla::distributed::DistPrecondKind::Jacobi,
    };
    // --method cg (default) | pipelined | ca [--s S]
    let method = match args.kv.get("method").map(|s| s.as_str()) {
        Some("pipelined") => DistMethod::CgPipelined,
        Some("ca") => DistMethod::CaCg {
            s: args.usize_or("s", 4),
        },
        _ => DistMethod::Auto,
    };
    // --backend local (thread ranks) | proc (worker processes over
    // shm rings, or a socket mesh with --transport socket)
    let backend = match args.kv.get("backend").map(|s| s.as_str()) {
        Some("proc") => CommBackend::Proc(ProcOpts {
            kind: match args.kv.get("transport").map(|s| s.as_str()) {
                Some("socket") => TransportKind::Socket,
                _ => TransportKind::Shm,
            },
            ..ProcOpts::default()
        }),
        _ => CommBackend::Local,
    };
    let is_proc = matches!(backend, CommBackend::Proc(_));
    let sys = poisson2d(g, None);
    let t = DSparseTensor::from_global(&sys.matrix, Some(&sys.coords), ranks, PartitionStrategy::Rcb)
        .expect("partition");
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(g * g);
    let opts = DistIterOpts {
        precond,
        method,
        backend,
        ..Default::default()
    };
    let (outcome, secs) = timed(|| t.solve(&b, &opts));
    let (x, reports) = match outcome {
        Ok(pair) => pair,
        // the typed dead-rank error is the headline feature of the
        // process backend: show it rather than panicking
        Err(e) => {
            eprintln!("dist solve failed: {e}");
            std::process::exit(1);
        }
    };
    let res = {
        let ax = sys.matrix.matvec(&x);
        b.iter()
            .zip(&ax)
            .map(|(bi, ai)| (bi - ai) * (bi - ai))
            .sum::<f64>()
            .sqrt()
    };
    let iters = reports[0].iters.max(1);
    println!(
        "dist-{} g={g} n={} ranks={ranks} backend={} iters={} residual={:.2e} time={:.1} ms",
        reports[0].method,
        g * g,
        if is_proc { "proc" } else { "local" },
        reports[0].iters,
        res,
        secs * 1e3
    );
    println!(
        "  reductions: {} rounds total ({:.2} rounds/iter — Algorithm 1 pins 2 for standard CG; \
         pipelined 1; CA-CG ~1/s)",
        reports[0].reduce_rounds,
        reports[0].reduce_rounds as f64 / iters as f64,
    );
    for (p, r) in reports.iter().enumerate() {
        println!(
            "  rank {p}: mem {:.2} MB, sent {:.2} MB ({:.1} KB/iter)",
            r.peak_bytes as f64 / 1e6,
            r.bytes_sent as f64 / 1e6,
            r.bytes_sent as f64 / iters as f64 / 1e3,
        );
        if is_proc {
            println!(
                "          wire: {:.2} MB in {} msgs, doorbell waits {} \
                 (p50 {:.0} us, p99 {:.0} us, max {:.0} us)",
                r.transport.wire_bytes as f64 / 1e6,
                r.transport.wire_msgs,
                r.transport.doorbell_waits,
                r.transport.doorbell_p50_us,
                r.transport.doorbell_p99_us,
                r.transport.doorbell_max_us,
            );
        }
    }
    if is_proc {
        let snap = merged_snapshot(&[rsla::metrics::Registry::global()]);
        println!(
            "  transport counters: teams={} rounds={} wire_bytes={} doorbell_waits={} dead_ranks={}",
            counter(&snap, "comm.transport.teams"),
            counter(&snap, "comm.transport.rounds"),
            counter(&snap, "comm.transport.wire_bytes"),
            counter(&snap, "comm.transport.doorbell_waits"),
            counter(&snap, "comm.transport.dead_ranks"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn negative_numeric_values_bind_to_their_key() {
        // regression: `--shift -0.5` used to be fragile because the
        // value starts with `-`
        let a = parse_tokens("solve".into(), toks(&["--shift", "-0.5", "--g", "32"]));
        assert_eq!(a.kv.get("shift").map(String::as_str), Some("-0.5"));
        assert_eq!(a.usize_or("g", 0), 32);
        assert!(a.flags.is_empty());

        let a = parse_tokens("solve".into(), toks(&["--shift", "-2"]));
        assert_eq!(a.kv.get("shift").map(String::as_str), Some("-2"));

        let a = parse_tokens("solve".into(), toks(&["--tol", "-.5e-3"]));
        assert_eq!(a.kv.get("tol").map(String::as_str), Some("-.5e-3"));
    }

    #[test]
    fn flags_are_not_mistaken_for_values() {
        let a = parse_tokens(
            "solve".into(),
            toks(&["--accel", "--g", "8", "--backend", "native-iter"]),
        );
        assert!(a.flags.contains("accel"));
        assert_eq!(a.usize_or("g", 0), 8);
        assert_eq!(a.kv.get("backend").map(String::as_str), Some("native-iter"));
    }

    #[test]
    fn trailing_key_without_value_becomes_flag() {
        let a = parse_tokens("explain".into(), toks(&["--accel"]));
        assert!(a.flags.contains("accel"));
        assert!(a.kv.is_empty());
        // a bare "-" is a flag, not a value
        let a = parse_tokens("x".into(), toks(&["--k", "-"]));
        assert!(a.flags.contains("k"));
    }
}
