//! # rsla — differentiable sparse linear algebra with adjoint solvers
//!
//! A ground-up Rust + JAX + Pallas reproduction of
//! *"torch-sla: Differentiable Sparse Linear Algebra with Adjoint Solvers
//! and Sparse Tensor Parallelism for PyTorch"* (Chi & Wen,
//! AI4Physics@ICML 2026).
//!
//! The paper's host (PyTorch autograd + CUDA backends) is replaced by a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordinator: typed sparse tensors
//!   ([`tensor`]), five interchangeable solver backends with auto-dispatch
//!   ([`backend`]), a reverse-mode autograd engine ([`autograd`]), the
//!   implicit-function-theorem adjoint framework ([`adjoint`]), the
//!   unified Krylov substrate written once over `LinearOperator x
//!   Communicator` ([`krylov`]), the distributed domain-decomposition
//!   layer with autograd-compatible halo exchange ([`distributed`]),
//!   and the solve [`engine`] — one typed submission path with
//!   pattern-affinity scheduling for every solver family
//!   ([`coordinator`] remains as its compatibility shim).
//! * **L2 (python/compile/model.py)** — JAX compute graphs (fused
//!   Jacobi-PCG, dense Cholesky solve, SpMV entry points) AOT-lowered to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (stencil SpMV, ELL
//!   SpMV) inlined into the L2 graphs.
//!
//! Python never runs on the solve path: the [`runtime`] module loads the
//! AOT artifacts through PJRT (`xla` crate) once and executes them from
//! Rust.
//!
//! ## Quickstart
//!
//! ```no_run
//! use rsla::tensor::{SparseTensor, SolveOpts};
//! use rsla::sparse::poisson::poisson2d;
//!
//! let sys = poisson2d(64, None);             // 2D Poisson, 64x64 interior
//! let a = SparseTensor::from_csr(sys.matrix.clone());
//! let b = vec![1.0; a.nrows()];
//! let x = a.solve(&b, &SolveOpts::default()).unwrap();
//! ```
//!
//! See `examples/` for autograd-aware solves, the inverse
//! coefficient-learning task (paper Fig. 3), and distributed runs.

pub mod adjoint;
pub mod autograd;
pub mod backend;
pub mod coordinator;
pub mod direct;
pub mod distributed;
pub mod eigen;
pub mod engine;
pub mod error;
pub mod factor_cache;
pub mod gradcheck;
pub mod iterative;
pub mod krylov;
pub mod lint;
pub mod metrics;
pub mod nonlinear;
pub mod optim;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod trace;
pub mod util;

pub use error::{Error, Result};
