//! PJRT runtime: load AOT HLO-text artifacts once, execute from the
//! Rust hot path.  Python never runs here (paper architecture: the
//! "CUDA backend" half of torch-sla, re-hosted on XLA-CPU).
//!
//! * [`registry::Registry`] — artifact discovery (manifest.tsv), lazy
//!   compile, executable cache.
//! * [`exec`] — typed argument/result marshalling between `Vec<f64>` /
//!   scalars and XLA literals.

pub mod exec;
pub mod registry;
pub mod service;

pub use exec::{Arg, OutValue};
pub use registry::{ArtifactSpec, Registry};
pub use service::RuntimeHandle;
