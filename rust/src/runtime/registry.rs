//! Artifact registry: manifest parsing, lazy compilation, executable
//! caching.
//!
//! `make artifacts` (the one-time Python step) writes
//! `artifacts/NAME.hlo.txt` plus `manifest.tsv` describing each entry's
//! parameter and result shapes.  The registry compiles each module on
//! first use through the PJRT CPU client and memoizes the loaded
//! executable — one compiled executable per model variant, as the paper
//! keeps one cuDSS plan / CUDA graph per shape.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

use super::exec::{execute, Arg, OutValue};
use crate::util::lock_recover;

/// Dtype/shape of one parameter or result, parsed from manifest.tsv
/// entries like `float64:5x32x32` (empty dims = scalar).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> Result<Self> {
        let (dtype, dims_s) = s
            .split_once(':')
            .ok_or_else(|| Error::Artifact("manifest".into(), format!("bad spec '{s}'")))?;
        let dims = if dims_s.is_empty() {
            vec![]
        } else {
            dims_s
                .split('x')
                .map(|d| {
                    d.parse::<usize>().map_err(|e| {
                        Error::Artifact("manifest".into(), format!("bad dim '{d}': {e}"))
                    })
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec {
            dtype: dtype.to_string(),
            dims,
        })
    }

    pub fn elem_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub params: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Compile-once, execute-many artifact store.  Thread-safe; executables
/// are shared behind `Arc`.
pub struct Registry {
    dir: PathBuf,
    client: xla::PjRtClient,
    specs: HashMap<String, ArtifactSpec>,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Wall-clock spent compiling (perf accounting; excluded from solve
    /// timings the way the paper excludes cuDSS plan creation).
    compile_seconds: Mutex<f64>,
}

/// Parse `manifest.tsv` in `dir` into artifact specs.
pub fn parse_manifest(dir: &Path) -> Result<HashMap<String, ArtifactSpec>> {
    let manifest = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&manifest).map_err(|e| {
        Error::Artifact(
            manifest.display().to_string(),
            format!("missing manifest ({e}); run `make artifacts`"),
        )
    })?;
    let mut specs = HashMap::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split('\t');
        let name = parts
            .next()
            .ok_or_else(|| Error::Artifact("manifest".into(), "empty line".into()))?
            .to_string();
        let params = parts
            .next()
            .unwrap_or("")
            .split(';')
            .filter(|s| !s.is_empty())
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = parts
            .next()
            .unwrap_or("")
            .split(';')
            .filter(|s| !s.is_empty())
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        specs.insert(
            name.clone(),
            ArtifactSpec {
                name,
                params,
                outputs,
            },
        );
    }
    Ok(specs)
}

impl Registry {
    /// Open the artifact directory (looks for `manifest.tsv`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let specs = parse_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Registry {
            dir,
            client,
            specs,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Default location: `$RSLA_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("RSLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn has(&self, name: &str) -> bool {
        self.specs.contains_key(name)
    }

    pub fn compile_seconds(&self) -> f64 {
        *lock_recover(&self.compile_seconds)
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = lock_recover(&self.cache).get(name) {
            return Ok(e.clone());
        }
        if !self.specs.contains_key(name) {
            return Err(Error::Artifact(
                name.into(),
                "not in manifest (regenerate with `make artifacts`)".into(),
            ));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(name.into(), "non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        *lock_recover(&self.compile_seconds) += t0.elapsed().as_secs_f64();
        lock_recover(&self.cache).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` with typed args; validates arity against
    /// the manifest.
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<OutValue>> {
        let spec = self
            .spec(name)
            .ok_or_else(|| Error::Artifact(name.into(), "unknown artifact".into()))?;
        if spec.params.len() != args.len() {
            return Err(Error::Artifact(
                name.into(),
                format!("expected {} args, got {}", spec.params.len(), args.len()),
            ));
        }
        for (i, (p, a)) in spec.params.iter().zip(args).enumerate() {
            let want = p.elem_count();
            let got = a.elem_count();
            if want != got {
                return Err(Error::Artifact(
                    name.into(),
                    format!("arg {i}: expected {want} elements, got {got}"),
                ));
            }
        }
        let exe = self.executable(name)?;
        execute(&exe, args, &spec.outputs)
    }
}
