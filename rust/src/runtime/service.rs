//! Runtime executor thread: the PJRT client is not thread-safe (the
//! `xla` crate wraps it in `Rc` + raw pointers), so — like a CUDA
//! context pinned to one stream thread — a single executor thread owns
//! the [`Registry`] and serves executions over a channel.
//! [`RuntimeHandle`] is the cheap, `Send + Sync` handle the backends
//! and the coordinator share.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{mpsc, Arc, Mutex};

use crate::error::{Error, Result};

use super::exec::{Arg, OutValue};
use super::registry::{parse_manifest, ArtifactSpec, Registry};
use crate::util::lock_recover;

enum Msg {
    Run {
        name: String,
        args: Vec<Arg>,
        reply: mpsc::Sender<Result<Vec<OutValue>>>,
    },
    CompileSeconds {
        reply: mpsc::Sender<f64>,
    },
    Shutdown,
}

/// Shareable handle to the runtime executor thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Msg>,
    specs: Arc<HashMap<String, ArtifactSpec>>,
    // serialize senders so the reply channels stay ordered per caller
    lock: Arc<Mutex<()>>,
}

impl RuntimeHandle {
    /// Spawn the executor thread over an artifact directory.
    pub fn spawn(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let specs = Arc::new(parse_manifest(&dir)?);
        let (tx, rx) = mpsc::channel::<Msg>();
        let dir_thread = dir.clone();
        std::thread::Builder::new()
            .name("rsla-pjrt".into())
            .spawn(move || {
                let registry = match Registry::open(&dir_thread) {
                    Ok(r) => r,
                    Err(e) => {
                        // fail every request with the open error
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                Msg::Run { reply, .. } => {
                                    let _ = reply.send(Err(Error::Xla(format!(
                                        "runtime failed to open: {e}"
                                    ))));
                                }
                                Msg::CompileSeconds { reply } => {
                                    let _ = reply.send(0.0);
                                }
                                Msg::Shutdown => break,
                            }
                        }
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Run { name, args, reply } => {
                            let _ = reply.send(registry.run(&name, &args));
                        }
                        Msg::CompileSeconds { reply } => {
                            let _ = reply.send(registry.compile_seconds());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Xla(format!("spawn runtime thread: {e}")))?;
        Ok(RuntimeHandle {
            tx,
            specs,
            lock: Arc::new(Mutex::new(())),
        })
    }

    /// `$RSLA_ARTIFACTS` or `./artifacts`.
    pub fn spawn_default() -> Result<Self> {
        let dir = std::env::var("RSLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::spawn(dir)
    }

    pub fn has(&self, name: &str) -> bool {
        self.specs.contains_key(name)
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute an artifact on the runtime thread (blocking).
    pub fn run(&self, name: &str, args: &[Arg]) -> Result<Vec<OutValue>> {
        let _g = lock_recover(&self.lock);
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Run {
                name: name.to_string(),
                args: args.to_vec(),
                reply: reply_tx,
            })
            .map_err(|_| Error::Xla("runtime thread gone".into()))?;
        reply_rx
            .recv()
            .map_err(|_| Error::Xla("runtime thread dropped reply".into()))?
    }

    pub fn compile_seconds(&self) -> f64 {
        let _g = lock_recover(&self.lock);
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Msg::CompileSeconds { reply: reply_tx }).is_err() {
            return 0.0;
        }
        reply_rx.recv().unwrap_or(0.0)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The PJRT runtime needs AOT artifacts (`make artifacts`) and the
    /// real xla bindings; both are absent in the offline build, so
    /// these tests skip themselves instead of failing.
    fn spawn_or_skip() -> Option<RuntimeHandle> {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Some(h),
            Err(e) => {
                eprintln!("skipping PJRT test: {e}");
                None
            }
        }
    }

    #[test]
    fn handle_runs_from_multiple_threads() {
        let h = match spawn_or_skip() {
            Some(h) => h,
            None => return,
        };
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                let x: Vec<f64> = (0..65536).map(|i| ((i + t) % 7) as f64).collect();
                let y = vec![1.0; 65536];
                let out = h
                    .run("dot_n65536", &[Arg::vec(x.clone()), Arg::vec(y)])
                    .unwrap();
                let want: f64 = x.iter().sum();
                assert!((out[0].scalar_f64() - want).abs() < 1e-6);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn unknown_artifact_fails_cleanly() {
        let h = match spawn_or_skip() {
            Some(h) => h,
            None => return,
        };
        assert!(!h.has("nope"));
        assert!(h.run("nope", &[]).is_err());
    }
}
