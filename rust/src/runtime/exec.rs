//! Literal marshalling: `Vec<f64>` / scalars <-> XLA literals.
//!
//! All artifacts are lowered with `return_tuple=True`, so results come
//! back as one tuple literal that we decompose against the manifest's
//! output specs.

use std::sync::Arc;

use crate::error::{Error, Result};

use super::registry::TensorSpec;

/// A typed argument for an artifact execution.
#[derive(Clone, Debug)]
pub enum Arg {
    /// f64 tensor with explicit dims (row-major).
    F64(Arc<Vec<f64>>, Vec<usize>),
    /// i32 tensor.
    I32(Arc<Vec<i32>>, Vec<usize>),
    /// f64 scalar.
    ScalarF64(f64),
    /// i32 scalar.
    ScalarI32(i32),
}

impl Arg {
    /// Convenience: 1-D f64 vector.
    pub fn vec(v: Vec<f64>) -> Self {
        let n = v.len();
        Arg::F64(Arc::new(v), vec![n])
    }

    /// Convenience: f64 tensor with dims.
    pub fn tensor(v: Vec<f64>, dims: Vec<usize>) -> Self {
        Arg::F64(Arc::new(v), dims)
    }

    pub fn elem_count(&self) -> usize {
        match self {
            Arg::F64(v, _) => v.len(),
            Arg::I32(v, _) => v.len(),
            Arg::ScalarF64(_) | Arg::ScalarI32(_) => 1,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F64(v, dims) => {
                let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v.as_slice()).reshape(&dims_i)?
            }
            Arg::I32(v, dims) => {
                let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(v.as_slice()).reshape(&dims_i)?
            }
            Arg::ScalarF64(s) => xla::Literal::scalar(*s),
            Arg::ScalarI32(s) => xla::Literal::scalar(*s),
        })
    }
}

/// A typed output from an artifact execution.
#[derive(Clone, Debug)]
pub enum OutValue {
    F64(Vec<f64>),
    I32(Vec<i32>),
}

impl OutValue {
    pub fn as_f64(&self) -> &Vec<f64> {
        match self {
            OutValue::F64(v) => v,
            OutValue::I32(_) => panic!("expected f64 output"), // rsla-lint: allow(L1, typed accessor; wrong-kind access is a caller bug)
        }
    }

    pub fn scalar_f64(&self) -> f64 {
        self.as_f64()[0] // rsla-lint: allow(L1, scalar artifacts declare exactly one element)
    }

    pub fn scalar_i32(&self) -> i32 {
        match self {
            OutValue::I32(v) => v[0], // rsla-lint: allow(L1, scalar artifacts declare exactly one element)
            OutValue::F64(v) => v[0] as i32, // rsla-lint: allow(L1, scalar artifacts declare exactly one element)
        }
    }
}

/// Execute a loaded executable with typed args, decomposing the tuple
/// result per `out_specs`.
pub fn execute(
    exe: &xla::PjRtLoadedExecutable,
    args: &[Arg],
    out_specs: &[TensorSpec],
) -> Result<Vec<OutValue>> {
    let literals: Vec<xla::Literal> = args
        .iter()
        .map(|a| a.to_literal())
        .collect::<Result<Vec<_>>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?;
    let tuple = result[0][0].to_literal_sync()?; // rsla-lint: allow(L1, single-device PJRT execute returns one result list)
    let parts = tuple.to_tuple()?;
    if parts.len() != out_specs.len() {
        return Err(Error::Xla(format!(
            "expected {} outputs, got {}",
            out_specs.len(),
            parts.len()
        )));
    }
    parts
        .into_iter()
        .zip(out_specs)
        .map(|(lit, spec)| {
            if spec.dtype.starts_with("int32") {
                Ok(OutValue::I32(lit.to_vec::<i32>()?))
            } else {
                Ok(OutValue::F64(lit.to_vec::<f64>()?))
            }
        })
        .collect()
}
