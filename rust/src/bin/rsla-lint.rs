//! `rsla-lint` — run the repo-invariant static-analysis pass over a
//! source tree (default `rust/src`, falling back to the crate's own
//! `src/` when run from `rust/`).
//!
//! ```text
//! cargo run --bin rsla-lint -- rust/src
//! ```
//!
//! Exit status: 0 when clean, 1 when any diagnostic fires, 2 on I/O
//! errors.  Rule catalog and suppression grammar: docs/static_analysis.md.

use std::path::PathBuf;
use std::process::ExitCode;

use rsla::lint;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .or_else(|| {
            ["rust/src", "src"]
                .iter()
                .map(PathBuf::from)
                .find(|p| p.is_dir())
        })
        .unwrap_or_else(|| PathBuf::from("rust/src"));
    if !root.is_dir() {
        eprintln!("rsla-lint: {} is not a directory", root.display());
        return ExitCode::from(2);
    }
    match lint::run(&root) {
        Ok(diags) if diags.is_empty() => {
            println!("rsla-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            println!("rsla-lint: {} violation(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("rsla-lint: {e}");
            ExitCode::from(2)
        }
    }
}
