//! MINRES (Paige & Saunders 1975) for symmetric — possibly *indefinite*
//! — systems, written once over ([`LinearOperator`], [`Communicator`]).
//! Distributed MINRES is a new scenario family: symmetric-indefinite
//! systems (shifted Laplacians, saddle points, deflated eigenvector
//! adjoints) at rank-team scale.
//!
//! The symmetric Lanczos recurrence is sequential, so each of its two
//! inner products (`alfa`, `beta^2`) is its own reduction round; the
//! Givens QR bookkeeping runs on replicated scalars.  The
//! preconditioner must be SPD and rank-local.

use super::{gdot, Communicator, LinearOperator};
use crate::iterative::{IterOpts, IterResult, Precond};
use crate::metrics::MemTracker;
use crate::trace::{self, names as tn};

/// Solve `A x = b` for symmetric (indefinite OK) `A` with
/// preconditioned MINRES, `x0 = 0`.
pub fn minres(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    comm: &dyn Communicator,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "minres rhs length mismatch");

    let _sp = trace::span_arg(tn::KRYLOV_MINRES, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_MINRES);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);

    let mut x = mem.buf(n);
    let mut r1 = mem.buf(n); // v_{k-1} (unscaled Lanczos vectors)
    let mut r2 = mem.buf(n); // v_k
    let mut y = mem.buf(n); // M^{-1} r2
    let mut w = mem.buf(n);
    let mut w1 = mem.buf(n);
    let mut w2 = mem.buf(n);
    let mut v_ext = mem.buf(n_ext);

    r2.data.copy_from_slice(b_own);
    m.apply(&r2, &mut y);
    let mut beta1 = gdot(comm, &r2, &y);
    if beta1 < 0.0 {
        // preconditioner not SPD
        let residual = gdot(comm, b_own, b_own).sqrt();
        ct.breakdown(0);
        ct.finish(0, residual, false);
        return IterResult {
            x: x.data.to_vec(),
            iters: 0,
            residual,
            converged: false,
            breakdown: true,
            history: vec![],
        };
    }
    if beta1 == 0.0 {
        ct.finish(0, 0.0, true);
        return IterResult {
            x: x.data.to_vec(),
            iters: 0,
            residual: 0.0,
            converged: true,
            breakdown: false,
            history: vec![0.0],
        };
    }
    beta1 = beta1.sqrt();

    // QR of the tridiagonal via Givens rotations, updated incrementally.
    let (mut oldb, mut beta) = (0.0_f64, beta1);
    let mut dbar = 0.0_f64;
    let mut epsln = 0.0_f64;
    let mut phibar = beta1;
    let (mut cs, mut sn) = (-1.0_f64, 0.0_f64);

    let mut history = Vec::new();
    if opts.record_history {
        history.push(phibar);
    }
    ct.record(phibar);

    let mut iters = 0;
    let mut converged = false;
    let mut breakdown = false;
    while iters < opts.max_iters {
        iters += 1;
        // --- Lanczos step ---
        let s = 1.0 / beta;
        for i in 0..n {
            v_ext.data[i] = y.data[i] * s;
        }
        a.apply(&mut v_ext, &mut y);
        if iters >= 2 {
            let c = beta / oldb;
            for i in 0..n {
                y.data[i] -= c * r1.data[i];
            }
        }
        let alfa = gdot(comm, &v_ext[..n], &y);
        {
            let c = alfa / beta;
            for i in 0..n {
                y.data[i] -= c * r2.data[i];
            }
        }
        r1.data.copy_from_slice(&r2.data);
        r2.data.copy_from_slice(&y.data);
        m.apply(&r2, &mut y);
        oldb = beta;
        let betasq = gdot(comm, &r2, &y);
        if betasq < 0.0 {
            breakdown = true;
            ct.breakdown(iters);
            break; // preconditioner lost positive-definiteness
        }
        beta = betasq.sqrt();

        // --- update QR factorization (replicated scalars) ---
        let oldeps = epsln;
        let delta = cs * dbar + sn * alfa;
        let gbar = sn * dbar - cs * alfa;
        epsln = sn * beta;
        dbar = -cs * beta;

        let gamma = (gbar * gbar + beta * beta).sqrt().max(f64::MIN_POSITIVE);
        cs = gbar / gamma;
        sn = beta / gamma;
        let phi = cs * phibar;
        phibar *= sn;

        // --- update solution ---
        let denom = 1.0 / gamma;
        for i in 0..n {
            w1.data[i] = w2.data[i];
            w2.data[i] = w.data[i];
            w.data[i] = (v_ext.data[i] - oldeps * w1.data[i] - delta * w2.data[i]) * denom;
            x.data[i] += phi * w.data[i];
        }

        if opts.record_history {
            history.push(phibar);
        }
        ct.record(phibar);
        if phibar <= opts.tol {
            converged = true;
            break;
        }
    }

    // true residual (phibar tracks the preconditioned norm)
    v_ext.data[..n].copy_from_slice(&x.data);
    let mut ax = vec![0.0; n];
    a.apply(&mut v_ext, &mut ax);
    let mut rr = 0.0;
    for i in 0..n {
        let d = b_own[i] - ax[i];
        rr += d * d;
    }
    let residual = comm.all_reduce_sum(rr).sqrt();

    let converged = converged || residual <= opts.tol * 10.0;
    ct.finish(iters, residual, converged);
    IterResult {
        x: x.data.to_vec(),
        iters,
        residual,
        converged,
        breakdown: breakdown && !converged,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::Identity;
    use crate::krylov::{NullComm, ShiftedOp};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{rel_l2, Prng};

    #[test]
    fn generic_minres_solves_shifted_indefinite_under_null_comm() {
        // A - sigma I with sigma inside the spectrum: symmetric
        // indefinite, via the ShiftedOp wrapper (matrix-free shift).
        let g = 10;
        let n = g * g;
        let sys = poisson2d(g, None);
        let op = ShiftedOp {
            op: &sys.matrix,
            sigma: 30.0,
        };
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(n);
        let r = minres(
            &op,
            &b,
            &Identity,
            &NullComm,
            &IterOpts {
                tol: 1e-9,
                max_iters: 20_000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged, "residual {}", r.residual);
        let mut ax = sys.matrix.matvec(&r.x);
        for (axi, xi) in ax.iter_mut().zip(&r.x) {
            *axi -= 30.0 * xi;
        }
        assert!(rel_l2(&ax, &b) < 1e-7);
    }
}
