//! The [`Communicator`] trait: what a Krylov kernel needs from the
//! collective layer — nothing more than rank identity and a fused
//! sum-all-reduce.
//!
//! Two implementations ship: [`NullComm`] (serial; every collective is
//! the identity and costs nothing) and `distributed::LocalComm` (the
//! in-process NCCL stand-in whose rounds and bytes are accounted).
//! Kernels written against this trait therefore run serially and
//! distributed from the one body, and the *number* of `all_reduce`
//! calls per iteration is the latency model the pipelined-CG ablation
//! measures.

/// Collective communication surface of the Krylov kernels.
pub trait Communicator {
    /// This rank's index in `[0, size)`.
    fn rank(&self) -> usize;

    /// Number of ranks in the team.
    fn size(&self) -> usize;

    /// Fused in-place sum-all-reduce: after the call every rank holds
    /// the team-wide elementwise sum.  One call is ONE reduction round
    /// (one latency unit) regardless of `xs.len()` — NCCL expresses
    /// this as a single all_reduce over a packed buffer.
    ///
    /// Reduction order is part of the contract: implementations MUST
    /// fold per-rank contributions in rank-ascending order
    /// (`((c0 + c1) + c2) + ...`), never arrival order, so a solve's
    /// floating-point trajectory is transport-independent — [`NullComm`]
    /// trivially (one rank), `LocalComm`/`ProcComm` pinned bitwise in
    /// `distributed::comm` and `tests/proc_comm.rs`.
    fn all_reduce(&self, xs: &mut [f64]);

    /// Scalar convenience over [`Communicator::all_reduce`].
    fn all_reduce_sum(&self, x: f64) -> f64 {
        let mut buf = [x];
        self.all_reduce(&mut buf);
        buf[0]
    }

    /// Bytes this rank has sent so far (0 for serial communicators).
    fn bytes_sent(&self) -> u64 {
        0
    }

    /// Completed reduction rounds so far (latency units; 0 for serial).
    fn reduce_rounds(&self) -> u64 {
        0
    }
}

/// The serial communicator: a team of one.  `all_reduce` is the
/// identity and compiles to nothing, so kernels pay zero cost for being
/// written distributed-first.
pub struct NullComm;

impl Communicator for NullComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    #[inline]
    fn all_reduce(&self, _xs: &mut [f64]) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_comm_is_identity() {
        let c = NullComm;
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        let mut xs = [1.5, -2.0];
        c.all_reduce(&mut xs);
        assert_eq!(xs, [1.5, -2.0]);
        assert_eq!(c.all_reduce_sum(3.25), 3.25);
        assert_eq!(c.bytes_sent(), 0);
        assert_eq!(c.reduce_rounds(), 0);
    }
}
