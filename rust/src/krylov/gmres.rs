//! Restarted GMRES(m) with modified Gram–Schmidt Arnoldi and Givens
//! rotations, written once over ([`LinearOperator`], [`Communicator`])
//! — the general-purpose solver for indefinite / nonsymmetric systems,
//! now available distributed (a new scenario family: the paper's
//! Appendix A wraps GMRES serially only).
//!
//! MGS is a sequential recurrence, so each projection coefficient is
//! its own reduction round (k+2 rounds for inner iteration k); the
//! Hessenberg/Givens bookkeeping is replicated on every rank from the
//! reduced scalars, so all ranks stay in lockstep.

use super::{gdot, gnorm, Communicator, LinearOperator};
use crate::iterative::{IterOpts, IterResult, Precond};
use crate::metrics::MemTracker;
use crate::trace::{self, names as tn};

/// Solve `A x = b` with right-preconditioned restarted GMRES(m),
/// `x0 = 0`.  `restart` is the Krylov basis size between restarts.
pub fn gmres(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    restart: usize,
    comm: &dyn Communicator,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "gmres rhs length mismatch");
    // cap the basis by the GLOBAL problem size (sum of owned rows)
    let n_glob = comm.all_reduce_sum(n as f64) as usize;
    let restart = restart.max(1).min(n_glob);

    let _sp = trace::span_arg(tn::KRYLOV_GMRES, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_GMRES);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut w = mem.buf(n);
    let mut z_ext = mem.buf(n_ext);
    // Krylov basis (restart+1 owned-layout vectors)
    let _basis_guard = mem.hold(((restart + 1) * n * 8) as u64);
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(restart + 1);

    let mut history = Vec::new();
    let mut total_iters = 0usize;
    let mut beta;

    r.data.copy_from_slice(b_own);
    beta = gnorm(comm, &r);
    if opts.record_history {
        history.push(beta);
    }
    ct.record(beta);

    let mut first_cycle = true;
    'outer: while beta > opts.tol && total_iters < opts.max_iters {
        if !first_cycle {
            ct.restart();
        }
        first_cycle = false;
        basis.clear();
        let mut v0 = r.data.to_vec();
        for vi in v0.iter_mut() {
            *vi /= beta;
        }
        basis.push(v0);

        // Hessenberg (restart+1 x restart), Givens cos/sin, residual g
        let mut h = vec![vec![0f64; restart]; restart + 1];
        let mut cs = vec![0f64; restart];
        let mut sn = vec![0f64; restart];
        let mut g = vec![0f64; restart + 1];
        g[0] = beta;

        let mut k_used = 0;
        for k in 0..restart {
            if total_iters >= opts.max_iters {
                break;
            }
            // w = A M^{-1} v_k
            m.apply(&basis[k], &mut z_ext.data[..n]);
            a.apply(&mut z_ext, &mut w);
            // modified Gram–Schmidt: one reduction round per projection
            for (i, vi) in basis.iter().enumerate() {
                h[i][k] = gdot(comm, &w, vi);
                for j in 0..n {
                    w.data[j] -= h[i][k] * vi[j];
                }
            }
            h[k + 1][k] = gnorm(comm, &w);
            if h[k + 1][k] > 1e-300 {
                let mut vk1 = w.data.to_vec();
                for vi in vk1.iter_mut() {
                    *vi /= h[k + 1][k];
                }
                basis.push(vk1);
            }
            // apply previous rotations to column k
            for i in 0..k {
                let t = cs[i] * h[i][k] + sn[i] * h[i + 1][k];
                h[i + 1][k] = -sn[i] * h[i][k] + cs[i] * h[i + 1][k];
                h[i][k] = t;
            }
            // new rotation
            let denom = (h[k][k] * h[k][k] + h[k + 1][k] * h[k + 1][k]).sqrt();
            if denom == 0.0 {
                ct.breakdown(total_iters);
                k_used = k;
                break;
            }
            cs[k] = h[k][k] / denom;
            sn[k] = h[k + 1][k] / denom;
            h[k][k] = denom;
            h[k + 1][k] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            total_iters += 1;
            k_used = k + 1;
            let res = g[k + 1].abs();
            if opts.record_history {
                history.push(res);
            }
            ct.record(res);
            if res <= opts.tol {
                break;
            }
            if basis.len() <= k + 1 {
                break; // lucky breakdown: exact solution in span
            }
        }
        // back-substitute y from H y = g (replicated scalar work)
        let kk = k_used;
        let mut y = vec![0f64; kk];
        for i in (0..kk).rev() {
            let mut s = g[i];
            for j in i + 1..kk {
                s -= h[i][j] * y[j];
            }
            y[i] = s / h[i][i];
        }
        // x += M^{-1} (V y)
        let mut vy = vec![0f64; n];
        for (j, yj) in y.iter().enumerate() {
            for i in 0..n {
                vy[i] += yj * basis[j][i];
            }
        }
        m.apply(&vy, &mut z_ext.data[..n]);
        for i in 0..n {
            x.data[i] += z_ext[i];
        }
        // true residual for restart (z_ext doubles as the x workspace)
        z_ext.data[..n].copy_from_slice(&x);
        a.apply(&mut z_ext, &mut w);
        for i in 0..n {
            r.data[i] = b_own[i] - w[i];
        }
        beta = gnorm(comm, &r);
        if beta <= opts.tol {
            break 'outer;
        }
    }

    ct.finish(total_iters, beta, beta <= opts.tol);
    IterResult {
        x: x.take(),
        iters: total_iters,
        residual: beta,
        converged: beta <= opts.tol,
        breakdown: false,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::Identity;
    use crate::krylov::NullComm;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::util::{self, Prng};

    #[test]
    fn generic_gmres_solves_nonsymmetric_under_null_comm() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 4);
        let b = rng.normal_vec(80);
        let r = gmres(&a, &b, &Identity, 30, &NullComm, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }
}
