//! BiCGStab (van der Vorst 1992) for general (nonsymmetric) systems,
//! right-preconditioned, written once over ([`LinearOperator`],
//! [`Communicator`]).
//!
//! Five reduction rounds per full iteration: `<r0,r>`, `<r0,v>`,
//! `<s,s>`, the fused `<t,t>`/`<t,s>` pair, and `<r,r>` — the
//! recurrence's data dependencies allow no further fusing without
//! changing the algorithm.  Preconditioner application is rank-local,
//! so the same body serves the distributed wrappers unchanged.

use super::{gdot2, Communicator, LinearOperator};
use crate::iterative::{IterOpts, IterResult, Precond};
use crate::metrics::MemTracker;
use crate::sparse::kernels;
use crate::trace::{self, names as tn};
use crate::util::{axpy_inplace, dot};

/// Solve `A x = b` with right-preconditioned BiCGStab, `x0 = 0`.
pub fn bicgstab(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    comm: &dyn Communicator,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "bicgstab rhs length mismatch");

    let _sp = trace::span_arg(tn::KRYLOV_BICGSTAB, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_BICGSTAB);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut r0 = mem.buf(n);
    let mut p = mem.buf(n);
    let mut v = mem.buf(n);
    let mut s = mem.buf(n);
    let mut t = mem.buf(n);
    let mut phat_ext = mem.buf(n_ext);
    let mut shat_ext = mem.buf(n_ext);

    r.data.copy_from_slice(b_own);
    r0.data.copy_from_slice(b_own);
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut rr = comm.all_reduce_sum(dot(&r, &r));
    let tol2 = opts.tol * opts.tol;

    let mut history = Vec::new();
    if opts.record_history {
        history.push(rr.sqrt());
    }
    ct.record_sq(rr);

    let mut iters = 0;
    let mut breakdown = false;
    while iters < opts.max_iters && rr > tol2 {
        let rho_new = comm.all_reduce_sum(dot(&r0, &r));
        if rho_new == 0.0 {
            breakdown = true;
            ct.breakdown(iters);
            break;
        }
        if iters == 0 {
            p.data.copy_from_slice(&r);
        } else {
            let beta = (rho_new / rho) * (alpha / omega);
            // p = r + beta * (p - omega * v)
            for i in 0..n {
                p.data[i] = r[i] + beta * (p[i] - omega * v[i]);
            }
        }
        rho = rho_new;
        m.apply(&p, &mut phat_ext.data[..n]);
        a.apply(&mut phat_ext, &mut v);
        let r0v = comm.all_reduce_sum(dot(&r0, &v));
        if r0v == 0.0 {
            breakdown = true;
            ct.breakdown(iters);
            break;
        }
        alpha = rho / r0v;
        // s = r - alpha v and <s,s>, fused into one pass over the
        // operands; bitwise identical to the write-loop + dot pair.
        let ss = comm.all_reduce_sum(kernels::sub_scaled_norm2sq(&r, alpha, &v, &mut s.data));
        if ss <= tol2 {
            axpy_inplace(alpha, &phat_ext[..n], &mut x);
            rr = ss;
            iters += 1;
            if opts.record_history {
                history.push(rr.sqrt());
            }
            ct.record_sq(rr);
            break;
        }
        m.apply(&s, &mut shat_ext.data[..n]);
        a.apply(&mut shat_ext, &mut t);
        // <t,t> and <t,s> ride one fused round; both locals come from
        // a single pass (`kernels::dot2`).
        let fused = gdot2(comm, &t, &t, &t, &s);
        let (tt, ts) = (fused[0], fused[1]);
        if tt == 0.0 {
            breakdown = true;
            ct.breakdown(iters);
            break;
        }
        omega = ts / tt;
        // x += alpha * phat + omega * shat
        axpy_inplace(alpha, &phat_ext[..n], &mut x);
        axpy_inplace(omega, &shat_ext[..n], &mut x);
        // r = s - omega t and <r,r>, fused into one pass.
        rr = comm.all_reduce_sum(kernels::sub_scaled_norm2sq(&s, omega, &t, &mut r.data));
        iters += 1;
        if opts.record_history {
            history.push(rr.sqrt());
        }
        ct.record_sq(rr);
        if omega == 0.0 {
            breakdown = true;
            ct.breakdown(iters);
            break;
        }
    }

    ct.finish(iters, rr.sqrt(), rr <= tol2);
    IterResult {
        x: x.take(),
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        breakdown: breakdown && rr > tol2,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::Jacobi;
    use crate::krylov::NullComm;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::util::{self, Prng};

    #[test]
    fn generic_bicgstab_solves_nonsymmetric_under_null_comm() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 100, 5);
        let b = rng.normal_vec(100);
        let m = Jacobi::new(&a).unwrap();
        let r = bicgstab(&a, &b, &m, &NullComm, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }
}
