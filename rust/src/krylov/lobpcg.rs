//! LOBPCG (Knyazev 2001) in the stabilized orthogonal-basis form,
//! written once over ([`LinearOperator`], [`Communicator`]) — the
//! paper's §3.3 point made literal: the only non-local operations are
//! the operator apply and the inner products, so the serial and
//! distributed eigensolvers are ONE body.
//!
//! Per-rank data layout: every tall vector (iterates X, residuals W,
//! directions P, basis S) is the rank's owned slice; the Rayleigh–Ritz
//! problem `T = S^T A S` is assembled from all-reduced inner products
//! and solved redundantly on every rank (dense d x d with d <= 3k), so
//! all ranks stay in lockstep without broadcasts.

use super::{gdot, Communicator, LinearOperator};
use crate::eigen::dense_sym::{jacobi_eigh, matmul};
use crate::eigen::{EigResult, LobpcgOpts};
use crate::iterative::Precond;
use crate::util::Prng;

/// `k` smallest eigenpairs of the symmetric operator `a` with rank-local
/// preconditioner `m`.  Vectors in the result are this rank's owned
/// slices (globally unit-norm).
pub fn lobpcg(
    a: &dyn LinearOperator,
    m: &dyn Precond,
    k: usize,
    comm: &dyn Communicator,
    opts: &LobpcgOpts,
) -> EigResult {
    let n = a.n_own();
    let n_glob = comm.all_reduce_sum(n as f64) as usize;
    assert!(k >= 1 && 3 * k < n_glob, "lobpcg needs 3k < n");
    // rank-deterministic start vectors: every rank generates ITS slice
    // (rank 0 under NullComm reproduces the serial stream exactly)
    let mut rng = Prng::new(opts.seed ^ ((comm.rank() as u64) << 32));

    let mut x: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(n)).collect();
    orthonormalize(&mut x, comm);
    let mut p: Vec<Vec<f64>> = Vec::new();

    let mut values = vec![0f64; k];
    let mut iters = 0;
    let mut residuals = vec![f64::INFINITY; k];

    for it in 0..opts.max_iters {
        iters = it + 1;
        // Rayleigh quotients + residuals.  AX is one packed block apply
        // (one matrix traversal for all k columns on formats with a
        // fused kernel); each column is bitwise identical to a scalar
        // apply, so the iteration history is unchanged.
        let ax = apply_columns(a, &x, n);
        let mut ws: Vec<Vec<f64>> = Vec::with_capacity(k);
        let mut worst = 0.0f64;
        for j in 0..k {
            let lam = gdot(comm, &x[j], &ax[j]);
            values[j] = lam;
            let r: Vec<f64> = (0..n).map(|i| ax[j][i] - lam * x[j][i]).collect();
            let rn = gdot(comm, &r, &r).sqrt();
            residuals[j] = rn;
            worst = worst.max(rn / lam.abs().max(1.0));
            let mut z = vec![0f64; n];
            m.apply(&r, &mut z);
            ws.push(z);
        }
        if worst < opts.tol {
            break;
        }
        // basis S = [X, W, P], orthonormalized with deflation of
        // near-dependent directions
        let mut s: Vec<Vec<f64>> = Vec::with_capacity(3 * k);
        s.extend(x.iter().cloned());
        s.extend(ws);
        s.extend(p.iter().cloned());
        orthonormalize(&mut s, comm);
        let d = s.len();
        // projected operator T = S^T A S (row-major d x d, replicated);
        // AS rides the same packed block apply as AX above.
        let as_ = apply_columns(a, &s, n);
        let mut t = vec![0f64; d * d];
        for i in 0..d {
            for j in i..d {
                let v = gdot(comm, &s[i], &as_[j]);
                t[i * d + j] = v;
                t[j * d + i] = v;
            }
        }
        let (_tvals, tvecs) = jacobi_eigh(&t, d);
        // new X = S * C[:, :k] — a row-local (owned-slice) product
        let mut c = vec![0f64; d * k];
        for (j, tv) in tvecs.iter().take(k).enumerate() {
            for i in 0..d {
                c[i * k + j] = tv[i];
            }
        }
        let sc = {
            // S as (n_own x d) row-major
            let mut sm = vec![0f64; n * d];
            for (j, sj) in s.iter().enumerate() {
                for i in 0..n {
                    sm[i * d + j] = sj[i];
                }
            }
            matmul(&sm, &c, n, d, k)
        };
        let x_new: Vec<Vec<f64>> = (0..k)
            .map(|j| (0..n).map(|i| sc[i * k + j]).collect())
            .collect();
        // P = X_new - X (X^T X_new): the locally-optimal direction memory
        let mut p_new: Vec<Vec<f64>> = Vec::with_capacity(k);
        for j in 0..k {
            let mut pj = x_new[j].clone();
            for xi in &x {
                let cij = gdot(comm, xi, &x_new[j]);
                for l in 0..n {
                    pj[l] -= cij * xi[l];
                }
            }
            let np = gdot(comm, &pj, &pj).sqrt();
            if np > 1e-12 {
                for v in pj.iter_mut() {
                    *v /= np;
                }
                p_new.push(pj);
            }
        }
        x = x_new;
        orthonormalize(&mut x, comm);
        p = p_new;
    }

    // sort pairs ascending by value
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&i, &j| values[i].total_cmp(&values[j]));
    EigResult {
        values: order.iter().map(|&i| values[i]).collect(),
        vectors: order.iter().map(|&i| x[i].clone()).collect(),
        iters,
        residuals: order.iter().map(|&i| residuals[i]).collect(),
    }
}

/// Apply `a` to each column, returning one owned-slice result per
/// column.  The columns are interleaved into one block
/// ([`LinearOperator::apply_block`]) so formats with a fused
/// multi-vector kernel traverse the matrix once for the whole block;
/// the trait contract guarantees each column is bitwise identical to a
/// scalar `apply`.
fn apply_columns(a: &dyn LinearOperator, cols: &[Vec<f64>], n: usize) -> Vec<Vec<f64>> {
    let k = cols.len();
    if k == 0 {
        return Vec::new();
    }
    let mut xb = vec![0f64; n * k];
    for (j, col) in cols.iter().enumerate() {
        for (i, v) in col.iter().enumerate() {
            xb[i * k + j] = *v;
        }
    }
    let mut yb = vec![0f64; n * k];
    a.apply_block(&xb, &mut yb, k);
    (0..k)
        .map(|j| (0..n).map(|i| yb[i * k + j]).collect())
        .collect()
}

/// In-place modified Gram–Schmidt with globally-reduced inner products;
/// drops near-dependent vectors.  Identical deflation thresholds on
/// every rank keep the basis dimension in lockstep.
fn orthonormalize(vs: &mut Vec<Vec<f64>>, comm: &dyn Communicator) {
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(vs.len());
    for v in vs.drain(..) {
        let mut w = v;
        for _ in 0..2 {
            for u in &out {
                let c = gdot(comm, &w, u);
                if c != 0.0 {
                    for i in 0..w.len() {
                        w[i] -= c * u[i];
                    }
                }
            }
        }
        let nw = gdot(comm, &w, &w).sqrt();
        if nw > 1e-10 {
            for x in w.iter_mut() {
                *x /= nw;
            }
            out.push(w);
        }
    }
    *vs = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::Jacobi;
    use crate::krylov::NullComm;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn generic_lobpcg_matches_lanczos_under_null_comm() {
        let g = 10;
        let sys = poisson2d(g, None);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = lobpcg(
            &sys.matrix,
            &m,
            4,
            &NullComm,
            &LobpcgOpts {
                tol: 1e-9,
                max_iters: 300,
                seed: 0,
            },
        );
        let l = crate::eigen::lanczos(
            &sys.matrix,
            4,
            crate::eigen::lanczos::Which::Smallest,
            90,
            0,
        );
        for (a, b) in r.values.iter().zip(&l.values) {
            assert!((a - b).abs() < 1e-6 * b, "{a} vs {b}");
        }
    }
}
