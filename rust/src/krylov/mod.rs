//! Unified Krylov substrate: every Krylov recurrence in the crate is
//! written ONCE here, generic over a [`LinearOperator`] (how `y = A x`
//! is applied — serial CSR, matrix-free stencil, matrix-free Newton
//! Jacobian, or halo-exchanged distributed SpMV) and a [`Communicator`]
//! (how inner products become global — the zero-cost [`NullComm`] for
//! serial, the in-process `LocalComm` for rank teams, NCCL in the
//! paper's deployment).
//!
//! This is the paper's §3.3 observation turned into architecture: a
//! distributed solve is the *same* recurrence with halo-exchanged SpMV
//! (Eq. 5) and all-reduced dot products, so the serial and distributed
//! layers must not maintain two solver copies.  `iterative/`, `eigen/`,
//! `backend/native_iter`, `nonlinear/newton` (Newton–Krylov) and
//! `distributed/dist_solver` are all thin wrappers over these kernels.
//!
//! Communication structure is part of each kernel's contract and is
//! pinned by counter tests on `LocalComm`:
//!
//! * [`cg`] — one halo exchange (inside the operator apply) plus TWO
//!   reduction rounds per iteration: `<p,Ap>`, then `<r,z>` and `<r,r>`
//!   packed into one fused round (Appendix C, Algorithm 1).
//! * [`cg_pipelined`] — Chronopoulos–Gear CG: ONE fused round per
//!   iteration (`<r,u>`, `<w,u>`, `<r,r>` packed).
//! * [`ca_cg`] — s-step communication-avoiding CG: ONE packed round per
//!   OUTER step of `s` iterations (the whole Gram structure rides a
//!   single all_reduce), ~`1/s` rounds per iteration, with a
//!   residual-replacement guard that falls back to [`cg`] on drift.
//! * [`bicgstab`] — five rounds (`<t,t>`/`<t,s>` ride one fused round).
//! * [`gmres`] / [`minres`] / [`lobpcg`] — one round per inner product
//!   (the Gram–Schmidt/Lanczos recurrences are sequential).
//!
//! Under [`NullComm`] every kernel executes the floating-point schedule
//! of the pre-unification serial solvers (each body is the transcribed
//! historical loop; `tests/krylov_equivalence.rs` pins CG and BiCGStab
//! against frozen reference copies — same iterate counts, solutions to
//! 1e-12 — and the remaining kernels are covered by their
//! behavior-pinning unit tests).

pub mod bicgstab;
pub mod ca_cg;
pub mod cg;
pub mod comm;
pub mod gmres;
pub mod lobpcg;
pub mod minres;
pub mod op;

pub use bicgstab::bicgstab;
pub use ca_cg::{ca_cg, CaBasis, CaCgOpts, CaCgResult};
pub use cg::{cg, cg_pipelined};
pub use comm::{Communicator, NullComm};
pub use gmres::gmres;
pub use lobpcg::lobpcg;
pub use minres::minres;
pub use op::{LinearOperator, SerialOp, ShiftedOp, TransposedOp};

use crate::sparse::kernels;
use crate::util::dot;

/// Globally-reduced inner product of two owned-layout slices: ONE
/// reduction round.
#[inline]
pub fn gdot(comm: &dyn Communicator, a: &[f64], b: &[f64]) -> f64 {
    comm.all_reduce_sum(dot(a, b))
}

/// Globally-reduced Euclidean norm (matches `util::norm2` bitwise under
/// [`NullComm`]: both are `dot(x,x).sqrt()`).
#[inline]
pub fn gnorm(comm: &dyn Communicator, x: &[f64]) -> f64 {
    gdot(comm, x, x).sqrt()
}

/// Two fused global inner products — ONE local pass over the operands
/// ([`kernels::dot2`]) and ONE packed reduction round.  Local results
/// are bitwise identical to two [`gdot`] calls, so adopting this in a
/// kernel changes neither its FP schedule nor its round count (the
/// packed round was already the contract for co-available scalars).
#[inline]
pub fn gdot2(comm: &dyn Communicator, x0: &[f64], y0: &[f64], x1: &[f64], y1: &[f64]) -> [f64; 2] {
    let mut fused = kernels::dot2(x0, y0, x1, y1);
    comm.all_reduce(&mut fused);
    fused
}

/// Three fused global inner products (the pipelined-CG triple): one
/// local pass ([`kernels::dot3`]), one packed reduction round, bitwise
/// identical locals to three [`gdot`] calls.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn gdot3(
    comm: &dyn Communicator,
    x0: &[f64],
    y0: &[f64],
    x1: &[f64],
    y1: &[f64],
    x2: &[f64],
    y2: &[f64],
) -> [f64; 3] {
    let mut fused = kernels::dot3(x0, y0, x1, y1, x2, y2);
    comm.all_reduce(&mut fused);
    fused
}
