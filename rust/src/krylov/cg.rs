//! Preconditioned conjugate gradient (Hestenes & Stiefel 1952) and its
//! single-reduction (Chronopoulos & Gear 1989, "pipelined") variant,
//! written once over ([`LinearOperator`], [`Communicator`]).
//!
//! Communication contract, pinned by the counter test on `LocalComm`:
//!
//! * [`cg`]: per iteration ONE operator apply (one halo exchange when
//!   distributed) and TWO reduction rounds — `<p,Ap>`, then `<r,z>` and
//!   `<r,r>` packed into one fused round (Appendix C, Algorithm 1).
//! * [`cg_pipelined`]: per iteration one apply and ONE fused round
//!   (`<r,u>`, `<w,u>`, `<r,r>`) — algebraically equivalent, half the
//!   reduction latency.
//!
//! Under `NullComm` the [`cg`] body executes the exact FP schedule of
//! the pre-unification serial CG (see `tests/krylov_equivalence.rs`).

use super::{gdot2, gdot3, Communicator, LinearOperator};
use crate::iterative::{IterOpts, IterResult, Precond};
use crate::metrics::MemTracker;
use crate::trace::{self, names as tn};
use crate::util::dot;

/// Solve `A x = b` with preconditioned CG, `x0 = 0`.  `b_own` is this
/// rank's owned slice of the right-hand side; the returned iterate has
/// the same layout.
pub fn cg(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    comm: &dyn Communicator,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "cg rhs length mismatch");

    let _sp = trace::span_arg(tn::KRYLOV_CG, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_CG);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut z = mem.buf(n);
    let mut p_ext = mem.buf(n_ext);
    let mut ap = mem.buf(n);

    r.data.copy_from_slice(b_own); // r = b - A*0
    m.apply(&r, &mut z);
    p_ext.data[..n].copy_from_slice(&z);
    // <r,z> and <r,r> ride one fused setup round; gdot2 computes both
    // locals in a single pass over the operands, bitwise identical to
    // two separate `dot` calls.
    let fused = gdot2(comm, &r, &z, &r, &r);
    let (mut rz, mut rr) = (fused[0], fused[1]);
    let tol2 = opts.tol * opts.tol;

    let mut history = Vec::new();
    if opts.record_history {
        history.push(rr.sqrt());
    }
    ct.record_sq(rr);

    let mut iters = 0;
    let mut breakdown = false;
    // rsla-lint: no_alloc
    while iters < opts.max_iters && rr > tol2 {
        a.apply(&mut p_ext, &mut ap);
        let pap = comm.all_reduce_sum(dot(&p_ext[..n], &ap));
        if pap <= 0.0 || !pap.is_finite() {
            // operator not SPD (or breakdown): stop with the current
            // iterate, and SAY SO — callers must be able to tell this
            // apart from an exhausted iteration budget
            breakdown = true;
            ct.breakdown(iters);
            break;
        }
        let alpha = rz / pap;
        for i in 0..n {
            x.data[i] += alpha * p_ext[i];
            r.data[i] -= alpha * ap[i];
        }
        m.apply(&r, &mut z);
        // <r,z> and <r,r> are available at the same point of the
        // recurrence, so they ride ONE fused all_reduce (a packed
        // 2-scalar NCCL buffer) — Algorithm 1's "two all_reduce per
        // iteration" is exactly <p,Ap> plus this fused pair.  The
        // locals come from one fused pass (`kernels::dot2`), which is
        // bitwise identical to two separate `dot` calls.
        let fused = gdot2(comm, &r, &z, &r, &r);
        let (rz_new, rr_new) = (fused[0], fused[1]);
        let beta = rz_new / rz;
        for i in 0..n {
            p_ext.data[i] = z[i] + beta * p_ext[i];
        }
        rz = rz_new;
        rr = rr_new;
        iters += 1;
        if opts.record_history {
            history.push(rr.sqrt());
        }
        ct.record_sq(rr);
    }

    ct.finish(iters, rr.sqrt(), rr <= tol2);
    IterResult {
        x: x.take(),
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        breakdown: breakdown && rr > tol2,
        history,
    }
}

/// Single-reduction CG (Chronopoulos & Gear 1989): algebraically
/// equivalent to [`cg`] but restructured so each iteration's inner
/// products — `<r,u>`, `<w,u>` and the `<r,r>` convergence check — ride
/// ONE fused reduction round, halving the per-iteration latency that
/// dominates at large P.  Only the reductions are reorganized, not the
/// operator apply, so it composes with the transposed-halo backward
/// pass unchanged (Appendix C).
pub fn cg_pipelined(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    comm: &dyn Communicator,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "cg_pipelined rhs length mismatch");

    let _sp = trace::span_arg(tn::KRYLOV_CG_PIPELINED, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_CG_PIPELINED);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    // u = M^-1 r lives in the extended layout: it is the vector whose
    // halo must be current for w = A u.
    let mut u_ext = mem.buf(n_ext);
    let mut w = mem.buf(n);
    let mut p = mem.buf(n);
    let mut s = mem.buf(n); // s = A p

    r.data.copy_from_slice(b_own);
    m.apply(&r, &mut u_ext.data[..n]);
    a.apply(&mut u_ext, &mut w);

    let fused = gdot3(comm, &r, &u_ext[..n], &w, &u_ext[..n], &r, &r);
    let (mut gamma, delta0, mut rr) = (fused[0], fused[1], fused[2]);

    let mut alpha = if delta0 > 0.0 { gamma / delta0 } else { 0.0 };
    let mut beta = 0.0_f64;
    let tol2 = opts.tol * opts.tol;

    let mut history = Vec::new();
    if opts.record_history {
        history.push(rr.sqrt());
    }
    ct.record_sq(rr);

    let mut iters = 0;
    let mut breakdown = false;
    // rsla-lint: no_alloc
    while iters < opts.max_iters && rr > tol2 && alpha.is_finite() && alpha != 0.0 {
        // p = u + beta p ; s = w + beta s  (beta = 0 on the first pass)
        for i in 0..n {
            p.data[i] = u_ext[i] + beta * p[i];
            s.data[i] = w[i] + beta * s[i];
        }
        // x += alpha p ; r -= alpha s ; u = M^-1 r
        for i in 0..n {
            x.data[i] += alpha * p[i];
            r.data[i] -= alpha * s[i];
        }
        m.apply(&r, &mut u_ext.data[..n]);
        // w = A u (one halo exchange when distributed)
        a.apply(&mut u_ext, &mut w);
        // ONE fused reduction: gamma_new = <r,u>, delta = <w,u>, rr —
        // all three locals from a single pass (`kernels::dot3`),
        // bitwise identical to three separate `dot` calls.
        let fused = gdot3(comm, &r, &u_ext[..n], &w, &u_ext[..n], &r, &r);
        let (gamma_new, delta, rr_new) = (fused[0], fused[1], fused[2]);
        rr = rr_new;
        iters += 1;
        if opts.record_history {
            history.push(rr.sqrt());
        }
        ct.record_sq(rr);
        if rr <= tol2 {
            break;
        }
        beta = gamma_new / gamma;
        let denom = delta - beta / alpha * gamma_new;
        if denom <= 0.0 || !denom.is_finite() {
            breakdown = true;
            ct.breakdown(iters);
            break; // breakdown: report the current iterate
        }
        alpha = gamma_new / denom;
        gamma = gamma_new;
    }

    ct.finish(iters, rr.sqrt(), rr <= tol2);
    IterResult {
        x: x.take(),
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        breakdown: breakdown && rr > tol2,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::krylov::NullComm;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn generic_cg_solves_poisson_under_null_comm() {
        let g = 16;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = cg(&sys.matrix, &b, &m, &NullComm, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-9);
    }

    #[test]
    fn serial_pipelined_cg_matches_standard_cg() {
        let g = 20;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let std = cg(&sys.matrix, &b, &m, &NullComm, &IterOpts::default(), None);
        let pip = cg_pipelined(&sys.matrix, &b, &m, &NullComm, &IterOpts::default(), None);
        assert!(std.converged && pip.converged);
        assert!(util::rel_l2(&pip.x, &std.x) < 1e-6);
        assert!(
            (std.iters as i64 - pip.iters as i64).abs() <= 3,
            "iters diverged: {} vs {}",
            std.iters,
            pip.iters
        );
    }

    #[test]
    fn pipelined_cg_respects_budget() {
        let g = 24;
        let sys = poisson2d(g, None);
        let r = cg_pipelined(
            &sys.matrix,
            &vec![1.0; g * g],
            &Identity,
            &NullComm,
            &IterOpts {
                tol: 1e-14,
                max_iters: 10,
                record_history: true,
            },
            None,
        );
        assert!(!r.converged);
        assert!(r.iters <= 10);
        assert!(r.history.iter().all(|h| h.is_finite()));
    }
}
