//! s-step communication-avoiding CG (Chronopoulos & Gear 1989 s-step
//! form; Hoemmen 2010; Carson & Demmel 2014 residual replacement),
//! written over ([`LinearOperator`], [`Communicator`]) like every other
//! kernel in this module.
//!
//! Communication contract (pinned by the counter tests in
//! `tests/krylov_equivalence.rs` and `benches/dist_scaling.rs`):
//!
//! * per OUTER step: `s` operator applies (s halo exchanges when
//!   distributed) and exactly ONE packed reduction round carrying the
//!   whole Gram structure — `sym(V^T AV)` (upper triangle),
//!   `(AP_prev)^T V`, `V^T r`, `P_prev^T r`, and `<r,r>` — i.e. ~`1/s`
//!   reduction rounds per CG iteration, vs 2 for [`super::cg`] and 1
//!   for [`super::cg_pipelined`].
//! * the residual-replacement guard adds one apply + one 2-scalar
//!   round every [`CaCgOpts::guard_every`] outer steps.
//! * the Newton basis adds 3 applies + 4 rounds ONCE per solve.
//!
//! Recurrence per outer step (`M` the preconditioner, monomial shifts
//! `theta = 0`):
//!
//! ```text
//! v_0 = M^-1 r;   v_{i+1} = M^-1 (A v_i) - theta_i v_i
//! G = sym(V^T AV);  C = (AP_prev)^T V;  gV = V^T r;  gP = P_prev^T r
//! B = -W_prev^-1 C                (Cholesky, column by column)
//! P = V + P_prev B;  AP = AV + AP_prev B
//! W = sym(G + C^T B)              (B^T W_prev B = -B^T C cancels B^T C)
//! a = W^-1 (gV + B^T gP)          (Cholesky)
//! x += P a;  r -= AP a
//! ```
//!
//! Finite-precision safety: the monomial basis conditions like a power
//! iteration, so large `s` can make `W` numerically rank-deficient.
//! Three independent guards keep the kernel honest instead of silently
//! returning a drifted iterate:
//!
//! 1. Cholesky breakdown (non-SPD pivot) in either small solve falls
//!    back to standard CG from the current iterate.
//! 2. The residual-replacement guard compares the RECURRED `<r,r>`
//!    against the TRUE `||b - A x||` every `guard_every` outer steps;
//!    on drift it replaces `r` and restarts the conjugation history,
//!    and after two consecutive drifts it falls back.
//! 3. The Newton basis (Chebyshev shifts of an estimated spectral
//!    interval, Leja-ordered) is selected automatically for `s > 4`,
//!    where the monomial basis degrades.
//!
//! Every floating-point reduction entry is a pinned-schedule
//! `util::dot` over contiguous columns (`sparse::kernels::gram_*`) and
//! all fold orders are fixed, so a CA-CG trajectory is bitwise
//! reproducible across runs and transport backends.

use super::{gnorm, Communicator, LinearOperator};
use crate::iterative::{IterOpts, IterResult, Precond};
use crate::metrics::MemTracker;
use crate::sparse::kernels;
use crate::trace::{self, names as tn};

/// Krylov basis polynomial for the s-step block.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CaBasis {
    /// Monomial for `s <= 4`, Newton above (where monomial degrades).
    #[default]
    Auto,
    /// `v_{i+1} = M^-1 A v_i` — zero extra setup cost, fine for small s.
    Monomial,
    /// Shifted basis `v_{i+1} = M^-1 A v_i - theta_i v_i` with
    /// Leja-ordered Chebyshev points of the estimated spectral
    /// interval; costs 3 applies + 4 reduction rounds once per solve.
    Newton,
}

#[derive(Clone, Debug)]
pub struct CaCgOpts {
    /// Basis block size: iterations advanced per reduction round.
    pub s: usize,
    pub basis: CaBasis,
    /// Run the residual-replacement check every this many outer steps
    /// (0 disables the guard).
    pub guard_every: usize,
    /// Drift threshold: replace when `||b - Ax|| > guard_factor *
    /// ||r_recurred||`.  A non-positive value forces the guard on every
    /// check (the fallback-path test hook).
    pub guard_factor: f64,
}

impl Default for CaCgOpts {
    fn default() -> Self {
        CaCgOpts {
            s: 4,
            basis: CaBasis::Auto,
            guard_every: 8,
            guard_factor: 10.0,
        }
    }
}

/// [`IterResult`] plus the CA-specific diagnostics the dist report and
/// the equivalence tests read.
#[derive(Debug)]
pub struct CaCgResult {
    pub iter: IterResult,
    /// Completed outer steps (each = one packed reduction round).
    pub outer_steps: usize,
    /// Residual replacements the drift guard performed.
    pub replacements: usize,
    /// True when the solve finished under standard CG (basis breakdown
    /// or persistent drift).
    pub fell_back: bool,
}

/// Deterministic dense Cholesky of a row-major `s x s` SPD matrix into
/// `l` (lower triangle, row-major).  Returns false on a non-SPD pivot
/// — the caller treats that as basis breakdown, not an error.
fn chol_factor(w: &[f64], s: usize, l: &mut [f64]) -> bool {
    l.fill(0.0);
    for j in 0..s {
        let mut d = w[j * s + j];
        for k in 0..j {
            d -= l[j * s + k] * l[j * s + k];
        }
        if !d.is_finite() || d <= 1e-14 * w[j * s + j].abs().max(1e-300) {
            return false;
        }
        let dj = d.sqrt();
        l[j * s + j] = dj;
        for i in (j + 1)..s {
            let mut v = w[i * s + j];
            for k in 0..j {
                v -= l[i * s + k] * l[j * s + k];
            }
            l[i * s + j] = v / dj;
        }
    }
    true
}

/// Solve `L L^T a = rhs` in place (`rhs` becomes `a`), `y` is scratch.
fn chol_solve(l: &[f64], s: usize, rhs: &mut [f64], y: &mut [f64]) {
    for i in 0..s {
        let mut v = rhs[i];
        for k in 0..i {
            v -= l[i * s + k] * y[k];
        }
        y[i] = v / l[i * s + i];
    }
    for i in (0..s).rev() {
        let mut v = y[i];
        for k in (i + 1)..s {
            v -= l[k * s + i] * rhs[k];
        }
        rhs[i] = v / l[i * s + i];
    }
}

/// Newton-basis shifts: Chebyshev points of `[0, 1.05 * lambda_max]`
/// (power-iteration estimate of `M^-1 A`), Leja-ordered so partial
/// products stay well-scaled.  Costs 3 applies + 4 reduction rounds.
fn newton_shifts(
    a: &dyn LinearOperator,
    m: &dyn Precond,
    comm: &dyn Communicator,
    s: usize,
    v_ext: &mut [f64],
    w: &mut [f64],
    thetas: &mut [f64],
) {
    let n = a.n_own();
    v_ext[..n].fill(1.0);
    v_ext[n..].fill(0.0);
    let g0 = gnorm(comm, &v_ext[..n]);
    if g0 > 0.0 {
        for v in v_ext[..n].iter_mut() {
            *v /= g0;
        }
    }
    let mut lam = 1.0;
    for _ in 0..3 {
        a.apply(v_ext, w);
        m.apply(w, &mut v_ext[..n]);
        lam = gnorm(comm, &v_ext[..n]);
        if !(lam.is_finite() && lam > 0.0) {
            lam = 1.0;
            break;
        }
        for v in v_ext[..n].iter_mut() {
            *v /= lam;
        }
    }
    let lmax = lam * 1.05;
    let sf = s as f64;
    for (k, t) in thetas.iter_mut().enumerate() {
        let ang = (2.0 * k as f64 + 1.0) * std::f64::consts::PI / (2.0 * sf);
        *t = lmax / 2.0 * (1.0 - ang.cos());
    }
    // Leja order in place: pick the largest magnitude first, then
    // greedily maximize the product of distances to the chosen prefix.
    for chosen in 0..s {
        let mut best = chosen;
        let mut best_score = f64::NEG_INFINITY;
        for i in chosen..s {
            let score = if chosen == 0 {
                thetas[i].abs()
            } else {
                let mut prod = 1.0;
                for t in thetas.iter().take(chosen) {
                    prod *= (thetas[i] - t).abs();
                }
                prod
            };
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        thetas.swap(chosen, best);
    }
}

/// Solve `A x = b` with s-step CA-CG, `x0 = 0`.  `b_own` is this rank's
/// owned slice of the right-hand side; the returned iterate has the
/// same layout.  `opts.record_history` records one residual per OUTER
/// step (that is where the recurred `<r,r>` is globally available).
pub fn ca_cg(
    a: &dyn LinearOperator,
    b_own: &[f64],
    m: &dyn Precond,
    comm: &dyn Communicator,
    opts: &IterOpts,
    ca: &CaCgOpts,
    mem: Option<&MemTracker>,
) -> CaCgResult {
    let n = a.n_own();
    let n_ext = a.n_ext();
    assert_eq!(n, b_own.len(), "ca_cg rhs length mismatch");
    let s = ca.s.max(1);
    let newton = match ca.basis {
        CaBasis::Monomial => false,
        CaBasis::Newton => true,
        CaBasis::Auto => s > 4,
    };

    let _sp = trace::span_arg(tn::KRYLOV_CA_CG, n as u64);
    let mut ct = trace::ConvergenceTrace::new(tn::KRYLOV_CA_CG);
    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut t = mem.buf(n); // apply output / true-residual scratch
    let mut ext = mem.buf(n_ext); // one extended buffer for every apply
    let mut v = mem.buf(n * s);
    let mut av = mem.buf(n * s);
    let mut p = mem.buf(n * s);
    let mut ap = mem.buf(n * s);
    let mut pn = mem.buf(n * s);
    let mut apn = mem.buf(n * s);

    // Packed one-round reduction layout (fixed width; the C / gP
    // sections are zero while no conjugation history exists):
    // [ G upper s(s+1)/2 | C s*s | gV s | gP s | rr 1 ]
    let nup = s * (s + 1) / 2;
    let (o_c, o_gv, o_gp, o_rr) = (nup, nup + s * s, nup + s * s + s, nup + s * s + 2 * s);
    let mut packed = vec![0.0; o_rr + 1];
    let mut w_full = vec![0.0; s * s];
    let mut l_prev = vec![0.0; s * s]; // Cholesky factor of W_prev
    let mut l = vec![0.0; s * s];
    let mut b_mat = vec![0.0; s * s];
    let mut coef = vec![0.0; s];
    let mut col = vec![0.0; s];
    let mut y = vec![0.0; s];
    let mut thetas = vec![0.0; s];

    r.data.copy_from_slice(b_own);
    if newton {
        newton_shifts(a, m, comm, s, &mut ext.data, &mut t.data, &mut thetas);
    }

    let tol2 = opts.tol * opts.tol;
    let mut history = Vec::new();
    let mut iters = 0usize;
    let mut outer = 0usize;
    let mut replacements = 0usize;
    let mut consec_drift = 0usize;
    let mut fell_back = false;
    let mut have_prev = false;
    let mut rr = f64::INFINITY;

    // rsla-lint: no_alloc
    while iters < opts.max_iters {
        // ---- basis block: s applies, no communication beyond halos
        m.apply(&r, &mut v.data[..n]);
        for i in 0..s {
            ext.data[..n].copy_from_slice(&v[i * n..(i + 1) * n]);
            ext.data[n..].fill(0.0);
            a.apply(&mut ext, &mut t);
            av.data[i * n..(i + 1) * n].copy_from_slice(&t);
            if i + 1 < s {
                let (lo, hi) = v.data.split_at_mut((i + 1) * n);
                m.apply(&t, &mut hi[..n]);
                if thetas[i] != 0.0 {
                    let th = thetas[i];
                    let prev = &lo[i * n..(i + 1) * n];
                    for (vn, &vp) in hi[..n].iter_mut().zip(prev) {
                        *vn -= th * vp;
                    }
                }
            }
        }
        // ---- the ONE packed reduction round of this outer step
        kernels::gram_upper(&v, &av, n, s, &mut packed[..nup]);
        if have_prev {
            kernels::gram_cross(&ap, &v, n, s, &mut packed[o_c..o_gv]);
            kernels::block_dot_vec(&p, n, s, &r, &mut packed[o_gp..o_rr]);
        } else {
            packed[o_c..o_gv].fill(0.0);
            packed[o_gp..o_rr].fill(0.0);
        }
        kernels::block_dot_vec(&v, n, s, &r, &mut packed[o_gv..o_gp]);
        packed[o_rr] = crate::util::dot(&r, &r);
        comm.all_reduce(&mut packed);
        rr = packed[o_rr];
        if opts.record_history {
            history.push(rr.sqrt());
        }
        ct.record_sq(rr);
        if rr <= tol2 {
            break;
        }
        // unpack sym(G) from the upper triangle
        {
            let mut k = 0;
            for i in 0..s {
                for j in i..s {
                    w_full[i * s + j] = packed[k];
                    w_full[j * s + i] = packed[k];
                    k += 1;
                }
            }
        }
        if have_prev {
            // B = -W_prev^-1 C, column by column through the cached
            // Cholesky factor of W_prev
            for j in 0..s {
                for i in 0..s {
                    col[i] = packed[o_c + i * s + j];
                }
                chol_solve(&l_prev, s, &mut col, &mut y);
                for i in 0..s {
                    b_mat[i * s + j] = -col[i];
                }
            }
            // W = sym(G + C^T B): the B^T W_prev B term cancels B^T C
            // exactly (W_prev B = -C), so only the cross term remains.
            for i in 0..s {
                for j in i..s {
                    let mut cij = 0.0;
                    for k in 0..s {
                        cij += packed[o_c + k * s + i] * b_mat[k * s + j];
                    }
                    let wij = w_full[i * s + j] + cij;
                    w_full[i * s + j] = wij;
                    w_full[j * s + i] = wij;
                }
            }
            // g = gV + B^T gP
            for j in 0..s {
                let mut gj = packed[o_gv + j];
                for k in 0..s {
                    gj += b_mat[k * s + j] * packed[o_gp + k];
                }
                coef[j] = gj;
            }
            kernels::block_combine(&v, &p, &b_mat, n, s, &mut pn.data);
            kernels::block_combine(&av, &ap, &b_mat, n, s, &mut apn.data);
            std::mem::swap(&mut p.data, &mut pn.data);
            std::mem::swap(&mut ap.data, &mut apn.data);
        } else {
            p.data.copy_from_slice(&v);
            ap.data.copy_from_slice(&av);
            coef.copy_from_slice(&packed[o_gv..o_gp]);
        }
        if !chol_factor(&w_full, s, &mut l) {
            // numerically rank-deficient basis block: stop advancing
            // the s-step recurrence and finish under standard CG
            fell_back = true;
            ct.breakdown(iters);
            break;
        }
        chol_solve(&l, s, &mut coef, &mut y);
        kernels::block_update_xr(&p, &ap, n, s, &coef, &mut x.data, &mut r.data);
        l_prev.copy_from_slice(&l);
        have_prev = true;
        iters += s;
        outer += 1;
        // ---- residual-replacement guard: one apply + one 2-scalar round
        if ca.guard_every != 0 && outer % ca.guard_every == 0 {
            ext.data[..n].copy_from_slice(&x);
            ext.data[n..].fill(0.0);
            a.apply(&mut ext, &mut t);
            for (ti, &bi) in t.data.iter_mut().zip(b_own) {
                *ti = bi - *ti;
            }
            let mut tr = [crate::util::dot(&t, &t), crate::util::dot(&r, &r)];
            comm.all_reduce(&mut tr);
            let drift = ca.guard_factor <= 0.0 || tr[0].sqrt() > ca.guard_factor * tr[1].sqrt();
            if drift {
                consec_drift += 1;
                replacements += 1;
                trace::event(tn::KRYLOV_CA_REPLACE, outer as u64);
                r.data.copy_from_slice(&t);
                have_prev = false; // restart conjugation after replacement
                if consec_drift >= 2 {
                    fell_back = true;
                    break;
                }
            } else {
                consec_drift = 0;
            }
        }
    }

    if fell_back {
        trace::event(tn::KRYLOV_CA_FALLBACK, iters as u64);
        // finish from the current iterate: solve A dx = b - A x with
        // standard CG and add the correction
        ext.data[..n].copy_from_slice(&x);
        ext.data[n..].fill(0.0);
        a.apply(&mut ext, &mut t);
        for (ti, &bi) in t.data.iter_mut().zip(b_own) {
            *ti = bi - *ti;
        }
        let sub = super::cg(
            a,
            &t,
            m,
            comm,
            &IterOpts {
                tol: opts.tol,
                max_iters: opts.max_iters.saturating_sub(iters),
                record_history: opts.record_history,
            },
            Some(mem),
        );
        for (xi, &di) in x.data.iter_mut().zip(&sub.x) {
            *xi += di;
        }
        iters += sub.iters;
        rr = sub.residual * sub.residual;
        history.extend(sub.history);
    }

    ct.finish(iters, rr.sqrt(), rr <= tol2);
    CaCgResult {
        iter: IterResult {
            x: x.take(),
            iters,
            residual: rr.sqrt(),
            converged: rr <= tol2,
            breakdown: false,
            history,
        },
        outer_steps: outer,
        replacements,
        fell_back,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::Jacobi;
    use crate::krylov::{cg, NullComm};
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    fn setup(g: usize, seed: u64) -> (crate::sparse::poisson::PoissonSystem, Vec<f64>, Jacobi) {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(seed);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        (sys, b, m)
    }

    #[test]
    fn ca_cg_matches_standard_cg_for_small_s() {
        let (sys, b, m) = setup(16, 0);
        let std = cg(&sys.matrix, &b, &m, &NullComm, &IterOpts::default(), None);
        for s in [2usize, 4] {
            let ca = ca_cg(
                &sys.matrix,
                &b,
                &m,
                &NullComm,
                &IterOpts::default(),
                &CaCgOpts {
                    s,
                    ..Default::default()
                },
                None,
            );
            assert!(ca.iter.converged, "s={s}: {}", ca.iter.residual);
            assert!(!ca.fell_back, "s={s} should not need the fallback");
            assert!(util::rel_l2(&ca.iter.x, &std.x) < 1e-6, "s={s}");
            // same Krylov space: iteration counts agree within one block
            assert!(
                (ca.iter.iters as i64 - std.iters as i64).abs() <= s as i64,
                "s={s}: iters {} vs std {}",
                ca.iter.iters,
                std.iters
            );
            // round structure: outer steps ~= iters / s
            assert_eq!(ca.outer_steps, ca.iter.iters.div_ceil(s));
        }
    }

    #[test]
    fn ca_cg_newton_basis_holds_at_s8() {
        let (sys, b, m) = setup(24, 3);
        let std = cg(&sys.matrix, &b, &m, &NullComm, &IterOpts::default(), None);
        // Auto resolves to Newton at s=8
        let ca = ca_cg(
            &sys.matrix,
            &b,
            &m,
            &NullComm,
            &IterOpts::default(),
            &CaCgOpts {
                s: 8,
                ..Default::default()
            },
            None,
        );
        assert!(ca.iter.converged);
        assert!(util::rel_l2(&sys.matrix.matvec(&ca.iter.x), &b) < 1e-8);
        assert!(
            ca.iter.iters <= std.iters + 16,
            "newton basis at s=8 must stay near CG's iteration count: {} vs {}",
            ca.iter.iters,
            std.iters
        );
    }

    #[test]
    fn ca_cg_is_bitwise_deterministic_across_runs() {
        let (sys, b, m) = setup(12, 5);
        let run = || {
            ca_cg(
                &sys.matrix,
                &b,
                &m,
                &NullComm,
                &IterOpts::default(),
                &CaCgOpts::default(),
                None,
            )
        };
        let (a1, a2) = (run(), run());
        assert_eq!(a1.iter.iters, a2.iter.iters);
        for (p, q) in a1.iter.x.iter().zip(&a2.iter.x) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn forced_guard_replaces_then_falls_back_and_still_converges() {
        let (sys, b, m) = setup(16, 7);
        let ca = ca_cg(
            &sys.matrix,
            &b,
            &m,
            &NullComm,
            &IterOpts::default(),
            &CaCgOpts {
                s: 4,
                guard_every: 2,
                guard_factor: 0.0, // force the drift verdict every check
                ..Default::default()
            },
            None,
        );
        assert!(ca.fell_back, "forced guard must trip the fallback");
        assert_eq!(ca.replacements, 2, "two consecutive drifts then fallback");
        assert!(ca.iter.converged, "fallback CG must still converge");
        assert!(util::rel_l2(&sys.matrix.matvec(&ca.iter.x), &b) < 1e-8);
    }

    #[test]
    fn ca_cg_respects_iteration_budget() {
        let (sys, b, m) = setup(24, 9);
        let ca = ca_cg(
            &sys.matrix,
            &b,
            &m,
            &NullComm,
            &IterOpts {
                tol: 1e-14,
                max_iters: 12,
                record_history: true,
            },
            &CaCgOpts::default(),
            None,
        );
        assert!(!ca.iter.converged);
        assert!(ca.iter.iters <= 12 + 4, "budget overshoot bounded by one block");
        assert!(ca.iter.history.iter().all(|h| h.is_finite()));
    }
}
