//! The [`LinearOperator`] trait: how a Krylov kernel applies `A` (and
//! `A^T`) in the *extended* vector layout that distributed operators
//! need.
//!
//! Layout contract: a rank owns `n_own` entries; the operator may need
//! `n_ext >= n_own` slots of workspace, where `[n_own, n_ext)` are halo
//! copies of remote entries the apply refreshes itself (serial
//! operators have `n_ext == n_own` and the extended layout degenerates
//! to the plain one).  Kernels allocate any vector that feeds `apply`
//! at length `n_ext`, keep its owned prefix current, and never read the
//! halo tail themselves.
//!
//! Implementations here: [`SerialOp`] (bridge from the crate's existing
//! [`LinOp`] matrix/matrix-free operators), [`ShiftedOp`] (`A - sigma
//! I`, local in any layout) and [`TransposedOp`] (`A^T`, for adjoint
//! solves through the same kernels).  The distributed implementation
//! (`DistOp`: halo-exchanged SpMV over a `DistCsr` share, Eq. 5-6)
//! lives in `distributed::op` next to the halo machinery.

use crate::iterative::LinOp;
use crate::sparse::Csr;

/// A square linear operator in the extended (owned + halo) layout.
pub trait LinearOperator {
    /// Entries owned by this rank: the length of result vectors and of
    /// the owned prefix of extended-layout inputs.
    fn n_own(&self) -> usize;

    /// Extended workspace length (owned + halo); `n_own` for serial.
    fn n_ext(&self) -> usize {
        self.n_own()
    }

    /// `y = A x`.  `x_ext[..n_own]` holds the owned entries; the
    /// operator may refresh `x_ext[n_own..]` (halo slots) as a side
    /// effect — which is exactly the one halo exchange per SpMV of the
    /// paper's Algorithm 1.
    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]);

    /// `gx = A^T gy`, owned layout on both sides (the transposed-halo
    /// backward path, Eq. 6).  Default panics for operators without an
    /// adjoint, mirroring [`LinOp::apply_t`].
    fn apply_adjoint(&self, _gy_own: &[f64], _gx_own: &mut [f64]) {
        panic!("apply_adjoint not implemented for this operator"); // rsla-lint: allow(L1, documented contract mirroring LinOp::apply_t)
    }

    /// Block apply: `Y = A X` for `k` interleaved owned-layout columns
    /// (`x_own[i * k + j]` is row `i` of column `j`; `x_own` has length
    /// `n_own * k`, `y_own` length `n_own * k`).
    ///
    /// The default loops columns through [`LinearOperator::apply`]
    /// (allocating per-call scratch), so every operator supports it;
    /// operators with a fused multi-vector kernel override it to make
    /// one matrix pass serve all `k` columns (LOBPCG blocks, the
    /// engine's multi-RHS fusion).  Overrides must keep each column
    /// bitwise identical to a scalar `apply` on that column — callers
    /// rely on block/scalar interchangeability.
    fn apply_block(&self, x_own: &[f64], y_own: &mut [f64], k: usize) {
        let n = self.n_own();
        debug_assert_eq!(x_own.len(), n * k);
        debug_assert_eq!(y_own.len(), n * k);
        let mut col_ext = vec![0.0; self.n_ext()];
        let mut col_y = vec![0.0; n];
        for j in 0..k {
            for (i, slot) in col_ext[..n].iter_mut().enumerate() {
                *slot = x_own[i * k + j];
            }
            self.apply(&mut col_ext, &mut col_y);
            for (i, &yi) in col_y.iter().enumerate() {
                y_own[i * k + j] = yi;
            }
        }
    }
}

/// A serial CSR matrix is a [`LinearOperator`] with an empty halo.
impl LinearOperator for Csr {
    fn n_own(&self) -> usize {
        self.nrows
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.spmv(x_ext, y_own);
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        self.spmv_t(gy_own, gx_own);
    }

    /// Fused multi-RHS SpMV: one pass over `vals`/`indices` for all `k`
    /// columns, each column bitwise identical to a scalar [`Csr::spmv`].
    fn apply_block(&self, x_own: &[f64], y_own: &mut [f64], k: usize) {
        crate::sparse::kernels::spmv_block(self, x_own, y_own, k);
    }
}

/// Bridge from any [`LinOp`] (CSR, matrix-free stencil, autograd-JVP
/// Jacobians, deflated operators...) to the extended-layout trait.  The
/// serial entry points in `iterative/` and `eigen/` wrap their operator
/// in this and pair it with [`super::NullComm`].
pub struct SerialOp<'a>(pub &'a dyn LinOp);

impl LinearOperator for SerialOp<'_> {
    fn n_own(&self) -> usize {
        self.0.nrows()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.0.apply(x_ext, y_own);
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        self.0.apply_t(gy_own, gx_own);
    }
}

/// `A - sigma I` over any operator, serial or distributed: the shift
/// acts on owned entries only, so it composes with halo exchange
/// unchanged (used for shift-invert style spectral probes and the
/// symmetric-indefinite MINRES scenarios).
pub struct ShiftedOp<'a> {
    pub op: &'a dyn LinearOperator,
    pub sigma: f64,
}

impl LinearOperator for ShiftedOp<'_> {
    fn n_own(&self) -> usize {
        self.op.n_own()
    }

    fn n_ext(&self) -> usize {
        self.op.n_ext()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.op.apply(x_ext, y_own);
        for (yi, xi) in y_own.iter_mut().zip(x_ext.iter()) {
            *yi -= self.sigma * xi;
        }
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        self.op.apply_adjoint(gy_own, gx_own);
        for (gi, yi) in gx_own.iter_mut().zip(gy_own) {
            *gi -= self.sigma * yi;
        }
    }
}

/// `A^T` as a [`LinearOperator`]: routes adjoint solves (`A^T lambda =
/// dL/dx`, Eq. 3) through the same generic kernels as forward solves.
pub struct TransposedOp<'a>(pub &'a dyn LinearOperator);

impl LinearOperator for TransposedOp<'_> {
    fn n_own(&self) -> usize {
        self.0.n_own()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.0.apply_adjoint(&x_ext[..self.0.n_own()], y_own);
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        // (A^T)^T = A; needs the extended layout only for the halo tail,
        // which serial operators do not have.
        let mut x_ext = vec![0.0; self.0.n_ext()];
        x_ext[..gy_own.len()].copy_from_slice(gy_own);
        self.0.apply(&mut x_ext, gx_own);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn csr_and_serial_op_agree() {
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(64);
        let mut x_ext = x.clone();
        let mut y1 = vec![0.0; 64];
        let mut y2 = vec![0.0; 64];
        LinearOperator::apply(&sys.matrix, &mut x_ext, &mut y1);
        SerialOp(&sys.matrix).apply(&mut x_ext, &mut y2);
        assert_eq!(y1, y2);
        assert_eq!(y1, sys.matrix.matvec(&x));
        assert_eq!(LinearOperator::n_own(&sys.matrix), 64);
        assert_eq!(LinearOperator::n_ext(&sys.matrix), 64);
    }

    #[test]
    fn apply_block_override_is_bitwise_the_default_column_loop() {
        let sys = poisson2d(7, None);
        let a = &sys.matrix;
        let n = a.nrows;
        let mut rng = Prng::new(5);
        for k in [1usize, 3, 8] {
            let x = rng.normal_vec(n * k);
            let mut fused = vec![0.0; n * k];
            a.apply_block(&x, &mut fused, k);
            // SerialOp takes the default (column-looped) path
            let mut looped = vec![0.0; n * k];
            SerialOp(a).apply_block(&x, &mut looped, k);
            assert_eq!(fused, looped, "k={k}");
        }
    }

    #[test]
    fn shifted_op_subtracts_sigma() {
        let sys = poisson2d(6, None);
        let mut rng = Prng::new(1);
        let x = rng.normal_vec(36);
        let op = ShiftedOp {
            op: &sys.matrix,
            sigma: 2.5,
        };
        let mut x_ext = x.clone();
        let mut y = vec![0.0; 36];
        op.apply(&mut x_ext, &mut y);
        let want: Vec<f64> = sys
            .matrix
            .matvec(&x)
            .iter()
            .zip(&x)
            .map(|(ax, xi)| ax - 2.5 * xi)
            .collect();
        assert!(util::max_abs_diff(&y, &want) < 1e-14);
    }

    #[test]
    fn transposed_op_is_adjoint() {
        let mut rng = Prng::new(2);
        let a = random_nonsymmetric(&mut rng, 20, 3);
        let x = rng.normal_vec(20);
        let y = rng.normal_vec(20);
        let t = TransposedOp(&a);
        // <A^T x, y> == <x, A y>
        let mut atx = vec![0.0; 20];
        let mut x_ext = x.clone();
        t.apply(&mut x_ext, &mut atx);
        let ay = a.matvec(&y);
        let lhs = util::dot(&atx, &y);
        let rhs = util::dot(&x, &ay);
        assert!((lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0));
        // apply_adjoint of the transpose is A itself
        let mut back = vec![0.0; 20];
        t.apply_adjoint(&y, &mut back);
        assert!(util::max_abs_diff(&back, &ay) < 1e-14);
    }
}
