//! Small shared utilities: dense vector kernels, a deterministic PRNG,
//! and the property-testing helper used across the test suite.

pub mod prng;
pub mod proptest;
pub mod sync;
pub mod vec_ops;

pub use prng::Prng;
pub use sync::lock_recover;
pub use vec_ops::*;
