//! Deterministic xoshiro256++ PRNG.
//!
//! The crates.io `rand` stack is not vendored in this environment, and the
//! library needs reproducible workload generation (benches regenerate the
//! paper's tables from fixed seeds), so we carry a small, well-known
//! generator ourselves.

/// xoshiro256++ by Blackman & Vigna (public domain reference
/// implementation, ported).  Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so that small consecutive seeds give
    /// independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // partial Fisher-Yates over an index map for small k
        let mut picked = Vec::with_capacity(k);
        let mut used = std::collections::HashSet::new();
        while picked.len() < k {
            let c = self.below(n);
            if used.insert(c) {
                picked.push(c);
            }
        }
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut p = Prng::new(1);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(7);
        let xs = p.normal_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut p = Prng::new(3);
        let picks = p.choose_distinct(50, 20);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(picks.iter().all(|&i| i < 50));
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }
}
