//! Poison-recovering lock helpers.
//!
//! A worker that panics while holding a `Mutex` poisons it; the default
//! `.lock().unwrap()` idiom then propagates that panic into every other
//! thread touching the lock — one bad job wedges metrics reporting (or
//! the whole engine) for the rest of the process.  Every subsystem the
//! engine shares across workers locks through [`lock_recover`] instead:
//! the data under our mutexes is counters, cache maps, and channel
//! handles, all of which remain structurally valid after a panic
//! mid-critical-section, so recovering the guard is always sound here.

use std::sync::{Mutex, MutexGuard};

/// Acquire `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn recovers_after_panic_while_held() {
        let m = Mutex::new(7u64);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.is_poisoned());
        // plain .lock().unwrap() would now panic; lock_recover does not
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
