//! Minimal property-testing harness (the `proptest` crate is not vendored
//! in this environment).
//!
//! `check(name, cases, f)` runs `f` against `cases` independent PRNG
//! streams; on the first failure it re-runs a seed-bisection pass to
//! report the smallest failing seed, then panics with the property name
//! and seed so the failure is reproducible with `Prng::new(seed)`.

use super::prng::Prng;

/// Run a randomized property `cases` times.  The closure receives a fresh
/// deterministic PRNG per case and returns `Err(msg)` to signal failure.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Prng::new(0xC0FFEE ^ seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}"); // rsla-lint: allow(L1, the harness must fail the test on a falsified property)
        }
    }
}

/// Assert two slices agree to `tol` (absolute + relative mix), with a
/// useful error message for `check` closures.
pub fn close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={:.3e}, tol={:.1e})",
                (x - y).abs(),
                tol
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("uniform in range", 50, |rng| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("{u} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0 + 1e-3], 1e-6).is_err());
        assert!(close(&[1.0, 2.0], &[1.0, 2.0 + 1e-9], 1e-6).is_ok());
    }
}
