//! Dense vector kernels used by every Krylov loop.
//!
//! These are the L3 hot path (profiled in EXPERIMENTS.md §Perf); they are
//! written as straight slice loops that LLVM auto-vectorizes, with the
//! mutating variants (`axpy_inplace`, ...) preferred inside solvers to
//! keep the iteration allocation-free.

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled reduction: breaks the fp-add dependency chain, ~3x
    // over the naive fold at large n (see EXPERIMENTS.md §Perf/L3).
    let mut acc = [0.0f64; 4];
    let chunks = x.len() / 4;
    for i in 0..chunks {
        let b = i * 4;
        acc[0] += x[b] * y[b];
        acc[1] += x[b + 1] * y[b + 1];
        acc[2] += x[b + 2] * y[b + 2];
        acc[3] += x[b + 3] * y[b + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y += alpha * x.
#[inline]
pub fn axpy_inplace(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y = x + beta * y  (the CG direction update).
#[inline]
pub fn xpby_inplace(x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = xi + beta * *yi;
    }
}

/// Elementwise z = a * b.
#[inline]
pub fn hadamard(a: &[f64], b: &[f64], z: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), z.len());
    for i in 0..a.len() {
        z[i] = a[i] * b[i];
    }
}

/// z = a - b.
#[inline]
pub fn sub(a: &[f64], b: &[f64], z: &mut [f64]) {
    for i in 0..a.len() {
        z[i] = a[i] - b[i];
    }
}

/// Scale in place.
#[inline]
pub fn scale_inplace(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// max_i |a_i - b_i|.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Relative L2 error ||a - b|| / ||b|| (0/0 = 0).
/// Numerically stable softplus ln(1 + e^x) — the positivity map used by
/// the inverse coefficient-learning task (paper §4.4).
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    let den = norm2(b);
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..1003).map(|i| (i as f64).sin()).collect();
        let y: Vec<f64> = (0..1003).map(|i| (i as f64).cos()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy_inplace(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn xpby() {
        let x = vec![1.0, 1.0];
        let mut y = vec![2.0, 4.0];
        xpby_inplace(&x, 0.5, &mut y);
        assert_eq!(y, vec![2.0, 3.0]);
    }

    #[test]
    fn rel_l2_zero_cases() {
        assert_eq!(rel_l2(&[0.0], &[0.0]), 0.0);
        assert!(rel_l2(&[1.0], &[0.0]).is_infinite());
    }
}
