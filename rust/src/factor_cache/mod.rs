//! Process-wide pattern-keyed factorization cache.
//!
//! The paper's adjoint design (Eq. 3, Table 2) assumes the forward
//! factorization is *reused* for the transpose/adjoint solve; training
//! loops, Newton iterations, and the batch service additionally reuse
//! factorizations across calls.  This module makes that reuse a single
//! shared mechanism instead of a per-call-site convention:
//!
//! * **numeric tier** — keyed by [`PatternKey`] (pattern + values).  A
//!   hit returns the finished [`CachedFactor`]; no numeric work at all.
//! * **symbolic tier** — keyed by [`StructureKey`] (pattern only).  A
//!   hit reuses the recorded ordering / elimination structure / fill
//!   allocation and re-runs only the values-dependent numeric phase
//!   (`EnvelopeCholesky::factor_numeric`, `SparseLu::refactor`).
//!
//! Every key match is re-verified by full equality before it is acted
//! on, so a 64-bit fingerprint collision can cost a missed reuse but
//! never a wrong answer.  Entries are evicted least-recently-used
//! against a byte budget; bytes are accounted through
//! [`metrics::MemTracker`] so benches report measured, not modeled,
//! cache footprints.  Counters are mirrored into any
//! [`metrics::Registry`] the caller passes (the dispatcher passes its
//! own, which is how the hit/miss/eviction counters surface in solve
//! reports).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::direct::{build_factor, refactor, CachedFactor, Symbolic};
use crate::error::{Error, Result};
use crate::metrics::{self, names, MemTracker};
use crate::sparse::key::{PatternKey, StructureKey};
use crate::sparse::Csr;
use crate::trace::{self, names as tn};
use crate::util::lock_recover;

/// Default byte budget for the process-wide cache.  Override per
/// process with `RSLA_FACTOR_CACHE_BYTES`, or construct private caches
/// with [`FactorCache::new`].
pub const DEFAULT_BUDGET_BYTES: u64 = 256 << 20;

struct NumericEntry {
    /// Full copy of the factored matrix: the equality witness that
    /// makes hash-keyed hits sound.
    matrix: Csr,
    factor: Arc<CachedFactor>,
    bytes: u64,
    last_used: u64,
}

struct SymbolicEntry {
    /// Pattern copy for the equality re-check.
    indptr: Vec<usize>,
    indices: Vec<usize>,
    sym: Symbolic,
    bytes: u64,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    numeric: HashMap<PatternKey, NumericEntry>,
    symbolic: HashMap<StructureKey, SymbolicEntry>,
    clock: u64,
}

/// Counter snapshot (see also the mirrored `factor_cache.*` registry
/// counters).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits_numeric: u64,
    pub hits_symbolic: u64,
    pub misses: u64,
    pub evictions: u64,
    pub collisions: u64,
    /// Cold factorizations + refactorizations actually executed.
    pub numeric_factorizations: u64,
    pub bytes_current: u64,
    pub bytes_peak: u64,
}

/// Two-tier LRU factorization cache.  Thread-safe; factorization runs
/// outside the lock (concurrent misses on the same key do duplicate
/// work once, last insert wins).
pub struct FactorCache {
    inner: Mutex<Inner>,
    budget: u64,
    mem: MemTracker,
    hits_numeric: AtomicU64,
    hits_symbolic: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    collisions: AtomicU64,
    numeric_factorizations: AtomicU64,
}

impl FactorCache {
    pub fn new(budget_bytes: u64) -> Self {
        FactorCache {
            inner: Mutex::new(Inner::default()),
            budget: budget_bytes,
            mem: MemTracker::new(),
            hits_numeric: AtomicU64::new(0),
            hits_symbolic: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            collisions: AtomicU64::new(0),
            numeric_factorizations: AtomicU64::new(0),
        }
    }

    /// The process-wide cache shared by the dispatcher, the batch
    /// service, Newton, AMG, and the native adjoint solver.
    pub fn global() -> &'static FactorCache {
        static GLOBAL: OnceLock<FactorCache> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let budget = std::env::var("RSLA_FACTOR_CACHE_BYTES")
                .ok()
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(DEFAULT_BUDGET_BYTES);
            FactorCache::new(budget)
        })
    }

    /// Byte-accurate accounting of cached entries (matrices, factors,
    /// symbolic structures).
    pub fn mem(&self) -> &MemTracker {
        &self.mem
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits_numeric: self.hits_numeric.load(Ordering::Relaxed),
            hits_symbolic: self.hits_symbolic.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            collisions: self.collisions.load(Ordering::Relaxed),
            numeric_factorizations: self.numeric_factorizations.load(Ordering::Relaxed),
            bytes_current: self.mem.current(),
            bytes_peak: self.mem.peak(),
        }
    }

    /// Drop every cached entry (tests, memory pressure).
    pub fn clear(&self) {
        let mut inner = lock_recover(&self.inner);
        for (_, e) in inner.numeric.drain() {
            self.mem.sub(e.bytes);
        }
        for (_, e) in inner.symbolic.drain() {
            self.mem.sub(e.bytes);
        }
    }

    fn bump(counter: &AtomicU64, reg: Option<&metrics::Registry>, name: &str) {
        counter.fetch_add(1, Ordering::Relaxed);
        if let Some(r) = reg {
            r.incr(name, 1);
        }
    }

    /// Factor `a` (or fetch it), bounded by `max_fill_bytes` of factor
    /// storage.  Serves numeric hits, symbolic-tier refactorizations,
    /// and cold factorizations, in that order; the returned handle
    /// answers both `solve` and `solve_t` from the one factorization.
    pub fn factor(
        &self,
        a: &Csr,
        max_fill_bytes: u64,
        reg: Option<&metrics::Registry>,
    ) -> Result<Arc<CachedFactor>> {
        let key = PatternKey::of(a);
        self.factor_keyed(a, &key, max_fill_bytes, reg)
    }

    /// [`factor`](Self::factor) with a caller-supplied key — the engine
    /// scheduler already fingerprints every linear job to group and
    /// route it, so the worker threads that key through here instead of
    /// paying a second O(nnz) `PatternKey::of` pass.  The key MUST be
    /// `PatternKey::of(a)`; every tier re-verifies full equality before
    /// acting on it, so a wrong key costs a missed reuse, never a wrong
    /// answer.
    pub fn factor_keyed(
        &self,
        a: &Csr,
        key: &PatternKey,
        max_fill_bytes: u64,
        reg: Option<&metrics::Registry>,
    ) -> Result<Arc<CachedFactor>> {
        let key = key.clone();
        let skey = key.structure();

        // numeric tier
        let cached_sym: Option<Symbolic> = {
            let mut inner = lock_recover(&self.inner);
            inner.clock += 1;
            let now = inner.clock;
            if let Some(e) = inner.numeric.get_mut(&key) {
                if e.matrix.indptr == a.indptr
                    && e.matrix.indices == a.indices
                    && e.matrix.vals == a.vals
                {
                    // budget check on the hit path too, using the SAME
                    // quantity the cold path compares (fill bytes), so
                    // a fixed request's OOM outcome never depends on
                    // cache warmth in either direction
                    let bytes = e.factor.fill_bytes();
                    if bytes > max_fill_bytes {
                        return Err(Error::OutOfMemory {
                            needed_bytes: bytes,
                            budget_bytes: max_fill_bytes,
                        });
                    }
                    e.last_used = now;
                    let factor = e.factor.clone();
                    drop(inner);
                    Self::bump(&self.hits_numeric, reg, names::FACTOR_CACHE_HIT_NUMERIC);
                    trace::event(tn::FACTOR_HIT_NUMERIC, key.structure_hash);
                    return Ok(factor);
                }
                Self::bump(&self.collisions, reg, names::FACTOR_CACHE_COLLISION);
            }
            // symbolic tier lookup (equality-verified)
            match inner.symbolic.get_mut(&skey) {
                Some(e) if e.indptr == a.indptr && e.indices == a.indices => {
                    e.last_used = now;
                    Some(e.sym.clone())
                }
                _ => None,
            }
        };

        // numeric work happens outside the lock
        let symmetric = a.is_symmetric(1e-12);
        let (factor, sym, was_symbolic_hit) = match cached_sym {
            Some(sym) => match refactor(&sym, a, symmetric, max_fill_bytes) {
                Ok(f) => {
                    Self::bump(&self.hits_symbolic, reg, names::FACTOR_CACHE_HIT_SYMBOLIC);
                    trace::event(tn::FACTOR_HIT_SYMBOLIC, key.structure_hash);
                    (f, sym, true)
                }
                Err(_) => {
                    // The cached family/pivot order no longer fits the
                    // values (breakdown) — or its replayed fill blows a
                    // budget that a freshly-chosen family might meet.
                    // Either way the COLD path decides, so outcomes
                    // (including OutOfMemory) never depend on cache
                    // warmth.
                    if let Some(r) = reg {
                        r.incr(names::FACTOR_CACHE_REFACTOR_FALLBACK, 1);
                    }
                    Self::bump(&self.misses, reg, names::FACTOR_CACHE_MISS);
                    trace::event(tn::FACTOR_MISS, key.structure_hash);
                    let (f, s) = build_factor(a, symmetric, max_fill_bytes)?;
                    (f, s, false)
                }
            },
            None => {
                Self::bump(&self.misses, reg, names::FACTOR_CACHE_MISS);
                trace::event(tn::FACTOR_MISS, key.structure_hash);
                let (f, s) = build_factor(a, symmetric, max_fill_bytes)?;
                (f, s, false)
            }
        };
        Self::bump(
            &self.numeric_factorizations,
            reg,
            names::FACTOR_CACHE_NUMERIC_FACTORIZATIONS,
        );

        // insert + evict
        {
            let mut inner = lock_recover(&self.inner);
            inner.clock += 1;
            let now = inner.clock;
            let entry_bytes =
                metrics::mem::csr_bytes(a.nrows, a.nnz()) + factor.bytes();
            self.mem.add(entry_bytes);
            if let Some(old) = inner.numeric.insert(
                key.clone(),
                NumericEntry {
                    matrix: a.clone(),
                    factor: factor.clone(),
                    bytes: entry_bytes,
                    last_used: now,
                },
            ) {
                self.mem.sub(old.bytes);
            }
            if !was_symbolic_hit {
                let sym_bytes =
                    ((a.indptr.len() + a.indices.len()) * 8) as u64 + sym.bytes();
                self.mem.add(sym_bytes);
                if let Some(old) = inner.symbolic.insert(
                    skey.clone(),
                    SymbolicEntry {
                        indptr: a.indptr.clone(),
                        indices: a.indices.clone(),
                        sym,
                        bytes: sym_bytes,
                        last_used: now,
                    },
                ) {
                    self.mem.sub(old.bytes);
                }
            }
            self.evict_to_budget(&mut inner, &key, &skey, reg);
        }
        Ok(factor)
    }

    /// LRU eviction down to the byte budget.  Numeric entries go first
    /// (they are larger and recoverable through the symbolic tier);
    /// the just-inserted entries are evicted last, and only if they
    /// alone exceed the budget.
    fn evict_to_budget(
        &self,
        inner: &mut Inner,
        keep_num: &PatternKey,
        keep_sym: &StructureKey,
        reg: Option<&metrics::Registry>,
    ) {
        while self.mem.current() > self.budget {
            let victim = inner
                .numeric
                .iter()
                .filter(|(k, _)| *k != keep_num)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(k) = victim {
                if let Some(e) = inner.numeric.remove(&k) {
                    self.mem.sub(e.bytes);
                    Self::bump(&self.evictions, reg, names::FACTOR_CACHE_EVICTION);
                }
                continue;
            }
            let victim = inner
                .symbolic
                .iter()
                .filter(|(k, _)| *k != keep_sym)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            if let Some(k) = victim {
                if let Some(e) = inner.symbolic.remove(&k) {
                    self.mem.sub(e.bytes);
                    Self::bump(&self.evictions, reg, names::FACTOR_CACHE_EVICTION);
                }
                continue;
            }
            // only the just-inserted entries remain
            if let Some(e) = inner.numeric.remove(keep_num) {
                self.mem.sub(e.bytes);
                Self::bump(&self.evictions, reg, names::FACTOR_CACHE_EVICTION);
                continue;
            }
            if let Some(e) = inner.symbolic.remove(keep_sym) {
                self.mem.sub(e.bytes);
                Self::bump(&self.evictions, reg, names::FACTOR_CACHE_EVICTION);
                continue;
            }
            break;
        }
    }

    /// Cached direct solve: factor (or fetch) then one triangular
    /// sweep.
    pub fn solve(&self, a: &Csr, b: &[f64], reg: Option<&metrics::Registry>) -> Result<Vec<f64>> {
        self.factor(a, u64::MAX, reg)?.solve(b)
    }

    /// Cached transpose solve A^T x = b from the same factorization.
    pub fn solve_t(
        &self,
        a: &Csr,
        b: &[f64],
        reg: Option<&metrics::Registry>,
    ) -> Result<Vec<f64>> {
        self.factor(a, u64::MAX, reg)?.solve_t(b)
    }

    /// Predicted Cholesky factor bytes for `a`'s pattern, served from a
    /// verified cached symbolic analysis — lets `native-direct` run its
    /// pre-factorization budget check without recomputing RCM and
    /// materializing the permuted matrix on every call.  Returns None
    /// on a symbolic miss or when the cached family is LU.
    pub fn chol_predicted_fill_bytes(&self, a: &Csr) -> Option<u64> {
        let skey = StructureKey::of(a);
        let inner = lock_recover(&self.inner);
        match inner.symbolic.get(&skey) {
            Some(e) if e.indptr == a.indptr && e.indices == a.indices => match &e.sym {
                Symbolic::Chol(cs) => Some((cs.predicted_fill() * 8) as u64),
                Symbolic::SnChol(cs) => Some((cs.predicted_fill() * 8) as u64),
                Symbolic::Lu(_) | Symbolic::SnLu { .. } => None,
            },
            _ => None,
        }
    }

    /// True when this cache holds a VERIFIED numeric-tier entry for
    /// exactly `a` (pattern + values).  Used by the shard layer to
    /// account cross-shard misses: a lookup that misses here while a
    /// sibling shard holds the factor is a scheduling failure, not a
    /// cold matrix.
    pub fn holds_numeric(&self, a: &Csr) -> bool {
        let key = PatternKey::of(a);
        self.holds_numeric_keyed(a, &key)
    }

    /// [`holds_numeric`](Self::holds_numeric) with a caller-supplied
    /// key (the engine's scheduler-computed fingerprint), skipping the
    /// O(nnz) re-hash.
    pub fn holds_numeric_keyed(&self, a: &Csr, key: &PatternKey) -> bool {
        let inner = lock_recover(&self.inner);
        match inner.numeric.get(key) {
            Some(e) => {
                e.matrix.indptr == a.indptr
                    && e.matrix.indices == a.indices
                    && e.matrix.vals == a.vals
            }
            None => false,
        }
    }

    /// Numeric symmetry of `a`, served from a verified cached factor
    /// when one exists (no O(nnz) scan), computed otherwise.  Sound
    /// under hash collisions: the cached answer is only used after a
    /// full equality check.
    pub fn symmetry_of(&self, a: &Csr) -> bool {
        let key = PatternKey::of(a);
        {
            let inner = lock_recover(&self.inner);
            if let Some(e) = inner.numeric.get(&key) {
                if e.matrix.indptr == a.indptr
                    && e.matrix.indices == a.indices
                    && e.matrix.vals == a.vals
                {
                    return e.factor.symmetric;
                }
            }
        }
        a.is_symmetric(1e-12)
    }
}

/// Per-worker factor-cache shards for the solve engine's
/// pattern-affinity scheduling: worker `w` factors through shard `w`,
/// and the scheduler routes same-pattern jobs to the worker whose shard
/// is already warm.  The API is *keyed-only*: every caller carries a
/// [`PatternKey`] (the engine threads the scheduler's fingerprint
/// through, or computes one exactly once at the call site), so no
/// shard probe ever pays a second O(nnz) hash.
/// [`CacheShards::factor_on_keyed`] additionally accounts
/// CROSS-SHARD traffic — a numeric miss on the probing shard while a
/// sibling shard holds the factor means the scheduler sent the job to
/// the wrong worker (counter `factor_cache.cross_shard_miss`); a
/// numeric-tier hit on the probing shard is a `factor_cache.shard_local_hit`.
pub struct CacheShards {
    shards: Vec<Arc<FactorCache>>,
}

impl CacheShards {
    /// `n` shards of `budget_bytes` each (n is clamped to >= 1).
    pub fn new(n: usize, budget_bytes: u64) -> Self {
        CacheShards {
            shards: (0..n.max(1))
                .map(|_| Arc::new(FactorCache::new(budget_bytes)))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn shard(&self, i: usize) -> &Arc<FactorCache> {
        // rsla-lint: allow(L1, shard index is a worker index and shards is sized to the worker count)
        &self.shards[i]
    }

    /// True when any shard holds a verified numeric factor for `a`.
    pub fn any_holds(&self, a: &Csr) -> bool {
        let key = PatternKey::of(a);
        self.shards.iter().any(|s| s.holds_numeric_keyed(a, &key))
    }

    /// Factor `a` through shard `i` with the caller's already-computed
    /// key, accounting shard-local hits and cross-shard misses in
    /// `reg`.  The whole shard probe (local hit, cross-shard miss,
    /// factor/fetch) runs without re-hashing `a` — there is
    /// deliberately no unkeyed variant, so every path that reaches a
    /// shard has paid the O(nnz) hash exactly once.
    pub fn factor_on_keyed(
        &self,
        i: usize,
        a: &Csr,
        key: &PatternKey,
        max_fill_bytes: u64,
        reg: Option<&metrics::Registry>,
    ) -> Result<Arc<CachedFactor>> {
        // an out-of-range worker index (impossible by construction)
        // degrades to shard 0 rather than panicking the worker
        let shard = match self.shards.get(i).or_else(|| self.shards.first()) {
            Some(s) => s,
            None => {
                return Err(Error::InvalidProblem(
                    "factor cache has no shards".into(),
                ))
            }
        };
        if let Some(r) = reg {
            if shard.holds_numeric_keyed(a, key) {
                r.incr(names::FACTOR_CACHE_SHARD_LOCAL_HIT, 1);
                trace::event(tn::FACTOR_SHARD_LOCAL_HIT, i as u64);
            } else if self
                .shards
                .iter()
                .enumerate()
                .any(|(j, s)| j != i && s.holds_numeric_keyed(a, key))
            {
                r.incr(names::FACTOR_CACHE_CROSS_SHARD_MISS, 1);
                trace::event(tn::FACTOR_CROSS_SHARD_MISS, i as u64);
            }
        }
        shard.factor_keyed(a, key, max_fill_bytes, reg)
    }

    /// Aggregate counter/byte snapshot across all shards.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.shards {
            let st = s.stats();
            total.hits_numeric += st.hits_numeric;
            total.hits_symbolic += st.hits_symbolic;
            total.misses += st.misses;
            total.evictions += st.evictions;
            total.collisions += st.collisions;
            total.numeric_factorizations += st.numeric_factorizations;
            total.bytes_current += st.bytes_current;
            total.bytes_peak += st.bytes_peak;
        }
        total
    }
}

/// Drop-in replacement for [`crate::direct::direct_solve`] that reuses
/// factorizations through the process-wide cache: repeated solves on
/// the same (pattern, values) skip factorization entirely, and solves
/// on new values over a known pattern skip the symbolic phase (the
/// Newton-loop case — the Jacobian pattern is fixed across iterations).
pub fn cached_direct_solve(a: &Csr, b: &[f64]) -> Result<Vec<f64>> {
    FactorCache::global().solve(a, b, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_nonsymmetric, random_spd};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn miss_then_numeric_hit_then_symbolic_hit() {
        let cache = FactorCache::new(u64::MAX);
        let mut rng = Prng::new(100);
        let a = random_spd(&mut rng, 30, 3, 1.5);
        let b = rng.normal_vec(30);

        let x1 = cache.solve(&a, &b, None).unwrap();
        assert_eq!(
            cache.stats().misses,
            1,
            "first solve must be a cold factorization"
        );
        assert_eq!(cache.stats().numeric_factorizations, 1);

        let x2 = cache.solve(&a, &b, None).unwrap();
        assert_eq!(cache.stats().hits_numeric, 1);
        assert_eq!(
            cache.stats().numeric_factorizations,
            1,
            "numeric hit must not refactor"
        );
        assert_eq!(x1, x2, "numeric hit returns the identical factor");

        // new values on the same pattern: symbolic tier
        let mut a2 = a.clone();
        for v in a2.vals.iter_mut() {
            *v *= 2.0;
        }
        let x3 = cache.solve(&a2, &b, None).unwrap();
        assert_eq!(cache.stats().hits_symbolic, 1);
        assert_eq!(cache.stats().numeric_factorizations, 2);
        assert!(util::rel_l2(&a2.matvec(&x3), &b) < 1e-10);
    }

    #[test]
    fn transpose_solve_shares_the_factorization() {
        let cache = FactorCache::new(u64::MAX);
        let mut rng = Prng::new(101);
        let a = random_nonsymmetric(&mut rng, 40, 4);
        let b = rng.normal_vec(40);
        let x = cache.solve(&a, &b, None).unwrap();
        let xt = cache.solve_t(&a, &b, None).unwrap();
        assert_eq!(cache.stats().numeric_factorizations, 1);
        assert_eq!(cache.stats().hits_numeric, 1);
        assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-9);
        let mut atx = vec![0.0; 40];
        a.spmv_t(&xt, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-9);
    }

    #[test]
    fn lru_eviction_under_byte_budget() {
        // budget sized to hold roughly one entry: the third distinct
        // matrix must evict the first
        let sys = poisson2d(10, None);
        let probe_cache = FactorCache::new(u64::MAX);
        let f = probe_cache.factor(&sys.matrix, u64::MAX, None).unwrap();
        let one_entry = metrics::mem::csr_bytes(100, sys.matrix.nnz()) + f.bytes();
        let cache = FactorCache::new(one_entry * 2);

        let mats: Vec<_> = (0..3)
            .map(|i| {
                let mut m = sys.matrix.clone();
                for v in m.vals.iter_mut() {
                    *v *= 1.0 + i as f64;
                }
                m
            })
            .collect();
        for m in &mats {
            cache.factor(m, u64::MAX, None).unwrap();
        }
        let stats = cache.stats();
        assert!(
            stats.evictions >= 1,
            "expected evictions under a {one_entry}x2-byte budget, got {stats:?}"
        );
        assert!(
            stats.bytes_current <= one_entry * 2,
            "cache exceeds its budget: {stats:?}"
        );
        // evicted entries re-enter through the (cheaper) symbolic tier
        cache.factor(&mats[0], u64::MAX, None).unwrap();
        assert!(cache.stats().hits_symbolic >= 1);
    }

    #[test]
    fn clear_releases_all_bytes() {
        let cache = FactorCache::new(u64::MAX);
        let sys = poisson2d(8, None);
        cache.factor(&sys.matrix, u64::MAX, None).unwrap();
        assert!(cache.stats().bytes_current > 0);
        cache.clear();
        assert_eq!(cache.stats().bytes_current, 0);
    }

    #[test]
    fn symmetry_is_cached_on_the_factor() {
        let cache = FactorCache::new(u64::MAX);
        let mut rng = Prng::new(102);
        let spd = random_spd(&mut rng, 25, 3, 1.0);
        cache.factor(&spd, u64::MAX, None).unwrap();
        assert!(cache.symmetry_of(&spd));
        let gen = random_nonsymmetric(&mut rng, 25, 3);
        cache.factor(&gen, u64::MAX, None).unwrap();
        assert!(!cache.symmetry_of(&gen));
    }

    #[test]
    fn warm_factor_still_respects_a_tighter_budget() {
        // OOM semantics must not depend on cache warmth: a factor
        // cached under a generous budget must still error when a later
        // caller brings a budget it exceeds.
        let cache = FactorCache::new(u64::MAX);
        let sys = poisson2d(16, None);
        let f = cache.factor(&sys.matrix, u64::MAX, None).unwrap();
        let tight = f.fill_bytes() - 1;
        assert!(matches!(
            cache.factor(&sys.matrix, tight, None),
            Err(Error::OutOfMemory { .. })
        ));
        // a budget that admitted the cold factorization also admits the
        // warm hit (same comparison quantity both ways)
        cache.factor(&sys.matrix, f.fill_bytes(), None).unwrap();
        assert!(cache.stats().hits_numeric >= 1);
    }

    #[test]
    fn oom_budget_propagates_and_nothing_is_cached() {
        let cache = FactorCache::new(u64::MAX);
        let sys = poisson2d(24, None);
        assert!(matches!(
            cache.factor(&sys.matrix, 10_000, None),
            Err(Error::OutOfMemory { .. })
        ));
        assert_eq!(cache.stats().bytes_current, 0);
    }

    #[test]
    fn prop_cached_refactorized_solves_bitwise_match_cold() {
        // The satellite property: a symbolic-tier refactorization must
        // produce bit-identical solves to a cold factorization of the
        // same values.  Cholesky guarantees this for any values (no
        // pivoting); LU guarantees it whenever the cold pivot order
        // matches the recorded one, which holds for unchanged values.
        crate::util::proptest::check("cached refactor bitwise == cold", 10, |rng| {
            let n = 10 + rng.below(30);
            let shift = 1.5 + rng.uniform();
            let spd = random_spd(rng, n, 3, shift);
            let b = rng.normal_vec(n);
            // warm a cache on the pattern with different values
            let warm = FactorCache::new(u64::MAX);
            warm.solve(&spd, &b, None).map_err(|e| e.to_string())?;
            // uniform scaling keeps the matrix symmetric (and SPD)
            let scale = 1.0 + 0.5 * rng.uniform();
            let mut spd2 = spd.clone();
            for v in spd2.vals.iter_mut() {
                *v *= scale;
            }
            // refactorized (symbolic hit) vs cold (fresh cache)
            let x_warm = warm.solve(&spd2, &b, None).map_err(|e| e.to_string())?;
            if warm.stats().hits_symbolic == 0 {
                return Err("expected a symbolic-tier hit".into());
            }
            let cold = FactorCache::new(u64::MAX);
            let x_cold = cold.solve(&spd2, &b, None).map_err(|e| e.to_string())?;
            if x_warm != x_cold {
                return Err("refactorized solve differs bitwise from cold solve".into());
            }
            // LU: replay with unchanged values is bitwise too
            let gen = random_nonsymmetric(rng, n, 3);
            let warm_lu = FactorCache::new(0); // zero budget: numeric tier never retains
            let x1 = warm_lu.solve(&gen, &b, None).map_err(|e| e.to_string())?;
            let cold_lu = FactorCache::new(u64::MAX);
            let x2 = cold_lu.solve(&gen, &b, None).map_err(|e| e.to_string())?;
            if x1 != x2 {
                return Err("LU cold solves disagree bitwise across caches".into());
            }
            Ok(())
        });
    }

    #[test]
    fn shards_account_local_hits_and_cross_shard_misses() {
        let shards = CacheShards::new(2, u64::MAX);
        let reg = metrics::Registry::new();
        let sys = poisson2d(8, None);
        // the shards API is keyed-only: hash once, probe many times
        let key = PatternKey::of(&sys.matrix);
        // cold on shard 0: neither local hit nor cross-shard miss
        shards
            .factor_on_keyed(0, &sys.matrix, &key, u64::MAX, Some(&reg))
            .unwrap();
        assert_eq!(reg.get("factor_cache.shard_local_hit"), 0);
        assert_eq!(reg.get("factor_cache.cross_shard_miss"), 0);
        // warm on shard 0: local hit
        shards
            .factor_on_keyed(0, &sys.matrix, &key, u64::MAX, Some(&reg))
            .unwrap();
        assert_eq!(reg.get("factor_cache.shard_local_hit"), 1);
        // same matrix routed to shard 1: cross-shard miss (the factor
        // exists, just not where the job landed)
        shards
            .factor_on_keyed(1, &sys.matrix, &key, u64::MAX, Some(&reg))
            .unwrap();
        assert_eq!(reg.get("factor_cache.cross_shard_miss"), 1);
        assert!(shards.any_holds(&sys.matrix));
        let agg = shards.stats();
        assert_eq!(agg.misses, 2, "one cold miss per shard");
        assert_eq!(agg.hits_numeric, 1);
    }

    #[test]
    fn lu_symbolic_refactor_same_values_bitwise() {
        // zero-byte budget forces the numeric tier to evict, so a
        // second solve with the SAME values would normally go cold; a
        // budget that keeps only the symbolic entry exercises the
        // replay path against identical values.
        let mut rng = Prng::new(103);
        let gen = random_nonsymmetric(&mut rng, 35, 4);
        let b = rng.normal_vec(35);

        let cold = FactorCache::new(u64::MAX);
        let x_cold = cold.solve(&gen, &b, None).unwrap();

        // budget below the numeric entry but above the symbolic entry:
        // compute both sizes from a probe run
        let probe = FactorCache::new(u64::MAX);
        let f = probe.factor(&gen, u64::MAX, None).unwrap();
        let numeric_bytes = metrics::mem::csr_bytes(35, gen.nnz()) + f.bytes();
        let cache = FactorCache::new(numeric_bytes); // symbolic survives, numeric evicted on 2nd insert
        cache.solve(&gen, &b, None).unwrap();
        let x_replay = cache.solve(&gen, &b, None).unwrap();
        assert_eq!(
            x_cold, x_replay,
            "LU replay with unchanged values must be bitwise identical"
        );
    }
}
