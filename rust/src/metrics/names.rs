//! Canonical [`Registry`](super::Registry) counter names.
//!
//! Every counter the library increments or reads is declared here,
//! exactly once, as a `pub const`.  `rsla-lint` rule **L4** enforces the
//! contract: a string literal passed to `Registry::incr`/`get` anywhere
//! in non-test library code must match one of these declarations, and no
//! name may be declared twice — so a typo'd counter name ("batchs") is a
//! CI failure instead of a silently-zero dashboard column.
//!
//! Names with a dynamic suffix (per job kind, per backend) declare their
//! *base* here and go through [`Registry::incr_labeled`](super::Registry::incr_labeled),
//! which appends `.{label}`; the full name is still discoverable by
//! prefix in snapshots.

/// Jobs completed (any kind), mirrored into `ServiceStats::completed`.
pub const SERVICE_COMPLETED: &str = "service.completed";
/// Scheduling batches formed by the intake window.
pub const SERVICE_BATCHES: &str = "service.batches";
/// Requests that shared a scheduling batch.
pub const SERVICE_BATCHED_REQUESTS: &str = "service.batched_requests";
/// Fused groups split by the worker's full-equality re-check
/// (64-bit `PatternKey` collisions).
pub const SERVICE_KEY_COLLISIONS: &str = "service.key_collisions";

/// Base for per-kind completion counters (`engine.completed.linear`, ...).
pub const ENGINE_COMPLETED: &str = "engine.completed";
/// Reply callbacks that panicked (caught; the worker survives).
pub const ENGINE_REPLY_PANIC: &str = "engine.reply_panic";
/// Jobs failed with `Error::Timeout` before execution.
pub const ENGINE_TIMEOUT: &str = "engine.timeout";
/// Submissions rejected by admission control (`Error::QueueFull`).
pub const ENGINE_REJECTED: &str = "engine.rejected";
/// Pattern routed to the worker already pinned to it.
pub const ENGINE_AFFINITY_HIT: &str = "engine.affinity.hit";
/// Pattern seen for the first time (or after a map reset).
pub const ENGINE_AFFINITY_MISS: &str = "engine.affinity.miss";
/// Affinity map cleared at its size cap.
pub const ENGINE_AFFINITY_MAP_RESET: &str = "engine.affinity.map_reset";
/// Job panics caught by a worker (`Error::WorkerPanic`).
pub const ENGINE_PANIC: &str = "engine.panic";

/// Numeric-tier cache hits (pattern + values; no numeric work).
pub const FACTOR_CACHE_HIT_NUMERIC: &str = "factor_cache.hit.numeric";
/// Symbolic-tier hits (pattern only; numeric phase re-ran).
pub const FACTOR_CACHE_HIT_SYMBOLIC: &str = "factor_cache.hit.symbolic";
/// Cold factorizations.
pub const FACTOR_CACHE_MISS: &str = "factor_cache.miss";
/// LRU evictions against the byte budget.
pub const FACTOR_CACHE_EVICTION: &str = "factor_cache.eviction";
/// 64-bit key matches rejected by the full-equality re-check.
pub const FACTOR_CACHE_COLLISION: &str = "factor_cache.collision";
/// Numeric factorizations actually executed (cold + refactor).
pub const FACTOR_CACHE_NUMERIC_FACTORIZATIONS: &str = "factor_cache.numeric_factorizations";
/// Symbolic replay failed; the cold path decided instead.
pub const FACTOR_CACHE_REFACTOR_FALLBACK: &str = "factor_cache.refactor_fallback";
/// Numeric-tier hit on the shard the job was routed to.
pub const FACTOR_CACHE_SHARD_LOCAL_HIT: &str = "factor_cache.shard_local_hit";
/// Numeric miss on the routed shard while a sibling held the factor
/// (a scheduling failure, not a cold matrix).
pub const FACTOR_CACHE_CROSS_SHARD_MISS: &str = "factor_cache.cross_shard_miss";

/// Supernodes (panels) in the last blocked factorization's partition.
pub const FACTOR_SUPERNODE_COUNT: &str = "factor.supernode.count";
/// Widest supernode (columns) in the last blocked factorization.
pub const FACTOR_SUPERNODE_MAX_COLS: &str = "factor.supernode.max_cols";
/// Dense panel flops executed by the blocked numeric phase.
pub const FACTOR_PANEL_FLOPS: &str = "factor.panel.flops";

/// Matrices the roofline cost model kept on the CSR SpMV kernel.
pub const SPMV_FORMAT_CSR: &str = "spmv.format.csr";
/// Matrices the roofline cost model converted to SELL-C-σ.
pub const SPMV_FORMAT_SELL: &str = "spmv.format.sell";

/// CA-CG residual replacements (drift guard rebuilt the true residual).
pub const KRYLOV_CA_REPLACEMENTS: &str = "krylov.ca.replacements";
/// CA-CG runs that abandoned the s-step recurrence for standard CG.
pub const KRYLOV_CA_FALLBACKS: &str = "krylov.ca.fallbacks";

/// Process rank teams launched by the transport layer.
pub const COMM_TRANSPORT_TEAMS: &str = "comm.transport.teams";
/// Worker processes that died (or went silent) before reporting.
pub const COMM_TRANSPORT_DEAD_RANKS: &str = "comm.transport.dead_ranks";
/// Team-wide reduction rounds completed over a process transport.
pub const COMM_TRANSPORT_ROUNDS: &str = "comm.transport.rounds";
/// Wire bytes sent across all worker endpoints (frames + headers; the
/// algorithmic `bytes_sent` halo accounting is separate and
/// backend-independent).
pub const COMM_TRANSPORT_WIRE_BYTES: &str = "comm.transport.wire_bytes";
/// Doorbell waits recorded across all worker endpoints (a blocked poll
/// on a ring or socket that had nothing to deliver yet).
pub const COMM_TRANSPORT_DOORBELL_WAITS: &str = "comm.transport.doorbell_waits";

/// Base for per-backend refusal counters (`dispatch.refused.{backend}`).
pub const DISPATCH_REFUSED: &str = "dispatch.refused";
/// Base for per-backend success counters (`dispatch.solved.{backend}`).
pub const DISPATCH_SOLVED: &str = "dispatch.solved";
/// Base for per-backend failure counters (`dispatch.failed.{backend}`).
pub const DISPATCH_FAILED: &str = "dispatch.failed";

/// Every declared name/base, for exhaustiveness checks and reports.
pub const ALL: &[&str] = &[
    SERVICE_COMPLETED,
    SERVICE_BATCHES,
    SERVICE_BATCHED_REQUESTS,
    SERVICE_KEY_COLLISIONS,
    ENGINE_COMPLETED,
    ENGINE_REPLY_PANIC,
    ENGINE_TIMEOUT,
    ENGINE_REJECTED,
    ENGINE_AFFINITY_HIT,
    ENGINE_AFFINITY_MISS,
    ENGINE_AFFINITY_MAP_RESET,
    ENGINE_PANIC,
    FACTOR_CACHE_HIT_NUMERIC,
    FACTOR_CACHE_HIT_SYMBOLIC,
    FACTOR_CACHE_MISS,
    FACTOR_CACHE_EVICTION,
    FACTOR_CACHE_COLLISION,
    FACTOR_CACHE_NUMERIC_FACTORIZATIONS,
    FACTOR_CACHE_REFACTOR_FALLBACK,
    FACTOR_CACHE_SHARD_LOCAL_HIT,
    FACTOR_CACHE_CROSS_SHARD_MISS,
    FACTOR_SUPERNODE_COUNT,
    FACTOR_SUPERNODE_MAX_COLS,
    FACTOR_PANEL_FLOPS,
    SPMV_FORMAT_CSR,
    SPMV_FORMAT_SELL,
    KRYLOV_CA_REPLACEMENTS,
    KRYLOV_CA_FALLBACKS,
    COMM_TRANSPORT_TEAMS,
    COMM_TRANSPORT_DEAD_RANKS,
    COMM_TRANSPORT_ROUNDS,
    COMM_TRANSPORT_WIRE_BYTES,
    COMM_TRANSPORT_DOORBELL_WAITS,
    DISPATCH_REFUSED,
    DISPATCH_SOLVED,
    DISPATCH_FAILED,
];

#[cfg(test)]
mod tests {
    use super::ALL;

    #[test]
    fn names_are_unique_and_well_formed() {
        let mut seen = std::collections::HashSet::new();
        for name in ALL {
            assert!(seen.insert(*name), "duplicate metric name {name}");
            assert!(
                name.chars()
                    .all(|c| c.is_ascii_lowercase() || c == '.' || c == '_'),
                "metric name {name} has characters outside [a-z._]"
            );
            assert!(name.contains('.'), "metric name {name} has no namespace");
        }
    }
}
