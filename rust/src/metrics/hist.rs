//! Lock-free log-scale latency histograms for the solve engine's
//! per-kind p50/p95/p99 tables.
//!
//! Samples land in power-of-two microsecond buckets (bucket `i` covers
//! `[2^i, 2^{i+1})` µs), so recording is one atomic increment and the
//! memory footprint is constant regardless of traffic.  Quantiles are
//! read back as the upper edge of the covering bucket — an upper bound
//! with at most 2x resolution error, which is the right bias for
//! latency SLO tables (never under-report a tail).
//!
//! Two accumulation modes:
//!
//! * [`LatencyHist::new`] — infinite horizon: every sample ever
//!   recorded weighs on every quantile (the right mode for a bench
//!   that reports one number at the end).
//! * [`LatencyHist::windowed`] — generational window: samples land in
//!   the current generation's bucket array; every `window` samples a
//!   new generation opens and the oldest of `n_windows` generations is
//!   discarded.  Quantiles aggregate the live generations only, so a
//!   long-running server's p99 reflects *recent* traffic instead of
//!   being pinned forever by a cold-start burst.  Rotation is a CAS on
//!   the epoch counter; the winning thread clears the reclaimed slot.
//!   `count()` stays lifetime-monotone in both modes.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;

/// A fixed-footprint latency histogram; `record` is wait-free in the
/// infinite mode and lock-free in the windowed mode (one CAS loop per
/// generation boundary).
pub struct LatencyHist {
    /// Slot-major bucket matrix: bucket `i` of slot `s` lives at
    /// `s * BUCKETS + i`.  The infinite mode has exactly one slot.
    buckets: Vec<AtomicU64>,
    /// Per-slot sample counts (the window's total is their sum).
    slot_counts: Vec<AtomicU64>,
    /// Lifetime sample count; also the generation sequencer.
    total: AtomicU64,
    /// Samples per generation; 0 means infinite horizon.
    window: u64,
    /// Current generation number (windowed mode only).
    epoch: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    /// Infinite-horizon histogram: nothing is ever forgotten.
    pub fn new() -> Self {
        Self::with_slots(0, 1)
    }

    /// Generational histogram: quantiles cover at most the last
    /// `window * n_windows` samples and at least the last
    /// `window * (n_windows - 1)` (the oldest live generation may be
    /// mid-fill when reclaimed).  `n_windows` is clamped to >= 2 so a
    /// rotation never empties the whole histogram at once.
    pub fn windowed(window: u64, n_windows: usize) -> Self {
        Self::with_slots(window.max(1), n_windows.max(2))
    }

    fn with_slots(window: u64, n_slots: usize) -> Self {
        LatencyHist {
            buckets: (0..n_slots * BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            slot_counts: (0..n_slots).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            window,
            epoch: AtomicU64::new(0),
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        let us = (seconds * 1e6).max(0.0) as u64;
        // us in [2^i, 2^{i+1}) -> i; sub-microsecond samples land in 0
        (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    pub fn record(&self, seconds: f64) {
        let seq = self.total.fetch_add(1, Ordering::Relaxed);
        let slot = if self.window == 0 {
            0
        } else {
            let generation = seq / self.window;
            self.advance_to(generation);
            (generation % self.slot_counts.len() as u64) as usize
        };
        if let Some(b) = self.buckets.get(slot * BUCKETS + Self::bucket_of(seconds)) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(c) = self.slot_counts.get(slot) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Raise the epoch to `generation`, clearing each reclaimed slot.
    /// The thread that wins the CAS for a step owns that step's clear,
    /// so a slot is cleared exactly once per rotation.
    fn advance_to(&self, generation: u64) {
        let mut cur = self.epoch.load(Ordering::Acquire);
        while cur < generation {
            match self
                .epoch
                .compare_exchange(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => {
                    let s = ((cur + 1) % self.slot_counts.len() as u64) as usize;
                    for b in self.buckets.iter().skip(s * BUCKETS).take(BUCKETS) {
                        b.store(0, Ordering::Relaxed);
                    }
                    if let Some(c) = self.slot_counts.get(s) {
                        c.store(0, Ordering::Relaxed);
                    }
                    cur += 1;
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Lifetime sample count — monotone in both modes (windowing only
    /// affects which samples weigh on [`quantile`](Self::quantile)).
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Latency (seconds) below which at least a fraction `q` of the
    /// live samples fall (all samples in the infinite mode, the last
    /// `n_windows` generations in the windowed mode), reported as the
    /// covering bucket's upper edge.  Returns 0.0 for an empty window.
    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self
            .slot_counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for i in 0..BUCKETS {
            seen += self
                .buckets
                .iter()
                .skip(i)
                .step_by(BUCKETS)
                .map(|b| b.load(Ordering::Relaxed))
                .sum::<u64>();
            if seen >= target {
                // upper edge of bucket i: 2^{i+1} microseconds
                return 2f64.powi(i as i32 + 1) * 1e-6;
            }
        }
        2f64.powi(BUCKETS as i32) * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bound_known_samples() {
        let h = LatencyHist::new();
        // 99 fast samples at ~100us, one slow at ~50ms
        for _ in 0..99 {
            h.record(100e-6);
        }
        h.record(50e-3);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        // p50/p99 cover the fast mode (within one power of two above)
        assert!(p50 >= 100e-6 && p50 <= 400e-6, "p50 = {p50}");
        assert!(p99 <= 400e-6, "p99 = {p99}");
        // the extreme tail sees the slow sample
        assert!(p999 >= 50e-3 && p999 <= 200e-3, "p999 = {p999}");
        // monotone in q
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn sub_microsecond_and_huge_samples_stay_in_range() {
        let h = LatencyHist::new();
        h.record(0.0);
        h.record(1e-9);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0).is_finite());
    }

    #[test]
    fn windowed_histogram_forgets_old_traffic() {
        let h = LatencyHist::windowed(100, 2);
        // a slow cold-start burst fills both generations
        for _ in 0..200 {
            h.record(50e-3);
        }
        assert!(h.quantile(0.99) >= 50e-3);
        // four generations of fast traffic rotate the slow ones out
        for _ in 0..400 {
            h.record(100e-6);
        }
        assert_eq!(h.count(), 600); // lifetime count stays monotone
        let p99 = h.quantile(0.99);
        assert!(p99 <= 400e-6, "p99 = {p99} still pinned by old traffic");
    }

    #[test]
    fn rotation_reclaims_exactly_the_oldest_generation() {
        let h = LatencyHist::windowed(10, 3);
        // generation 0: slow; generations 1-2: fast — all three live
        for _ in 0..10 {
            h.record(50e-3);
        }
        for _ in 0..20 {
            h.record(100e-6);
        }
        assert!(h.quantile(1.0) >= 50e-3);
        // the 31st sample opens generation 3, reclaiming generation 0's
        // slot: the max drops to the fast mode in one step
        h.record(100e-6);
        assert!(h.quantile(1.0) <= 400e-6);
        assert_eq!(h.count(), 31);
    }

    #[test]
    fn windowed_mode_with_no_rotation_matches_infinite() {
        let inf = LatencyHist::new();
        let win = LatencyHist::windowed(1000, 4);
        for s in [100e-6, 2e-3, 50e-3, 1e-6] {
            inf.record(s);
            win.record(s);
        }
        for q in [0.5, 0.95, 0.99, 1.0] {
            assert_eq!(inf.quantile(q), win.quantile(q));
        }
    }
}
