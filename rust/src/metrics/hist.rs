//! Lock-free log-scale latency histograms for the solve engine's
//! per-kind p50/p95/p99 tables.
//!
//! Samples land in power-of-two microsecond buckets (bucket `i` covers
//! `[2^i, 2^{i+1})` µs), so recording is one atomic increment and the
//! memory footprint is constant regardless of traffic.  Quantiles are
//! read back as the upper edge of the covering bucket — an upper bound
//! with at most 2x resolution error, which is the right bias for
//! latency SLO tables (never under-report a tail).

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 64;

/// A fixed-footprint latency histogram; `record` is wait-free.
pub struct LatencyHist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
        }
    }

    fn bucket_of(seconds: f64) -> usize {
        let us = (seconds * 1e6).max(0.0) as u64;
        // us in [2^i, 2^{i+1}) -> i; sub-microsecond samples land in 0
        (63 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1)
    }

    pub fn record(&self, seconds: f64) {
        let idx = Self::bucket_of(seconds);
        // rsla-lint: allow(L1, bucket_of clamps its result to BUCKETS-1)
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Latency (seconds) below which at least a fraction `q` of the
    /// recorded samples fall, reported as the covering bucket's upper
    /// edge.  Returns 0.0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                // upper edge of bucket i: 2^{i+1} microseconds
                return 2f64.powi(i as i32 + 1) * 1e-6;
            }
        }
        2f64.powi(BUCKETS as i32) * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bound_known_samples() {
        let h = LatencyHist::new();
        // 99 fast samples at ~100us, one slow at ~50ms
        for _ in 0..99 {
            h.record(100e-6);
        }
        h.record(50e-3);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        // p50/p99 cover the fast mode (within one power of two above)
        assert!(p50 >= 100e-6 && p50 <= 400e-6, "p50 = {p50}");
        assert!(p99 <= 400e-6, "p99 = {p99}");
        // the extreme tail sees the slow sample
        assert!(p999 >= 50e-3 && p999 <= 200e-3, "p999 = {p999}");
        // monotone in q
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn empty_histogram_reads_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn sub_microsecond_and_huge_samples_stay_in_range() {
        let h = LatencyHist::new();
        h.record(0.0);
        h.record(1e-9);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0).is_finite());
    }
}
