//! Wall-clock timing helpers for benches and the coordinator.

use std::time::{Duration, Instant};

/// Simple stopwatch with named laps (per-phase profiling in §Perf).
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch {
            start: now,
            last: now,
            laps: Vec::new(),
        }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d) in &self.laps {
            s.push_str(&format!("  {name:<28} {:>10.3} ms\n", d.as_secs_f64() * 1e3));
        }
        s.push_str(&format!(
            "  {:<28} {:>10.3} ms\n",
            "TOTAL",
            self.total().as_secs_f64() * 1e3
        ));
        s
    }
}

/// Run `f` and return (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-k timing for micro-benches: runs `f` k times (at least
/// once), returns (last_result, median_seconds).
pub fn timed_median<T>(k: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let mut out = f();
    let mut times = vec![t0.elapsed().as_secs_f64()];
    for _ in 1..k {
        let t0 = Instant::now();
        out = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    // rsla-lint: allow(L1, index k/2 < times.len() because the loop above pushed max(k,1) samples)
    (out, times[k.max(1) / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.laps()[0].1 >= Duration::from_millis(1));
        assert!(sw.report().contains("TOTAL"));
    }

    #[test]
    fn timed_median_runs_k_times() {
        let mut count = 0;
        let (_, t) = timed_median(5, || {
            count += 1;
            count
        });
        assert_eq!(count, 5);
        assert!(t >= 0.0);
    }
}
