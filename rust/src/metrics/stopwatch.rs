//! Wall-clock timing helpers for benches and the coordinator.

use std::time::{Duration, Instant};

/// Laps a [`Stopwatch`] can hold; `lap` past this drops the lap (the
/// duration is still returned) rather than growing storage.
pub const MAX_LAPS: usize = 32;

/// Simple stopwatch with named laps (per-phase profiling in §Perf).
/// Lap names are `&'static str` and lap storage is a fixed inline
/// array, so `lap` never allocates — it is safe to call from warm
/// paths that carry `no_alloc` pins.
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: [(&'static str, Duration); MAX_LAPS],
    n_laps: usize,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch {
            start: now,
            last: now,
            laps: [("", Duration::ZERO); MAX_LAPS],
            n_laps: 0,
        }
    }

    /// Record the time since the previous lap under `name`.
    /// Allocation-free: the name is a static label and the lap lands in
    /// preallocated inline storage (laps past [`MAX_LAPS`] are dropped).
    // rsla-lint: no_alloc
    pub fn lap(&mut self, name: &'static str) -> Duration {
        let now = Instant::now();
        let d = now.checked_duration_since(self.last).unwrap_or_default();
        self.last = now;
        if let Some(slot) = self.laps.get_mut(self.n_laps) {
            *slot = (name, d);
            self.n_laps += 1;
        }
        d
    }

    pub fn total(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn laps(&self) -> &[(&'static str, Duration)] {
        self.laps.get(..self.n_laps).unwrap_or(&[])
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d) in self.laps() {
            s.push_str(&format!("  {name:<28} {:>10.3} ms\n", d.as_secs_f64() * 1e3));
        }
        s.push_str(&format!(
            "  {:<28} {:>10.3} ms\n",
            "TOTAL",
            self.total().as_secs_f64() * 1e3
        ));
        s
    }
}

/// Run `f` and return (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median-of-k timing for micro-benches: runs `f` k times (at least
/// once), returns (last_result, median_seconds).
pub fn timed_median<T>(k: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let mut out = f();
    let mut times = vec![t0.elapsed().as_secs_f64()];
    for _ in 1..k {
        let t0 = Instant::now();
        out = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    // rsla-lint: allow(L1, index k/2 < times.len() because the loop above pushed max(k,1) samples)
    (out, times[k.max(1) / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(1));
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert_eq!(sw.laps()[0].0, "a");
        assert!(sw.laps()[0].1 >= Duration::from_millis(1));
        assert!(sw.report().contains("TOTAL"));
    }

    #[test]
    fn laps_past_capacity_are_dropped_not_grown() {
        let mut sw = Stopwatch::new();
        for _ in 0..MAX_LAPS + 5 {
            sw.lap("x");
        }
        assert_eq!(sw.laps().len(), MAX_LAPS);
        // the duration is still measured and returned for dropped laps
        assert!(sw.lap("y") >= Duration::ZERO);
        assert_eq!(sw.laps().len(), MAX_LAPS);
    }

    #[test]
    fn timed_median_runs_k_times() {
        let mut count = 0;
        let (_, t) = timed_median(5, || {
            count += 1;
            count
        });
        assert_eq!(count, 5);
        assert!(t >= 0.0);
    }
}
