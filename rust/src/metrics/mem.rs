//! Byte-accurate memory accounting for solver working sets.
//!
//! `MemTracker` is a cheap atomic current/peak pair.  Solvers wrap their
//! large buffers in [`TrackedBuf`] (or call `add`/`sub` for matrices they
//! borrow) so that the peak reported in benches is a *measured* count of
//! bytes held, not a model.  The naive-autograd tape (Fig. 2's O(k·n)
//! growth) and the distributed per-rank working sets use the same
//! mechanism.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::sparse::align::AlignedVec;

/// Current/peak byte counter; clone-shareable across threads.
#[derive(Clone, Default)]
pub struct MemTracker {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    current: AtomicU64,
    peak: AtomicU64,
}

impl MemTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, bytes: u64) {
        let cur = self.inner.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.inner.peak.fetch_max(cur, Ordering::Relaxed);
    }

    pub fn sub(&self, bytes: u64) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn current(&self) -> u64 {
        self.inner.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Reset peak to the current level (start of a measured region).
    pub fn reset_peak(&self) {
        self.inner
            .peak
            .store(self.current(), Ordering::Relaxed);
    }

    /// Allocate a tracked, zero-initialized, 64-byte-aligned f64
    /// buffer.  Solver work vectors all come from here, which is how
    /// the kernel layer's alignment contract (`docs/kernels.md`)
    /// reaches every Krylov loop without per-solver changes.
    pub fn buf(&self, n: usize) -> TrackedBuf {
        self.add((n * 8) as u64);
        TrackedBuf {
            data: AlignedVec::zeroed(n),
            tracker: self.clone(),
        }
    }

    /// Track an existing allocation for its lifetime (returns a guard).
    pub fn hold(&self, bytes: u64) -> MemGuard {
        self.add(bytes);
        MemGuard {
            bytes,
            tracker: self.clone(),
        }
    }
}

/// An owned, 64-byte-aligned f64 buffer whose bytes are accounted
/// until drop.
pub struct TrackedBuf {
    pub data: AlignedVec<f64>,
    tracker: MemTracker,
}

impl TrackedBuf {
    /// Extract the contents as a plain vector, releasing the accounted
    /// bytes (the buffer is returned to the caller and no longer
    /// counted as solver working set).
    pub fn take(mut self) -> Vec<f64> {
        self.tracker.sub((self.data.len() * 8) as u64);
        std::mem::take(&mut self.data).to_vec()
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        self.tracker.sub((self.data.len() * 8) as u64);
    }
}

impl std::ops::Deref for TrackedBuf {
    type Target = [f64];
    fn deref(&self) -> &[f64] {
        &self.data
    }
}

impl std::ops::DerefMut for TrackedBuf {
    fn deref_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }
}

/// RAII guard for borrowed allocations (e.g. the input matrix itself).
pub struct MemGuard {
    bytes: u64,
    tracker: MemTracker,
}

impl Drop for MemGuard {
    fn drop(&mut self) {
        self.tracker.sub(self.bytes);
    }
}

/// Bytes held by a CSR matrix: indptr (8B) + indices (8B) + vals (8B).
pub fn csr_bytes(nrows: usize, nnz: usize) -> u64 {
    ((nrows + 1) * 8 + nnz * 16) as u64
}

/// Process-wide tally of bytes allocated by `CachedFactor::solve` /
/// `solve_t` (each returns a fresh `Vec`).  `solve_into` adds nothing,
/// which is exactly what the serve bench asserts for per-Krylov-
/// iteration preconditioner applications (`BlockDirect`, AMG's coarse
/// solve): a measured zero, not a claim.
static FACTOR_SOLVE_ALLOC: AtomicU64 = AtomicU64::new(0);

pub fn note_factor_solve_alloc(bytes: u64) {
    FACTOR_SOLVE_ALLOC.fetch_add(bytes, Ordering::Relaxed);
}

/// Cumulative bytes allocated by factor solves so far (monotonic).
pub fn factor_solve_alloc_bytes() -> u64 {
    FACTOR_SOLVE_ALLOC.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let t = MemTracker::new();
        {
            let _a = t.buf(1000); // 8000 B
            assert_eq!(t.current(), 8000);
            {
                let _b = t.buf(500); // +4000
                assert_eq!(t.peak(), 12000);
            }
            assert_eq!(t.current(), 8000);
        }
        assert_eq!(t.current(), 0);
        assert_eq!(t.peak(), 12000);
        t.reset_peak();
        assert_eq!(t.peak(), 0);
    }

    #[test]
    fn buffers_are_64_byte_aligned_and_take_releases() {
        let t = MemTracker::new();
        let mut b = t.buf(33);
        assert_eq!(b.as_ptr() as usize % 64, 0);
        b[32] = 1.5;
        assert_eq!(t.current(), 33 * 8);
        let v = b.take();
        assert_eq!(v.len(), 33);
        assert_eq!(v[32], 1.5);
        assert_eq!(t.current(), 0);
    }

    #[test]
    fn guard_releases() {
        let t = MemTracker::new();
        {
            let _g = t.hold(1024);
            assert_eq!(t.current(), 1024);
        }
        assert_eq!(t.current(), 0);
    }
}
