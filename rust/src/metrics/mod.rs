//! Metrics: wall-clock stopwatches, counters, and the device-memory model.
//!
//! The paper reports peak memory per solve (Tables 3-4, Fig. 2) and OOM
//! walls.  This testbed has no CUDA allocator to interrogate, so solver
//! memory is *accounted*: every solver registers the buffers it holds via
//! [`mem::MemTracker`] (measured `len * 8` bytes, not estimates), and the
//! accelerator backends check the accounted requirement against a
//! configurable budget before running — reproducing the OOM rows as
//! budget violations backed by real byte counts.

pub mod hist;
pub mod mem;
pub mod stopwatch;

pub use hist::LatencyHist;
pub use mem::MemTracker;
pub use stopwatch::Stopwatch;

use std::collections::HashMap;
use std::sync::Mutex;

/// Process-wide named counters/gauges used by the coordinator
/// (requests routed per backend, batches formed, halo bytes moved...).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<HashMap<String, u64>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = self.counters.lock().unwrap();
        *m.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn get(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// Sorted snapshot for reports.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let m = self.counters.lock().unwrap();
        let mut v: Vec<_> = m.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_counts() {
        let r = Registry::new();
        r.incr("solves", 2);
        r.incr("solves", 3);
        assert_eq!(r.get("solves"), 5);
        assert_eq!(r.get("missing"), 0);
        assert_eq!(r.snapshot(), vec![("solves".to_string(), 5)]);
    }
}
