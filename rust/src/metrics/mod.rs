//! Metrics: wall-clock stopwatches, counters, and the device-memory model.
//!
//! The paper reports peak memory per solve (Tables 3-4, Fig. 2) and OOM
//! walls.  This testbed has no CUDA allocator to interrogate, so solver
//! memory is *accounted*: every solver registers the buffers it holds via
//! [`mem::MemTracker`] (measured `len * 8` bytes, not estimates), and the
//! accelerator backends check the accounted requirement against a
//! configurable budget before running — reproducing the OOM rows as
//! budget violations backed by real byte counts.

pub mod hist;
pub mod mem;
pub mod names;
pub mod stopwatch;

pub use hist::LatencyHist;
pub use mem::MemTracker;
pub use stopwatch::Stopwatch;

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::lock_recover;

/// Process-wide named counters/gauges used by the coordinator
/// (requests routed per backend, batches formed, halo bytes moved...).
///
/// Counter names are declared once in [`names`]; lint rule L4 checks
/// that every literal passed to [`incr`](Registry::incr)/[`get`](Registry::get)
/// in library code is a declared name.  Locking goes through
/// [`lock_recover`], so a worker that panics mid-increment cannot wedge
/// metrics reporting for every other thread in the process.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<HashMap<String, u64>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-global registry, for layers with no engine handle to
    /// thread one through (the backend dispatch path records its SpMV
    /// format choices here; `rsla solve` reads them back).  Engine
    /// instances still carry their own registries.
    pub fn global() -> &'static Registry {
        static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut m = lock_recover(&self.counters);
        *m.entry(name.to_string()).or_insert(0) += by;
    }

    /// Increment `base.label` — the dynamic-suffix form for per-kind /
    /// per-backend counters.  `base` must be a declared name in
    /// [`names`]; the label (a job kind, a backend name) is appended at
    /// runtime.
    pub fn incr_labeled(&self, base: &str, label: &str, by: u64) {
        let mut m = lock_recover(&self.counters);
        let mut name = String::with_capacity(base.len() + 1 + label.len());
        name.push_str(base);
        name.push('.');
        name.push_str(label);
        *m.entry(name).or_insert(0) += by;
    }

    pub fn get(&self, name: &str) -> u64 {
        *lock_recover(&self.counters).get(name).unwrap_or(&0)
    }

    /// Sorted snapshot for reports.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let m = lock_recover(&self.counters);
        let mut v: Vec<_> = m.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_counts() {
        let r = Registry::new();
        r.incr("solves", 2);
        r.incr("solves", 3);
        assert_eq!(r.get("solves"), 5);
        assert_eq!(r.get("missing"), 0);
        assert_eq!(r.snapshot(), vec![("solves".to_string(), 5)]);
    }

    #[test]
    fn labeled_incr_composes_the_full_name() {
        let r = Registry::new();
        r.incr_labeled(names::ENGINE_COMPLETED, "linear", 2);
        r.incr_labeled(names::ENGINE_COMPLETED, "eig", 1);
        assert_eq!(r.get("engine.completed.linear"), 2);
        assert_eq!(r.get("engine.completed.eig"), 1);
    }

    #[test]
    fn registry_survives_a_panic_while_locked() {
        // Poison the counters mutex the way a panicking worker would,
        // then check that every Registry operation still works: the
        // whole point of lock_recover (satellite 3 regression).
        let r = Registry::new();
        r.incr("solves", 1);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = r.counters.lock().unwrap();
            panic!("worker died holding the metrics lock");
        }));
        assert!(res.is_err());
        assert!(r.counters.is_poisoned());
        r.incr("solves", 2);
        assert_eq!(r.get("solves"), 3);
        assert_eq!(r.snapshot(), vec![("solves".to_string(), 3)]);
    }
}
