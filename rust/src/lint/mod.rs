//! `rsla-lint` — the repo-invariant static-analysis pass.
//!
//! The library's correctness contract is bitwise determinism (frozen FP
//! schedules pinned by `krylov_equivalence`, refactor-vs-cold,
//! fused-vs-per-request) and its serving contract is no worker death and
//! no deadlock across three mutex-bearing subsystems.  Those contracts
//! are invisible to `rustc` and `clippy`; this pass makes them
//! machine-checked.  Rules (catalog + rationale in
//! `docs/static_analysis.md`):
//!
//! * **L1** no-panic-in-library: `unwrap`/`expect`/`panic!`/
//!   `unreachable!`/`todo!`/`unimplemented!` forbidden outside tests and
//!   binaries; `[idx]` indexing additionally forbidden in the strict
//!   control-plane modules ([`rules::STRICT_INDEX_MODULES`]).
//! * **L2** lock-ordering against the hierarchy in [`lock_order`], plus
//!   no tracked guard held across a reply-callback / `solver_fn` site.
//! * **L3** determinism: float accumulation inside `HashMap`/`HashSet`
//!   iteration, `par_iter`-style unordered reductions.
//! * **L4** metrics hygiene: every metric name literal is declared
//!   exactly once in `metrics/names.rs`; dynamic names go through
//!   `incr_labeled`.
//! * **L5** no-alloc-on-warm-path: bodies annotated
//!   `// rsla-lint: no_alloc` must not allocate.
//!
//! Suppression is per-site and must carry a reason:
//! `// rsla-lint: allow(L1, why this site is safe)` on the offending
//! line or the line above.  Dense index kernels may instead annotate
//! `// rsla-lint: allow_item(L1, why the whole body is safe)` above a
//! `fn`/`for`/`while`/`loop` to suppress the rule for that one
//! brace-matched body (same binding rule as `no_alloc`).  A reasonless
//! `allow`/`allow_item` is itself an error, as is an `allow_item` with
//! no following body.
//!
//! Run as `cargo run --bin rsla-lint -- rust/src` (CI blocks on it).

pub mod lock_order;
pub mod rules;
pub mod scanner;

use std::fmt;
use std::path::{Path, PathBuf};

use scanner::SourceFile;

/// One finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the scan root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id: L1..L5, or ANN for malformed annotations.
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Lint every `.rs` file under `root` (sorted walk, deterministic
/// output order).  Returns diagnostics; empty means the tree is clean.
pub fn run(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut scanned = Vec::with_capacity(files.len());
    for path in &files {
        let raw = std::fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        scanned.push(SourceFile::scan(&rel, raw));
    }
    Ok(lint_files(&scanned))
}

/// Rule passes over already-scanned files (the self-test corpus enters
/// here without touching the filesystem).
pub fn lint_files(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let registered = rules::l4_collect_registered(files, &mut diags);
    for f in files {
        rules::check_annotations(f, &mut diags);
        rules::l1_no_panic(f, &mut diags);
        rules::l2_lock_order(f, &mut diags);
        rules::l3_determinism(f, &mut diags);
        rules::l4_metric_names(f, &registered, &mut diags);
        rules::l5_no_alloc(f, &mut diags);
    }
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    diags
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_snippet(rel: &str, src: &str) -> Vec<Diagnostic> {
        lint_files(&[SourceFile::scan(rel, src.to_string())])
    }

    // ---------------- fixture corpus: one firing + one suppressed ----
    // snippet per rule, pinning fire/no-fire behavior (acceptance
    // criterion of the lint PR).

    #[test]
    fn l1_fires_on_unwrap_and_respects_allow() {
        let fire = lint_snippet("engine/x.rs", "fn f(o: Option<u8>) { o.unwrap(); }\n");
        assert!(
            fire.iter().any(|d| d.rule == "L1" && d.message.contains("unwrap")),
            "expected an L1 unwrap finding, got {fire:?}"
        );
        let ok = lint_snippet(
            "engine/x.rs",
            "fn f(o: Option<u8>) {\n    // rsla-lint: allow(L1, value guaranteed by caller)\n    o.unwrap();\n}\n",
        );
        assert!(ok.is_empty(), "allow(L1, reason) must suppress: {ok:?}");
    }

    #[test]
    fn l1_exempts_tests_and_binaries() {
        let in_test = lint_snippet(
            "engine/x.rs",
            "#[cfg(test)]\nmod tests {\n    fn t(o: Option<u8>) { o.unwrap(); }\n}\n",
        );
        assert!(in_test.is_empty(), "{in_test:?}");
        let in_bin = lint_snippet("bin/tool.rs", "fn main() { None::<u8>.unwrap(); }\n");
        assert!(in_bin.is_empty(), "{in_bin:?}");
        let in_main = lint_snippet("main.rs", "fn main() { None::<u8>.unwrap(); }\n");
        assert!(in_main.is_empty(), "{in_main:?}");
    }

    #[test]
    fn l1_indexing_only_in_strict_modules() {
        let strict = lint_snippet("factor_cache/x.rs", "fn f(v: &[u8]) -> u8 { v[0] }\n");
        assert!(
            strict.iter().any(|d| d.rule == "L1" && d.message.contains("index")),
            "{strict:?}"
        );
        let kernel = lint_snippet("krylov/x.rs", "fn f(v: &[u8]) -> u8 { v[0] }\n");
        assert!(
            kernel.is_empty(),
            "iterative kernels are exempt from the indexing sub-rule: {kernel:?}"
        );
        // a lifetime before `[` opens a slice type, not an index
        let lifetime = lint_snippet(
            "trace/x.rs",
            "struct P<'a> {\n    bytes: &'a [u8],\n}\nfn f(p: &P<'static>) -> &'static [u8] { &[] }\n",
        );
        assert!(
            lifetime.is_empty(),
            "slice types after lifetimes are not indexing: {lifetime:?}"
        );
        let suppressed = lint_snippet(
            "factor_cache/x.rs",
            "fn f(v: &[u8]) -> u8 {\n    // rsla-lint: allow(L1, len checked by caller)\n    v[0]\n}\n",
        );
        assert!(suppressed.is_empty(), "{suppressed:?}");
    }

    #[test]
    fn direct_module_is_strict_indexed() {
        let strict = lint_snippet("direct/x.rs", "fn f(v: &[u8]) -> u8 { v[0] }\n");
        assert!(
            strict.iter().any(|d| d.rule == "L1" && d.message.contains("index")),
            "direct/ must be under the strict-indexing sub-rule: {strict:?}"
        );
    }

    #[test]
    fn allow_item_suppresses_the_whole_body() {
        // one annotation covers every indexing site in the fn body
        let ok = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: allow_item(L1, loop bounds are invariants of the panel layout)\nfn f(v: &[u8]) -> u8 {\n    let a = v[0];\n    let b = v[1];\n    a + b\n}\n",
        );
        assert!(ok.is_empty(), "allow_item must cover the full body: {ok:?}");
        // ...but only for the named rule: an L5 violation inside the
        // same (no_alloc) body still fires
        let other_rule = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: no_alloc\n// rsla-lint: allow_item(L1, loop bounds are invariants)\nfn f(v: &[f64]) -> Vec<f64> {\n    let _a = v[0];\n    v.to_vec()\n}\n",
        );
        assert!(
            other_rule.iter().all(|d| d.rule != "L1"),
            "allow_item(L1) must cover the indexing: {other_rule:?}"
        );
        assert!(
            other_rule.iter().any(|d| d.rule == "L5"),
            "allow_item(L1) must not suppress L5: {other_rule:?}"
        );
        // ...and only for that one body: a sibling fn is not covered
        let sibling = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: allow_item(L1, first body only)\nfn f(v: &[u8]) -> u8 { v[0] }\nfn g(v: &[u8]) -> u8 { v[1] }\n",
        );
        assert!(
            sibling.iter().any(|d| d.rule == "L1" && d.line == 3),
            "allow_item must not leak past the annotated body: {sibling:?}"
        );
    }

    #[test]
    fn malformed_allow_item_is_an_error() {
        // reasonless
        let no_reason = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: allow_item(L1)\nfn f(v: &[u8]) -> u8 { v[0] }\n",
        );
        assert!(
            no_reason.iter().any(|d| d.rule == "ANN" && d.message.contains("reason")),
            "{no_reason:?}"
        );
        // no following body to bind to
        let dangling = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: allow_item(L1, dangling)\nconst X: u8 = 0;\n",
        );
        assert!(
            dangling.iter().any(|d| d.rule == "ANN" && d.message.contains("body")),
            "{dangling:?}"
        );
    }

    #[test]
    fn l2_fires_on_inverted_order_and_callback_under_lock() {
        // counters (tier 3) held while acquiring inner (tier 2): inverted
        let fire = lint_snippet(
            "metrics/x.rs",
            "fn f(&self) {\n    let g = self.counters.lock().unwrap();\n    let h = self.inner.lock().unwrap();\n    drop(h); drop(g);\n}\n",
        );
        assert!(
            fire.iter().any(|d| d.rule == "L2" && d.message.contains("tier")),
            "{fire:?}"
        );
        // legal direction: inner then counters
        let ok = lint_snippet(
            "factor_cache/x.rs",
            "fn f(&self) {\n    let g = self.inner.lock().unwrap();\n    let h = self.counters.lock().unwrap();\n    drop(h); drop(g);\n}\n",
        );
        assert!(ok.iter().all(|d| d.rule != "L2"), "{ok:?}");
        // reply under a tracked guard
        let cb = lint_snippet(
            "engine/x.rs",
            "fn f(&self) {\n    let g = self.intake.lock().unwrap();\n    reply(result);\n    drop(g);\n}\n",
        );
        assert!(
            cb.iter().any(|d| d.rule == "L2" && d.message.contains("callback")),
            "{cb:?}"
        );
        // suppressed
        let sup = lint_snippet(
            "metrics/x.rs",
            "fn f(&self) {\n    let g = self.counters.lock().unwrap();\n    // rsla-lint: allow(L2, single-threaded init path)\n    let h = self.inner.lock().unwrap();\n    drop(h); drop(g);\n}\n",
        );
        assert!(sup.iter().all(|d| d.rule != "L2"), "{sup:?}");
    }

    #[test]
    fn l2_guard_dropped_before_acquisition_is_clean() {
        let ok = lint_snippet(
            "engine/x.rs",
            "fn f(&self) {\n    let g = self.counters.lock().unwrap();\n    drop(g);\n    let h = self.intake.lock().unwrap();\n    drop(h);\n}\n",
        );
        assert!(ok.iter().all(|d| d.rule != "L2"), "{ok:?}");
        // temporary guard (consumed same statement) does not leak liveness
        let tmp = lint_snippet(
            "engine/x.rs",
            "fn f(&self) {\n    let tx = self.intake.lock().unwrap().take();\n    let h = self.counters.lock().unwrap();\n    drop(h);\n}\n",
        );
        assert!(tmp.iter().all(|d| d.rule != "L2"), "{tmp:?}");
    }

    #[test]
    fn l3_fires_on_float_accumulation_over_hashmap() {
        let fire = lint_snippet(
            "sparse/x.rs",
            "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f64>) -> f64 {\n    let mut acc = 0.0;\n    for (_, v) in m {\n        acc += v;\n    }\n    acc\n}\n",
        );
        assert!(fire.iter().any(|d| d.rule == "L3"), "{fire:?}");
        let ok = lint_snippet(
            "sparse/x.rs",
            "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f64>) -> f64 {\n    let mut keys: Vec<u32> = m.keys().copied().collect();\n    keys.sort_unstable();\n    let mut acc = 0.0;\n    for k in keys { acc += 1.0; }\n    acc\n}\n",
        );
        assert!(ok.iter().all(|d| d.rule != "L3"), "sorted-key iteration is fine: {ok:?}");
        let sup = lint_snippet(
            "sparse/x.rs",
            "use std::collections::HashMap;\nfn f(m: &HashMap<u32, u64>) -> u64 {\n    let mut acc = 0;\n    for (_, v) in m {\n        // rsla-lint: allow(L3, integer accumulation is order-independent)\n        acc += v;\n    }\n    acc\n}\n",
        );
        assert!(sup.iter().all(|d| d.rule != "L3"), "{sup:?}");
    }

    #[test]
    fn l4_checks_names_against_the_declared_registry() {
        let names = SourceFile::scan(
            "metrics/names.rs",
            "pub const A: &str = \"engine.good\";\n".to_string(),
        );
        let user_bad = SourceFile::scan(
            "engine/x.rs",
            "fn f(r: &Registry) { r.incr(\"engine.bogus\", 1); }\n".to_string(),
        );
        let diags = lint_files(&[names, user_bad]);
        assert!(
            diags.iter().any(|d| d.rule == "L4" && d.message.contains("engine.bogus")),
            "{diags:?}"
        );

        let names = SourceFile::scan(
            "metrics/names.rs",
            "pub const A: &str = \"engine.good\";\n".to_string(),
        );
        let user_ok = SourceFile::scan(
            "engine/x.rs",
            "fn f(r: &Registry) { r.incr(\"engine.good\", 1); }\n".to_string(),
        );
        assert!(lint_files(&[names, user_ok]).is_empty());

        // double declaration fires
        let dup = SourceFile::scan(
            "metrics/names.rs",
            "pub const A: &str = \"engine.twice\";\npub const B: &str = \"engine.twice\";\n"
                .to_string(),
        );
        let diags = lint_files(&[dup]);
        assert!(
            diags.iter().any(|d| d.rule == "L4" && d.message.contains("twice")),
            "{diags:?}"
        );
    }

    #[test]
    fn l4_flags_format_built_names() {
        let names = SourceFile::scan(
            "metrics/names.rs",
            "pub const A: &str = \"engine.completed\";\n".to_string(),
        );
        let dynamic = SourceFile::scan(
            "engine/x.rs",
            "fn f(r: &Registry, k: &str) { r.incr(&format!(\"engine.completed.{k}\"), 1); }\n"
                .to_string(),
        );
        let diags = lint_files(&[names, dynamic]);
        assert!(
            diags.iter().any(|d| d.rule == "L4" && d.message.contains("incr_labeled")),
            "{diags:?}"
        );
    }

    #[test]
    fn l4_covers_trace_span_names_and_cross_file_duplicates() {
        // a literal passed to a trace probe must be declared in
        // trace/names.rs (or metrics/names.rs — one shared registry)
        let names = SourceFile::scan(
            "trace/names.rs",
            "pub const A: &str = \"job.exec\";\n".to_string(),
        );
        let user_bad = SourceFile::scan(
            "engine/x.rs",
            "fn f() { let _s = trace::span(\"job.bogus\"); }\n".to_string(),
        );
        let diags = lint_files(&[names, user_bad]);
        assert!(
            diags.iter().any(|d| d.rule == "L4" && d.message.contains("job.bogus")),
            "{diags:?}"
        );

        let names = SourceFile::scan(
            "trace/names.rs",
            "pub const A: &str = \"job.exec\";\n".to_string(),
        );
        let user_ok = SourceFile::scan(
            "engine/x.rs",
            "fn f(id: u64) { trace::event_job(\"job.exec\", id, \"linear\", 0); }\n".to_string(),
        );
        assert!(lint_files(&[names, user_ok]).is_empty());

        // the same name declared in BOTH registries is a duplicate
        let m = SourceFile::scan(
            "metrics/names.rs",
            "pub const A: &str = \"engine.completed\";\n".to_string(),
        );
        let t = SourceFile::scan(
            "trace/names.rs",
            "pub const B: &str = \"engine.completed\";\n".to_string(),
        );
        let diags = lint_files(&[m, t]);
        assert!(
            diags
                .iter()
                .any(|d| d.rule == "L4" && d.message.contains("declared twice")),
            "{diags:?}"
        );
    }

    #[test]
    fn trace_module_is_strict_indexed() {
        let strict = lint_snippet("trace/x.rs", "fn f(v: &[u8]) -> u8 { v[0] }\n");
        assert!(
            strict.iter().any(|d| d.rule == "L1" && d.message.contains("index")),
            "trace/ must be under the strict-indexing sub-rule: {strict:?}"
        );
    }

    #[test]
    fn l5_fires_inside_no_alloc_bodies_only() {
        let fire = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: no_alloc\nfn f(xs: &[f64]) -> Vec<f64> { xs.to_vec() }\n",
        );
        assert!(fire.iter().any(|d| d.rule == "L5"), "{fire:?}");
        let unannotated = lint_snippet("direct/x.rs", "fn f(xs: &[f64]) -> Vec<f64> { xs.to_vec() }\n");
        assert!(unannotated.is_empty(), "{unannotated:?}");
        let sup = lint_snippet(
            "direct/x.rs",
            "// rsla-lint: no_alloc\nfn f(xs: &[f64]) -> Vec<f64> {\n    // rsla-lint: allow(L5, one-time setup before the hot loop)\n    xs.to_vec()\n}\n",
        );
        assert!(sup.is_empty(), "{sup:?}");
        // loop-scoped annotation: setup may allocate, the loop may not
        let loop_scoped = lint_snippet(
            "krylov/x.rs",
            "fn f(n: usize) {\n    let mut v = Vec::new();\n    // rsla-lint: no_alloc\n    while v.len() < n {\n        v.push(0.0);\n    }\n}\n",
        );
        assert!(loop_scoped.is_empty(), "{loop_scoped:?}");
        let loop_fire = lint_snippet(
            "krylov/x.rs",
            "fn f(n: usize) {\n    // rsla-lint: no_alloc\n    for _ in 0..n {\n        let v = Vec::new();\n        drop(v);\n    }\n}\n",
        );
        assert!(loop_fire.iter().any(|d| d.rule == "L5"), "{loop_fire:?}");
    }

    #[test]
    fn reasonless_allow_is_an_error() {
        let diags = lint_snippet(
            "engine/x.rs",
            "fn f(o: Option<u8>) {\n    // rsla-lint: allow(L1)\n    o.unwrap();\n}\n",
        );
        assert!(
            diags.iter().any(|d| d.rule == "ANN" && d.message.contains("reason")),
            "{diags:?}"
        );
    }

    #[test]
    fn the_repo_tree_is_clean() {
        // The gate CI enforces, runnable as a plain unit test: zero
        // unannotated violations across rust/src.  CARGO_MANIFEST_DIR
        // points at rust/, so the scan root is <manifest>/src.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let diags = run(&root).expect("scan rust/src");
        assert!(
            diags.is_empty(),
            "rsla-lint found {} violation(s):\n{}",
            diags.len(),
            diags
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
