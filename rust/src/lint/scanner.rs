//! The hand-rolled source scanner behind `rsla-lint`.
//!
//! `rsla-lint` deliberately carries no parser dependency (`syn` would
//! drag in proc-macro2 and break the offline build), so rules operate
//! on a *stripped* view of each file produced here:
//!
//! * comments (line, nested block) and the contents of string / raw
//!   string / char literals are blanked to spaces, **byte-for-byte** —
//!   every remaining token sits at its original offset, so positions
//!   in the stripped text index directly into the raw text;
//! * `// rsla-lint: ...` annotations are collected per line while
//!   comments are stripped;
//! * `#[cfg(test)]` (and `#[cfg(all(test, ...))]` etc.) item regions
//!   are brace-matched so rules can exempt test code.
//!
//! The trade-off is lexical, not semantic, precision: rules match
//! token shapes, and the escape hatch for the false positive they
//! cannot see through is an explicit, reasoned
//! `// rsla-lint: allow(RULE, reason)`.

use std::collections::HashMap;

/// A parsed `// rsla-lint:` annotation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Annotation {
    /// `allow(RULE, reason)` — suppress RULE on this or the next line.
    Allow { rule: String, reason: String },
    /// `allow_item(RULE, reason)` — suppress RULE across the whole
    /// `fn`/`for`/`while`/`loop` body that follows the annotation
    /// (same binding rule as `no_alloc`).  For dense index kernels one
    /// reasoned item-scope allow beats a hundred per-line ones.
    AllowItem { rule: String, reason: String },
    /// `allow(RULE)` / `allow_item(RULE)` with no reason — collected so
    /// the driver can reject it (reasons are mandatory).
    AllowNoReason { rule: String },
    /// `no_alloc` — the next `fn`/loop body must not allocate (L5).
    NoAlloc,
}

/// One scanned source file.
pub struct SourceFile {
    /// Path relative to the scan root, with `/` separators.
    pub rel: String,
    /// Raw source text.
    pub raw: String,
    /// Stripped text: identical length/line structure to `raw`, with
    /// comments and literal contents blanked.
    pub code: String,
    /// `rsla-lint:` annotations by (1-based) line number of the comment.
    pub annotations: HashMap<usize, Vec<Annotation>>,
    /// Byte offset of the start of each (1-based) line in `code`.
    line_starts: Vec<usize>,
    /// Byte ranges of `#[cfg(test)]` item bodies in `code`.
    pub test_regions: Vec<(usize, usize)>,
    /// Item-scoped allows: inclusive line ranges bound by
    /// `allow_item(RULE, reason)` annotations, with the allowed rule.
    item_allows: Vec<(usize, usize, String)>,
}

impl SourceFile {
    pub fn scan(rel: &str, raw: String) -> SourceFile {
        let (code, annotations) = strip(&raw);
        let line_starts = line_starts_of(&code);
        let test_regions = test_regions_of(&code);
        let mut sf = SourceFile {
            rel: rel.to_string(),
            raw,
            code,
            annotations,
            line_starts,
            test_regions,
            item_allows: Vec::new(),
        };
        let mut entries: Vec<(usize, Vec<String>)> = sf
            .annotations
            .iter()
            .map(|(line, anns)| {
                let rules = anns
                    .iter()
                    .filter_map(|a| match a {
                        Annotation::AllowItem { rule, .. } => Some(rule.clone()),
                        _ => None,
                    })
                    .collect::<Vec<_>>();
                (*line, rules)
            })
            .filter(|(_, rules)| !rules.is_empty())
            .collect();
        entries.sort_unstable();
        let mut allows = Vec::new();
        for (line, rules) in entries {
            if let Some((start, end)) = sf.item_region(line) {
                let (ls, le) = (sf.line_of(start), sf.line_of(end));
                for rule in rules {
                    allows.push((ls, le, rule));
                }
            }
        }
        sf.item_allows = allows;
        sf
    }

    /// The brace-matched item body an `allow_item`/`no_alloc` annotation
    /// at `ann_line` binds to: the first `fn`/`for`/`while`/`loop`
    /// keyword within a few lines below, then its first `{...}` block.
    /// None when no item follows (rules flag that as a malformed
    /// annotation).
    pub fn item_region(&self, ann_line: usize) -> Option<(usize, usize)> {
        let mut kw_line = None;
        'probe: for probe in ann_line..ann_line + 6 {
            let text = self.code_line(probe);
            for kw in ["fn ", "for ", "while ", "loop"] {
                if let Some(col) = text.find(kw) {
                    let standalone = col == 0
                        || text
                            .get(..col)
                            .and_then(|p| p.chars().last())
                            .map(|c| !(c.is_ascii_alphanumeric() || c == '_'))
                            .unwrap_or(true);
                    if standalone {
                        kw_line = Some(probe);
                        break 'probe;
                    }
                }
            }
        }
        let kw_line = kw_line?;
        let offset = *self.line_starts.get(kw_line.saturating_sub(1))?;
        let open = offset + self.code.get(offset..)?.find('{')?;
        let close = matching_brace(&self.code, open)?;
        Some((open, close))
    }

    /// 1-based line number of byte offset `pos`.
    pub fn line_of(&self, pos: usize) -> usize {
        match self.line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Is `pos` inside a `#[cfg(test)]` region?
    pub fn in_test_region(&self, pos: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= pos && pos <= b)
    }

    /// Does line `line` (or the line above it) carry `allow(rule, ...)`
    /// with a non-empty reason, or fall inside an item body annotated
    /// `allow_item(rule, ...)`?
    pub fn allowed(&self, line: usize, rule: &str) -> bool {
        for l in [line, line.saturating_sub(1)] {
            if let Some(anns) = self.annotations.get(&l) {
                for a in anns {
                    if let Annotation::Allow { rule: r, .. } = a {
                        if r == rule {
                            return true;
                        }
                    }
                }
            }
        }
        self.item_allows
            .iter()
            .any(|(ls, le, r)| *ls <= line && line <= *le && r == rule)
    }

    /// The stripped text of 1-based line `line` (empty if out of range).
    pub fn code_line(&self, line: usize) -> &str {
        let start = match self.line_starts.get(line.saturating_sub(1)) {
            Some(&s) => s,
            None => return "",
        };
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.code.len());
        self.code.get(start..end).unwrap_or("")
    }
}

fn line_starts_of(code: &str) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, b) in code.bytes().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

fn parse_annotation(text: &str) -> Option<Annotation> {
    let body = text.strip_prefix("rsla-lint:")?.trim();
    if body == "no_alloc" {
        return Some(Annotation::NoAlloc);
    }
    if let Some(inner) = body.strip_prefix("allow_item(").and_then(|b| b.strip_suffix(')')) {
        return match inner.split_once(',') {
            Some((rule, reason)) if !reason.trim().is_empty() => Some(Annotation::AllowItem {
                rule: rule.trim().to_string(),
                reason: reason.trim().to_string(),
            }),
            _ => Some(Annotation::AllowNoReason {
                rule: inner.trim().to_string(),
            }),
        };
    }
    let inner = body.strip_prefix("allow(")?.strip_suffix(')')?;
    match inner.split_once(',') {
        Some((rule, reason)) if !reason.trim().is_empty() => Some(Annotation::Allow {
            rule: rule.trim().to_string(),
            reason: reason.trim().to_string(),
        }),
        _ => Some(Annotation::AllowNoReason {
            rule: inner.trim().to_string(),
        }),
    }
}

/// Blank comments and literal contents, collecting annotations.
/// The output has exactly the same byte length and newline positions
/// as the input.
fn strip(src: &str) -> (String, HashMap<usize, Vec<Annotation>>) {
    #[derive(PartialEq)]
    enum Mode {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
        Char,
    }
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut annotations: HashMap<usize, Vec<Annotation>> = HashMap::new();
    let mut mode = Mode::Code;
    let mut line = 1usize;
    let mut comment_buf = String::new();
    let mut comment_line = 1usize;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    // push `c` preserving newlines; everything else becomes a space
    fn blank(out: &mut Vec<u8>, c: u8, line: &mut usize) {
        if c == b'\n' {
            out.push(b'\n');
            *line += 1;
        } else {
            out.push(b' ');
        }
    }
    while i < n {
        // rsla-lint: allow(L1, i < n is the loop guard and i+1 is checked)
        let c = bytes[i];
        let next = if i + 1 < n { bytes[i + 1] } else { 0 }; // rsla-lint: allow(L1, i + 1 < n is checked inline)
        match mode {
            Mode::Code => {
                if c == b'/' && next == b'/' {
                    mode = Mode::LineComment;
                    comment_buf.clear();
                    comment_line = line;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && next == b'*' {
                    mode = Mode::BlockComment;
                    block_depth = 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    mode = Mode::Str;
                    out.push(b'"');
                    i += 1;
                } else if c == b'b' && next == b'"' && !prev_is_ident(&out) {
                    // byte string b"...": same escape rules as a string
                    mode = Mode::Str;
                    out.extend_from_slice(b" \"");
                    i += 2;
                } else if (c == b'r' || c == b'b')
                    && (next == b'"' || next == b'#' || next == b'r')
                    && !prev_is_ident(&out)
                {
                    // raw string r"..." / r#"..."# / br#"..."#
                    let mut j = i + 1;
                    if c == b'b' && bytes.get(j) == Some(&b'r') {
                        j += 1;
                    }
                    let mut hashes = 0usize;
                    while bytes.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if bytes.get(j) == Some(&b'"') {
                        mode = Mode::RawStr;
                        raw_hashes = hashes;
                        // blank the prefix, keep the opening quote
                        for _ in i..j {
                            out.push(b' ');
                        }
                        out.push(b'"');
                        i = j + 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == b'\'' {
                    // char literal vs lifetime: a lifetime is ' followed
                    // by an identifier NOT closed by another '
                    if next == b'\\' {
                        mode = Mode::Char;
                        out.push(b'\'');
                        i += 1;
                    // rsla-lint: allow(L1, i + 2 < n is checked first)
                    } else if i + 2 < n && bytes[i + 2] == b'\'' {
                        out.extend_from_slice(b"' '");
                        i += 3;
                    } else {
                        out.push(c); // lifetime marker
                        i += 1;
                    }
                } else {
                    if c == b'\n' {
                        line += 1;
                    }
                    out.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                if c == b'\n' {
                    let text = comment_buf.trim().trim_start_matches(['/', '!']).trim();
                    if text.starts_with("rsla-lint:") {
                        if let Some(a) = parse_annotation(text) {
                            annotations.entry(comment_line).or_default().push(a);
                        }
                    }
                    mode = Mode::Code;
                    out.push(b'\n');
                    line += 1;
                    i += 1;
                } else {
                    comment_buf.push(c as char);
                    out.push(b' ');
                    i += 1;
                }
            }
            Mode::BlockComment => {
                if c == b'/' && next == b'*' {
                    block_depth += 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'*' && next == b'/' {
                    block_depth -= 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                    if block_depth == 0 {
                        mode = Mode::Code;
                    }
                } else {
                    blank(&mut out, c, &mut line);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == b'\\' && i + 1 < n {
                    blank(&mut out, c, &mut line);
                    blank(&mut out, next, &mut line);
                    i += 2;
                } else if c == b'"' {
                    out.push(b'"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    blank(&mut out, c, &mut line);
                    i += 1;
                }
            }
            Mode::RawStr => {
                let closes =
                    c == b'"' && (1..=raw_hashes).all(|k| bytes.get(i + k) == Some(&b'#'));
                if closes {
                    out.push(b'"');
                    for _ in 0..raw_hashes {
                        out.push(b' ');
                    }
                    i += 1 + raw_hashes;
                    mode = Mode::Code;
                } else {
                    blank(&mut out, c, &mut line);
                    i += 1;
                }
            }
            Mode::Char => {
                if c == b'\\' && i + 1 < n {
                    blank(&mut out, c, &mut line);
                    blank(&mut out, next, &mut line);
                    i += 2;
                } else if c == b'\'' {
                    out.push(b'\'');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    blank(&mut out, c, &mut line);
                    i += 1;
                }
            }
        }
    }
    // a trailing line comment without newline still carries annotations
    if mode == Mode::LineComment {
        let text = comment_buf.trim().trim_start_matches(['/', '!']).trim();
        if text.starts_with("rsla-lint:") {
            if let Some(a) = parse_annotation(text) {
                annotations.entry(comment_line).or_default().push(a);
            }
        }
    }
    debug_assert_eq!(out.len(), n, "strip must preserve byte offsets");
    (String::from_utf8_lossy(&out).into_owned(), annotations)
}

/// Would appending `r`/`b` continue an identifier? (avoid treating the
/// `r` of e.g. `attr"` or `for"` as a raw-string sigil)
fn prev_is_ident(out: &[u8]) -> bool {
    out.last()
        .map(|&b| b.is_ascii_alphanumeric() || b == b'_')
        .unwrap_or(false)
}

/// Find every occurrence of `pat` in `hay` starting at or after `from`.
pub fn find_all(hay: &str, pat: &str) -> Vec<usize> {
    let mut found = Vec::new();
    let mut at = 0usize;
    // rsla-lint: allow(L1, at advances by match offsets and stays <= hay.len())
    while let Some(p) = hay[at..].find(pat) {
        found.push(at + p);
        at += p + pat.len().max(1);
    }
    found
}

/// Byte offset of the `{` matching brace-depth entry at `open`, i.e.
/// the position of the closing `}` for the `{` at `open`.
pub fn matching_brace(code: &str, open: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    if bytes.get(open) != Some(&b'{') {
        return None;
    }
    let mut depth = 0isize;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        if b == b'{' {
            depth += 1;
        } else if b == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Brace-matched body ranges of items annotated `#[cfg(test)]` /
/// `#[cfg(all(test, ...))]` / `#[cfg(any(test, ...))]`.
fn test_regions_of(code: &str) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    for pat in ["#[cfg(test)]", "#[cfg(all(test", "#[cfg(any(test"] {
        for start in find_all(code, pat) {
            // rsla-lint: allow(L1, start comes from find_all over the same text)
            if let Some(open_rel) = code[start..].find('{') {
                let open = start + open_rel;
                if let Some(close) = matching_brace(code, open) {
                    regions.push((open, close));
                }
            }
        }
    }
    regions.sort_unstable();
    regions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_preserves_offsets_and_blanks_literals() {
        let src = "let s = \"un wrap() inside\"; // tail\nlet t = 1;\n";
        let (code, _) = strip(src);
        assert_eq!(code.len(), src.len());
        assert!(!code.contains("wrap"));
        assert!(!code.contains("tail"));
        assert!(code.contains("let t = 1;"));
        // newline structure intact
        assert_eq!(
            code.match_indices('\n').count(),
            src.match_indices('\n').count()
        );
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let src = r####"let r = r#"panic!("no")"#; let c = '"'; let l: &'static str = "x";"####;
        let (code, _) = strip(src);
        assert_eq!(code.len(), src.len());
        assert!(!code.contains("panic!"));
        assert!(code.contains("let c ="));
        assert!(code.contains("'static"));
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        let src = "let a = \"x\\\ny\";\nlet b = 2;\n";
        let f = SourceFile::scan("t.rs", src.to_string());
        // the escaped newline is blanked, so line 3 still starts at the
        // same raw offset as in the source
        let pos = f.code.find("let b").expect("let b survives stripping");
        assert_eq!(f.line_of(pos), 3);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* a /* nested */ still comment */ let x = 1;";
        let (code, _) = strip(src);
        assert!(code.contains("let x = 1;"));
        assert!(!code.contains("nested"));
    }

    #[test]
    fn annotations_parse_with_and_without_reason() {
        let src = "// rsla-lint: allow(L1, checked above)\nx();\n// rsla-lint: allow(L2)\ny();\n// rsla-lint: no_alloc\nfn f() {}\n";
        let f = SourceFile::scan("t.rs", src.to_string());
        assert_eq!(
            f.annotations.get(&1),
            Some(&vec![Annotation::Allow {
                rule: "L1".into(),
                reason: "checked above".into()
            }])
        );
        assert_eq!(
            f.annotations.get(&3),
            Some(&vec![Annotation::AllowNoReason { rule: "L2".into() }])
        );
        assert_eq!(f.annotations.get(&5), Some(&vec![Annotation::NoAlloc]));
        assert!(f.allowed(2, "L1"), "allow applies to the next line");
        assert!(!f.allowed(4, "L2"), "reasonless allow must not suppress");
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let f = SourceFile::scan("t.rs", src.to_string());
        let pos = f.code.find(".unwrap").expect("unwrap token present");
        assert!(f.in_test_region(pos));
        let lib = f.code.find("fn lib").expect("fn lib present");
        assert!(!f.in_test_region(lib));
    }
}
