//! The repo's declared lock hierarchy (lint rule **L2**).
//!
//! Locks must be acquired top-down; the tiers, highest first:
//!
//! ```text
//!   tier 0   engine scheduler state        Engine.intake / Engine.threads
//!      |     (submission + lifecycle)
//!      v
//!   tier 1   CacheShards routing           (no mutex today; reserved so the
//!      |                                    planned shared-shard work slots in)
//!      v
//!   tier 2   factor_cache LRU              FactorCache.inner
//!      |
//!      v
//!   tier 3   metrics::Registry             Registry.counters
//!      |
//!      v
//!   tier 4   transport peer channels       ProcComm.peer_streams
//!      |
//!      v
//!   tier 5   transport wait histogram      ProcComm.wait_hist
//! ```
//!
//! Acquiring a *deeper* (higher-numbered) lock while holding a shallower
//! one is legal — that is the call direction: the engine locks intake,
//! workers enter the factor cache, the cache mirrors counters into the
//! registry.  Acquiring a *shallower* lock while a deeper guard is live
//! inverts the order and can deadlock against a thread walking the legal
//! direction; L2 flags it.  L2 also flags holding ANY tracked guard
//! across a reply-callback or `solver_fn` call site: both run
//! caller-supplied code of unknown locking behavior.
//!
//! The checker is lexical: a lock site is recognized by the receiver
//! field it is acquired through (`.lock()` / `.read()` / `.write()` on
//! `intake`, `threads`, `inner`, `counters`, or through
//! `lock_recover(&...)`).  Receivers not named here are untracked.
//! Renaming one of these fields must update this table — the lint
//! self-test corpus pins the tier assignments.

/// (receiver field name, tier, human description).
pub const TIERS: &[(&str, u8, &str)] = &[
    ("intake", 0, "engine scheduler: Engine.intake"),
    ("threads", 0, "engine scheduler: Engine.threads"),
    ("shards", 1, "CacheShards routing state (reserved)"),
    ("inner", 2, "factor_cache LRU: FactorCache.inner"),
    ("counters", 3, "metrics::Registry.counters"),
    ("peer_streams", 4, "transport peer channels: ProcComm.peer_streams"),
    ("wait_hist", 5, "transport wait histogram: ProcComm.wait_hist"),
];

/// Call tokens that run caller-supplied code; no tracked guard may be
/// live across them.
pub const CALLBACK_SITES: &[&str] = &["reply(", "respond(", "respond_timeout(", "solver_fn("];

/// Tier of a receiver field name, if tracked.
pub fn tier_of(field: &str) -> Option<(u8, &'static str)> {
    TIERS
        .iter()
        .find(|(name, _, _)| *name == field)
        .map(|&(_, t, desc)| (t, desc))
}
