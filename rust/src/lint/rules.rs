//! Rule implementations for `rsla-lint`.  Each pass is lexical (see
//! [`super::scanner`]); precision comes from narrow token shapes plus
//! the reasoned `allow` escape hatch, not from type information.

use std::collections::{HashMap, HashSet};

use super::lock_order;
use super::scanner::{find_all, matching_brace, Annotation, SourceFile};
use super::Diagnostic;

/// Modules where plain `[idx]` indexing is an L1 violation: the
/// control-plane layers whose panics take down workers, wedge the
/// scheduler, or poison shared locks — plus `direct/`, whose cached
/// factors are served from those same workers (a panicking solve or
/// refactor kills the worker that holds the factor).  Dense index
/// kernels inside `direct/` annotate one reasoned
/// `allow_item(L1, ...)` per kernel body instead of drowning in
/// per-line allows.  The remaining numeric modules (`krylov/`,
/// `iterative/`, `sparse/`, ...) stay exempt — tight index loops are
/// their idiom and their bounds are loop invariants.
pub const STRICT_INDEX_MODULES: &[&str] = &[
    "engine/",
    "factor_cache/",
    "metrics/",
    "coordinator/",
    "runtime/",
    "lint/",
    "trace/",
    "direct/",
    // the process-transport wire/ring/socket code parses untrusted
    // bytes; a panic there kills a worker mid-collective and wedges
    // the whole rank team
    "distributed/transport/",
];

const L1_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Tokens L5 forbids inside `no_alloc` bodies.
const L5_TOKENS: &[&str] = &[
    "Vec::new",
    "vec![",
    ".to_vec()",
    ".clone()",
    ".collect()",
    "Box::new",
    "format!",
];

/// Keywords that may legitimately precede a `[` opening an array
/// literal (`for x in [..]`, `return [..]`) rather than indexing.
const PRE_BRACKET_KEYWORDS: &[&str] = &[
    "in", "return", "break", "if", "else", "match", "loop", "while", "mut", "ref",
];

fn push(diags: &mut Vec<Diagnostic>, f: &SourceFile, line: usize, rule: &'static str, msg: String) {
    diags.push(Diagnostic {
        file: f.rel.clone(),
        line,
        rule,
        message: msg,
    });
}

/// Binaries never serve library callers; panicking there is normal CLI
/// error handling.
fn is_binary(f: &SourceFile) -> bool {
    f.rel == "main.rs" || f.rel.starts_with("bin/")
}

/// Malformed annotations: `allow` without a reason.
pub fn check_annotations(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    let mut lines: Vec<_> = f.annotations.iter().collect();
    lines.sort_by_key(|(line, _)| **line);
    for (line, anns) in lines {
        for a in anns {
            if let Annotation::AllowNoReason { rule } = a {
                push(
                    diags,
                    f,
                    *line,
                    "ANN",
                    format!(
                        "allow({rule}) has no reason; write allow({rule}, why this site is safe)"
                    ),
                );
            }
            if matches!(a, Annotation::AllowItem { .. }) && f.item_region(*line).is_none() {
                push(
                    diags,
                    f,
                    *line,
                    "ANN",
                    "allow_item annotation is not followed by a fn or loop body".to_string(),
                );
            }
        }
    }
}

/// Is the byte directly before `pos` an identifier char?  Guards
/// macro-name matches (`unreachable!` must not match inside
/// `my_unreachable!`) and keyword matches (`fn ` inside `often `).
fn ident_before(code: &str, pos: usize) -> bool {
    pos > 0
        && code
            .as_bytes()
            .get(pos - 1)
            .map(|&b| b.is_ascii_alphanumeric() || b == b'_')
            .unwrap_or(false)
}

/// L1: no panic paths in library code.
pub fn l1_no_panic(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    if is_binary(f) {
        return;
    }
    for token in L1_TOKENS {
        for pos in find_all(&f.code, token) {
            if token.ends_with('!') && ident_before(&f.code, pos) {
                continue;
            }
            if f.in_test_region(pos) {
                continue;
            }
            let line = f.line_of(pos);
            if f.allowed(line, "L1") {
                continue;
            }
            push(
                diags,
                f,
                line,
                "L1",
                format!(
                    "`{token}` on a library path; propagate an Error or annotate allow(L1, reason)"
                ),
            );
        }
    }
    if STRICT_INDEX_MODULES.iter().any(|m| f.rel.starts_with(m)) {
        l1_indexing(f, diags);
    }
}

/// `expr[...]` indexing in strict modules.  An opening `[` counts when
/// the previous non-space token ends in an identifier char, `)` or `]`
/// — i.e. it indexes a value — and that token is not a keyword that
/// introduces an array literal (`for x in [...]`).
fn l1_indexing(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    let bytes = f.code.as_bytes();
    for pos in find_all(&f.code, "[") {
        let mut end = pos;
        while end > 0 && bytes.get(end - 1) == Some(&b' ') {
            end -= 1;
        }
        let prev = if end > 0 {
            *bytes.get(end - 1).unwrap_or(&b' ')
        } else {
            b' '
        };
        if !(prev.is_ascii_alphanumeric() || prev == b'_' || prev == b')' || prev == b']') {
            continue;
        }
        let mut start = end;
        while start > 0
            && bytes
                .get(start - 1)
                .map(|&b| b.is_ascii_alphanumeric() || b == b'_')
                .unwrap_or(false)
        {
            start -= 1;
        }
        let word = f.code.get(start..end).unwrap_or("");
        if PRE_BRACKET_KEYWORDS.contains(&word) {
            continue;
        }
        // `&'a [u8]` / `&'static [T]`: the "identifier" is a lifetime,
        // and the bracket opens a slice type, not an index expression.
        if start > 0 && bytes.get(start - 1) == Some(&b'\'') {
            continue;
        }
        if f.in_test_region(pos) {
            continue;
        }
        let line = f.line_of(pos);
        if f.allowed(line, "L1") {
            continue;
        }
        push(
            diags,
            f,
            line,
            "L1",
            "`[..]` indexing in a strict module; use .get()/iterators or annotate allow(L1, reason)"
                .to_string(),
        );
    }
}

// ---------------------------------------------------------------------
// L2 lock ordering
// ---------------------------------------------------------------------

struct LockSite {
    /// Byte offset of the acquisition token (absolute, into `f.code`).
    pos: usize,
    tier: u8,
    desc: &'static str,
    /// Receiver field the lock was classified by.
    field: String,
}

/// Find tracked lock acquisitions in a function body: `X.lock()`,
/// `X.read()`, `X.write()`, and `lock_recover(&X)` where `X` ends in a
/// field named in [`lock_order::TIERS`].
fn lock_sites(body: &str, base: usize) -> Vec<LockSite> {
    let mut sites = Vec::new();
    for token in [".lock()", ".read()", ".write()"] {
        for pos in find_all(body, token) {
            let field = last_ident_ending_at(body, pos);
            if let Some((tier, desc)) = lock_order::tier_of(&field) {
                sites.push(LockSite {
                    pos: base + pos,
                    tier,
                    desc,
                    field,
                });
            }
        }
    }
    for pos in find_all(body, "lock_recover(") {
        if ident_before(body, pos) {
            continue;
        }
        let open = pos + "lock_recover(".len();
        let arg: String = body
            .get(open..)
            .unwrap_or("")
            .chars()
            .take_while(|&c| c != ')')
            .collect();
        let field = trailing_ident(&arg);
        if let Some((tier, desc)) = lock_order::tier_of(&field) {
            sites.push(LockSite {
                pos: base + pos,
                tier,
                desc,
                field,
            });
        }
    }
    sites.sort_by_key(|s| s.pos);
    sites
}

/// The identifier whose last byte is at `pos - 1` (empty if the byte
/// before `pos` is not an identifier char).
fn last_ident_ending_at(text: &str, pos: usize) -> String {
    let bytes = text.as_bytes();
    let mut start = pos;
    while start > 0
        && bytes
            .get(start - 1)
            .map(|&b| b.is_ascii_alphanumeric() || b == b'_')
            .unwrap_or(false)
    {
        start -= 1;
    }
    text.get(start..pos).unwrap_or("").to_string()
}

/// Trailing identifier of an expression like `&self.inner`.
fn trailing_ident(expr: &str) -> String {
    let rev: String = expr
        .trim_end()
        .chars()
        .rev()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    rev.chars().rev().collect()
}

struct LiveGuard {
    /// Binding name, if the guard was kept in a `let`.
    name: Option<String>,
    tier: u8,
    field: String,
    /// Brace depth the guard was bound at; it dies when the walk
    /// returns to a shallower depth.
    depth: usize,
}

/// L2: out-of-order acquisition, and callbacks run under tracked
/// guards.  Walks each `fn` body line by line, tracking named guards
/// (`let g = ...lock...;`) until `drop(g)` or their block closes;
/// guards consumed within one statement are live only on their line.
pub fn l2_lock_order(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    for fn_pos in find_all(&f.code, "fn ") {
        if ident_before(&f.code, fn_pos) {
            continue;
        }
        let open = match f.code.get(fn_pos..).and_then(|s| {
            // a `;` before the `{` means a bodyless trait method
            match (s.find(';'), s.find('{')) {
                (Some(a), Some(b)) if a < b => None,
                (_, Some(b)) => Some(fn_pos + b),
                _ => None,
            }
        }) {
            Some(o) => o,
            None => continue,
        };
        let close = match matching_brace(&f.code, open) {
            Some(c) => c,
            None => continue,
        };
        if let Some(body) = f.code.get(open..=close) {
            l2_check_body(f, body, open, diags);
        }
    }
}

fn l2_check_body(f: &SourceFile, body: &str, base: usize, diags: &mut Vec<Diagnostic>) {
    let sites = lock_sites(body, base);
    let has_callback = lock_order::CALLBACK_SITES.iter().any(|c| body.contains(c));
    if sites.is_empty() && !has_callback {
        return;
    }
    let mut live: Vec<LiveGuard> = Vec::new();
    let mut depth = 0usize;
    let mut offset = 0usize;
    for raw_line in body.split_inclusive('\n') {
        let line_start = base + offset;
        let line_end = line_start + raw_line.len();
        let line_no = f.line_of(line_start);
        let trimmed = raw_line.trim();

        // leading `}`s close blocks before anything else on the line
        let leading_closes = trimmed.bytes().take_while(|&b| b == b'}').count();
        let depth_at_entry = depth.saturating_sub(leading_closes);
        live.retain(|g| g.depth <= depth_at_entry);

        // explicit drop(name)
        live.retain(|g| match &g.name {
            Some(name) => !trimmed.contains(&format!("drop({name})")),
            None => true,
        });

        // callback sites under any live tracked guard
        for cb in lock_order::CALLBACK_SITES {
            for cb_rel in find_all(raw_line, cb) {
                let abs = line_start + cb_rel;
                let is_def = raw_line
                    .get(..cb_rel)
                    .map(|pre| pre.trim_end().ends_with("fn"))
                    .unwrap_or(false);
                if is_def || ident_before(raw_line, cb_rel) || f.in_test_region(abs) {
                    continue;
                }
                if let Some(g) = live.first() {
                    if !f.allowed(line_no, "L2") {
                        push(
                            diags,
                            f,
                            line_no,
                            "L2",
                            format!(
                                "callback site `{}` reached while holding `{}` ({}); \
                                 drop the guard before running caller-supplied code",
                                cb.trim_end_matches('('),
                                g.field,
                                g.desc
                            ),
                        );
                    }
                }
            }
        }

        // acquisitions on this line, in order
        for site in sites
            .iter()
            .filter(|s| s.pos >= line_start && s.pos < line_end)
        {
            if f.in_test_region(site.pos) {
                continue;
            }
            if let Some(held) = live.iter().find(|g| g.tier > site.tier) {
                if !f.allowed(line_no, "L2") {
                    push(
                        diags,
                        f,
                        line_no,
                        "L2",
                        format!(
                            "acquiring `{}` (tier {}, {}) while holding `{}` (tier {}, {}); \
                             lock order is top-down — see lint/lock_order.rs",
                            site.field, site.tier, site.desc, held.field, held.tier, held.desc
                        ),
                    );
                }
            }
            live.push(LiveGuard {
                name: binds_guard(trimmed),
                tier: site.tier,
                field: site.field.clone(),
                depth,
            });
        }

        // guards not kept in a `let` die with their statement/line
        live.retain(|g| g.name.is_some());

        for b in raw_line.bytes() {
            match b {
                b'{' => depth += 1,
                b'}' => depth = depth.saturating_sub(1),
                _ => {}
            }
        }
        offset += raw_line.len();
    }
}

/// Does this statement keep the guard?  `let g = x.lock().unwrap();`
/// binds it; `let v = x.lock().unwrap().take();` consumes it within
/// the statement (guard is a temporary).
fn binds_guard(line: &str) -> Option<String> {
    let rest = line.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        return None;
    }
    // end of the acquisition expression
    let end = if let Some(p) = line.find("lock_recover(") {
        let inner = line.get(p..)?;
        p + inner.find(')')? + 1
    } else {
        [".lock()", ".read()", ".write()"]
            .iter()
            .filter_map(|t| line.rfind(t).map(|q| q + t.len()))
            .max()?
    };
    let mut tail = line.get(end..).unwrap_or("");
    for suffix in [
        ".unwrap()",
        ".expect(",
        ".unwrap_or_else(|poisoned| poisoned.into_inner())",
    ] {
        if let Some(t) = tail.strip_prefix(suffix) {
            // for `.expect("...")`, also skip past the closing paren
            tail = if suffix.ends_with('(') {
                let close = t.find(')').map(|c| c + 1).unwrap_or(t.len());
                t.get(close..).unwrap_or("")
            } else {
                t
            };
        }
    }
    if tail.trim_end() == ";" {
        Some(name)
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// L3 determinism
// ---------------------------------------------------------------------

/// L3: float accumulation inside `HashMap`/`HashSet` iteration, and
/// unordered parallel reductions.
pub fn l3_determinism(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    for pos in find_all(&f.code, "par_iter(") {
        if ident_before(&f.code, pos) || f.in_test_region(pos) {
            continue;
        }
        let line = f.line_of(pos);
        if !f.allowed(line, "L3") {
            push(
                diags,
                f,
                line,
                "L3",
                "unordered parallel iteration; reductions over it break bitwise determinism"
                    .to_string(),
            );
        }
    }

    // names bound to HashMap/HashSet in this file (locals and params)
    let mut tracked: HashSet<String> = HashSet::new();
    for ty in [
        "HashMap<",
        "HashSet<",
        "HashMap::new",
        "HashSet::new",
        "HashMap::with_capacity",
        "HashSet::with_capacity",
    ] {
        for pos in find_all(&f.code, ty) {
            if let Some(name) = binding_name_before(&f.code, pos) {
                tracked.insert(name);
            }
        }
    }
    if tracked.is_empty() {
        return;
    }

    // `for PAT in <tracked> { body }` loops with `+=` accumulation
    for for_pos in find_all(&f.code, "for ") {
        if ident_before(&f.code, for_pos) || f.in_test_region(for_pos) {
            continue;
        }
        let header_end = match f.code.get(for_pos..).and_then(|s| s.find('{')) {
            Some(rel) => for_pos + rel,
            None => continue,
        };
        let header = f.code.get(for_pos..header_end).unwrap_or("");
        let iterated = match header.split(" in ").nth(1) {
            Some(expr) => expr.trim_start().trim_start_matches('&'),
            None => continue,
        };
        let head_ident: String = iterated
            .trim_start_matches("mut ")
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !tracked.contains(&head_ident) {
            continue;
        }
        let close = match matching_brace(&f.code, header_end) {
            Some(c) => c,
            None => continue,
        };
        let body = f.code.get(header_end..=close).unwrap_or("");
        for acc_pos in find_all(body, "+=") {
            let line = f.line_of(header_end + acc_pos);
            let stmt = f.code_line(line);
            // integer-literal increments (`+= 1;`) are order-independent
            let rhs = stmt.split("+=").nth(1).unwrap_or("").trim();
            let bare = rhs.trim_end_matches(';').trim_end();
            if !bare.is_empty() && bare.chars().all(|c| c.is_ascii_digit()) {
                continue;
            }
            if !f.allowed(line, "L3") {
                push(
                    diags,
                    f,
                    line,
                    "L3",
                    format!(
                        "accumulation over unordered iteration of `{head_ident}` \
                         (HashMap/HashSet order is nondeterministic); sort keys first"
                    ),
                );
            }
        }
    }

    // reduction chains rooted at a tracked collection
    for name in &tracked {
        for method in [".values()", ".iter()", ".keys()"] {
            let chain = format!("{name}{method}");
            for pos in find_all(&f.code, &chain) {
                if ident_before(&f.code, pos) || f.in_test_region(pos) {
                    continue;
                }
                let line = f.line_of(pos);
                let stmt = f.code_line(line);
                if (stmt.contains(".sum(") || stmt.contains(".fold(")) && !f.allowed(line, "L3") {
                    push(
                        diags,
                        f,
                        line,
                        "L3",
                        format!(
                            "reduction chained on unordered `{name}{method}`; \
                             collect-and-sort before reducing"
                        ),
                    );
                }
            }
        }
    }
}

/// For a `HashMap<`/`HashSet<` type token at `pos`, recover the bound
/// name from the same line: `let NAME[: ..] =` or a `NAME: &Type`
/// parameter/field.
fn binding_name_before(code: &str, pos: usize) -> Option<String> {
    let line_start = code.get(..pos)?.rfind('\n').map(|p| p + 1).unwrap_or(0);
    let prefix = code.get(line_start..pos)?;
    if let Some(let_pos) = prefix.rfind("let ") {
        let after = prefix.get(let_pos + 4..)?;
        let after = after.strip_prefix("mut ").unwrap_or(after);
        let name: String = after
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            return Some(name);
        }
    }
    let colon = prefix.rfind(':')?;
    let name = trailing_ident(prefix.get(..colon)?);
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

// ---------------------------------------------------------------------
// L4 metrics hygiene
// ---------------------------------------------------------------------

/// The files that declare the observable-name vocabulary.  Metric
/// names and trace span names share one grammar and one registry, so a
/// name declared in BOTH files is a cross-file duplicate and flagged.
pub const NAME_REGISTRY_FILES: &[&str] = &["metrics/names.rs", "trace/names.rs"];

/// Collect metric and trace-span names declared in the registry files
/// ([`NAME_REGISTRY_FILES`]), flagging duplicate declarations — within
/// one file or across the two.
pub fn l4_collect_registered(
    files: &[SourceFile],
    diags: &mut Vec<Diagnostic>,
) -> HashSet<String> {
    let mut registered: HashMap<String, (String, usize)> = HashMap::new();
    for f in files
        .iter()
        .filter(|f| NAME_REGISTRY_FILES.contains(&f.rel.as_str()))
    {
        for pos in find_all(&f.code, ": &str =") {
            if f.in_test_region(pos) {
                continue;
            }
            let Some(lit) = literal_after(f, pos) else {
                continue;
            };
            let line = f.line_of(pos);
            if let Some((first_file, first)) = registered.get(&lit) {
                push(
                    diags,
                    f,
                    line,
                    "L4",
                    format!("name \"{lit}\" declared twice (first at {first_file}:{first})"),
                );
            } else {
                registered.insert(lit, (f.rel.clone(), line));
            }
        }
    }
    registered.into_keys().collect()
}

/// The first `"..."` literal at or after `pos`, with content read from
/// the RAW text (the stripped view blanks literal contents but keeps
/// the quotes in place).
fn literal_after(f: &SourceFile, pos: usize) -> Option<String> {
    let open = pos + f.code.get(pos..)?.find('"')?;
    let close = open + 1 + f.code.get(open + 1..)?.find('"')?;
    f.raw.get(open + 1..close).map(|s| s.to_string())
}

/// Does `name` look like a metric name (`namespace.counter[.sub]`)?
/// Filters unrelated `.get("key")` lookups (CLI args, config maps).
fn metric_shaped(name: &str) -> bool {
    name.contains('.')
        && name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '.' || c == '_')
}

/// L4: string literals passed to `Registry::incr`/`get`/`incr_labeled`
/// or to the trace probes (`trace::span`/`span_arg`/`event`/`event_job`)
/// must be declared in a registry file (`metrics/names.rs` or
/// `trace/names.rs`); `format!`-built names must go through
/// `incr_labeled` with a declared base.
pub fn l4_metric_names(f: &SourceFile, registered: &HashSet<String>, diags: &mut Vec<Diagnostic>) {
    if NAME_REGISTRY_FILES.contains(&f.rel.as_str()) {
        return;
    }
    for method in [
        ".incr(",
        ".get(",
        ".incr_labeled(",
        "trace::span(",
        "trace::span_arg(",
        "trace::event(",
        "trace::event_job(",
    ] {
        for pos in find_all(&f.code, method) {
            if f.in_test_region(pos) {
                continue;
            }
            let line = f.line_of(pos);
            let arg_start = pos + method.len();
            let arg = f.code.get(arg_start..).unwrap_or("").trim_start();
            if arg.starts_with("&format!") || arg.starts_with("format!") {
                if !f.allowed(line, "L4") {
                    push(
                        diags,
                        f,
                        line,
                        "L4",
                        "metric name built with format!; use incr_labeled with a declared base"
                            .to_string(),
                    );
                }
                continue;
            }
            if !arg.starts_with('"') {
                continue; // a names:: const or variable, declared by construction
            }
            let Some(lit) = literal_after(f, arg_start) else {
                continue;
            };
            // `.get("...")` is ubiquitous (HashMap, CLI args): only
            // metric-shaped literals are checked there.  `.incr(` and
            // `.incr_labeled(` are Registry-specific: always checked.
            if !metric_shaped(&lit) {
                if method != ".get(" && !f.allowed(line, "L4") {
                    push(
                        diags,
                        f,
                        line,
                        "L4",
                        format!("metric name \"{lit}\" is not namespace.counter shaped"),
                    );
                }
                continue;
            }
            if !registered.contains(&lit) && !f.allowed(line, "L4") {
                push(
                    diags,
                    f,
                    line,
                    "L4",
                    format!(
                        "name \"{lit}\" is not declared in metrics/names.rs or trace/names.rs"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// L5 no-alloc-on-warm-path
// ---------------------------------------------------------------------

/// L5: bodies annotated `// rsla-lint: no_alloc` must not allocate.
/// The annotation binds to the next `fn`/`for`/`while`/`loop` at or
/// after its line; the brace-matched body is the checked region.
pub fn l5_no_alloc(f: &SourceFile, diags: &mut Vec<Diagnostic>) {
    let mut ann_lines: Vec<usize> = f
        .annotations
        .iter()
        .filter(|(_, anns)| anns.iter().any(|a| *a == Annotation::NoAlloc))
        .map(|(line, _)| *line)
        .collect();
    ann_lines.sort_unstable();
    for ann_line in ann_lines {
        let Some((start, end)) = no_alloc_region(f, ann_line) else {
            push(
                diags,
                f,
                ann_line,
                "ANN",
                "no_alloc annotation is not followed by a fn or loop body".to_string(),
            );
            continue;
        };
        let body = f.code.get(start..=end).unwrap_or("");
        for token in L5_TOKENS {
            for pos in find_all(body, token) {
                let abs = start + pos;
                if f.in_test_region(abs) {
                    continue;
                }
                let line = f.line_of(abs);
                if f.allowed(line, "L5") {
                    continue;
                }
                push(
                    diags,
                    f,
                    line,
                    "L5",
                    format!("`{token}` inside a no_alloc body (annotated at line {ann_line})"),
                );
            }
        }
    }
}

/// The brace-matched body following a `no_alloc` annotation — the same
/// binding rule as `allow_item` ([`SourceFile::item_region`]).
fn no_alloc_region(f: &SourceFile, ann_line: usize) -> Option<(usize, usize)> {
    f.item_region(ann_line)
}
