//! The mixed-family demo workload, defined ONCE and shared by
//! `rsla serve-sim --mixed` (the CI smoke job) and the `serve_mixed`
//! bench, so both drive the SAME kind mix and cannot drift: per
//! request index `i % 10` — 60% linear on a small set of recurring
//! Poisson patterns, then one multi-RHS, one nonlinear (damped Newton
//! on [`QuadPoisson`]), one eigen (LOBPCG), and alternating adjoint /
//! distributed.

use crate::backend::SolveOpts;
use crate::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use crate::eigen::LobpcgOpts;
use crate::nonlinear::{examples::QuadPoisson, NewtonOpts};
use crate::sparse::poisson::{poisson2d, PoissonSystem};
use crate::util::Prng;

use super::JobSpec;

/// Deterministic open-loop request generator over recurring sparsity
/// patterns.  Family opts are public knobs so the CLI demo (small
/// grids, RCB partitions) and the bench (large grids, bounded eig /
/// Newton budgets) tune the same generator instead of re-implementing
/// the mix.
pub struct MixedWorkload {
    patterns: Vec<PoissonSystem>,
    rng: Prng,
    pub newton: NewtonOpts,
    pub eig: LobpcgOpts,
    pub dist: DistIterOpts,
    pub dist_strategy: PartitionStrategy,
    /// Hand the partitioner grid coordinates (RCB needs them).
    pub dist_use_coords: bool,
    pub dist_ranks: usize,
    /// Right-hand sides per multi-RHS job.
    pub multi_rhs: usize,
}

impl MixedWorkload {
    pub fn new(grids: &[usize], seed: u64) -> Self {
        // an empty grid list would make every `i % len` below panic;
        // clamp to the default demo grid instead
        let grids: &[usize] = if grids.is_empty() { &[6] } else { grids };
        MixedWorkload {
            patterns: grids.iter().map(|&g| poisson2d(g, None)).collect(),
            rng: Prng::new(seed),
            newton: NewtonOpts::default(),
            eig: LobpcgOpts::default(),
            dist: DistIterOpts::default(),
            dist_strategy: PartitionStrategy::Contiguous,
            dist_use_coords: false,
            dist_ranks: 2,
            multi_rhs: 3,
        }
    }

    /// The `i`-th request of the stream.
    pub fn spec(&mut self, i: usize) -> JobSpec {
        let idx = i % self.patterns.len();
        let matrix = self.patterns[idx].matrix.clone(); // rsla-lint: allow(L1, idx = i % len and patterns is non-empty by construction)
        let n = matrix.nrows;
        match i % 10 {
            0..=5 => JobSpec::Linear {
                b: self.rng.normal_vec(n),
                matrix,
                opts: SolveOpts::default(),
            },
            6 => JobSpec::MultiRhs {
                bs: (0..self.multi_rhs).map(|_| self.rng.normal_vec(n)).collect(),
                matrix,
                opts: SolveOpts::default(),
            },
            7 => JobSpec::Nonlinear {
                residual: Box::new(QuadPoisson {
                    a: matrix,
                    f: (0..n).map(|_| 0.5 + self.rng.uniform()).collect(),
                }),
                u0: vec![0.0; n],
                opts: self.newton.clone(),
            },
            8 => JobSpec::Eig {
                matrix,
                k: 2,
                opts: self.eig.clone(),
            },
            _ => {
                if i % 20 == 9 {
                    JobSpec::Adjoint {
                        b: self.rng.normal_vec(n),
                        gy: self.rng.normal_vec(n),
                        matrix,
                        opts: SolveOpts::default(),
                    }
                } else {
                    let tensor = {
                        let sys = &self.patterns[idx]; // rsla-lint: allow(L1, idx = i % len and patterns is non-empty by construction)
                        let coords = if self.dist_use_coords {
                            Some(sys.coords.as_slice())
                        } else {
                            None
                        };
                        DSparseTensor::from_global(
                            &sys.matrix,
                            coords,
                            self.dist_ranks,
                            self.dist_strategy,
                        )
                        .expect("partition demo system") // rsla-lint: allow(L1, bundled Poisson demo systems always partition)
                    };
                    JobSpec::Dist {
                        tensor,
                        b: self.rng.normal_vec(n),
                        opts: self.dist.clone(),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::JobKind;

    #[test]
    fn stream_covers_every_job_kind() {
        let mut w = MixedWorkload::new(&[6, 8], 1);
        let mut seen = [false; 6];
        for i in 0..20 {
            seen[w.spec(i).kind().idx()] = true;
        }
        assert!(seen.iter().all(|&s| s), "20 requests must cover all kinds");
    }
}
