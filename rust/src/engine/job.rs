//! The typed `Job` surface of the solve engine: what can be submitted
//! ([`JobSpec`]), how it is classified for scheduling and metrics
//! ([`JobKind`]), and what comes back ([`JobResult`] through a
//! [`Ticket`]).

use std::sync::mpsc::Receiver;
use std::time::Duration;

use crate::backend::{SolveOpts, SolveOutcome};
use crate::distributed::{DSparseTensor, DistIterOpts, DistSolveReport};
use crate::eigen::{EigResult, LobpcgOpts};
use crate::error::{Error, Result};
use crate::nonlinear::{NewtonOpts, NonlinearResult, Residual};
use crate::sparse::Csr;

/// Solver family of a job — the scheduling/metrics label.  Every kind
/// executes through the one `Engine::submit` path.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum JobKind {
    Linear,
    MultiRhs,
    Nonlinear,
    Eig,
    Adjoint,
    Dist,
}

impl JobKind {
    pub const ALL: [JobKind; 6] = [
        JobKind::Linear,
        JobKind::MultiRhs,
        JobKind::Nonlinear,
        JobKind::Eig,
        JobKind::Adjoint,
        JobKind::Dist,
    ];

    pub fn idx(self) -> usize {
        match self {
            JobKind::Linear => 0,
            JobKind::MultiRhs => 1,
            JobKind::Nonlinear => 2,
            JobKind::Eig => 3,
            JobKind::Adjoint => 4,
            JobKind::Dist => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            JobKind::Linear => "linear",
            JobKind::MultiRhs => "multi_rhs",
            JobKind::Nonlinear => "nonlinear",
            JobKind::Eig => "eig",
            JobKind::Adjoint => "adjoint",
            JobKind::Dist => "dist",
        }
    }
}

/// One unit of work.  Owns everything it needs (matrices, right-hand
/// sides, residual objects, distributed tensors) so it can cross the
/// scheduler thread boundary.
pub enum JobSpec {
    /// A x = b.
    Linear {
        matrix: Csr,
        b: Vec<f64>,
        opts: SolveOpts,
    },
    /// One matrix, many right-hand sides: factorize once, sweep all.
    MultiRhs {
        matrix: Csr,
        bs: Vec<Vec<f64>>,
        opts: SolveOpts,
    },
    /// F(u) = 0 by damped Newton; each step's linear solve runs through
    /// the serving worker's factor-cache shard.
    Nonlinear {
        residual: Box<dyn Residual + Send>,
        u0: Vec<f64>,
        opts: NewtonOpts,
    },
    /// k smallest eigenpairs of a symmetric matrix (LOBPCG).
    Eig {
        matrix: Csr,
        k: usize,
        opts: LobpcgOpts,
    },
    /// Forward + adjoint pair: x = A^{-1} b and lambda = A^{-T} gy from
    /// ONE factorization (paper Eq. 3).
    Adjoint {
        matrix: Csr,
        b: Vec<f64>,
        gy: Vec<f64>,
        opts: SolveOpts,
    },
    /// Distributed solve: the worker launches and manages the rank team
    /// for the tensor's partition.
    Dist {
        tensor: DSparseTensor,
        b: Vec<f64>,
        opts: DistIterOpts,
    },
}

impl JobSpec {
    pub fn kind(&self) -> JobKind {
        match self {
            JobSpec::Linear { .. } => JobKind::Linear,
            JobSpec::MultiRhs { .. } => JobKind::MultiRhs,
            JobSpec::Nonlinear { .. } => JobKind::Nonlinear,
            JobSpec::Eig { .. } => JobKind::Eig,
            JobSpec::Adjoint { .. } => JobKind::Adjoint,
            JobSpec::Dist { .. } => JobKind::Dist,
        }
    }

    /// The matrix whose sparsity pattern drives affinity routing, when
    /// the job has one (nonlinear and distributed jobs route by load).
    pub fn affinity_matrix(&self) -> Option<&Csr> {
        match self {
            JobSpec::Linear { matrix, .. }
            | JobSpec::MultiRhs { matrix, .. }
            | JobSpec::Eig { matrix, .. }
            | JobSpec::Adjoint { matrix, .. } => Some(matrix),
            JobSpec::Nonlinear { .. } | JobSpec::Dist { .. } => None,
        }
    }

    /// The `(matrix, b, opts)` view of a linear job; `None` for every
    /// other family.  The fuse/batch paths use this instead of matching
    /// `JobSpec::Linear` inline so a non-linear spec reaching them is a
    /// graceful fallback, never a panic.
    pub fn linear_parts(&self) -> Option<(&Csr, &[f64], &SolveOpts)> {
        match self {
            JobSpec::Linear { matrix, b, opts } => Some((matrix, b.as_slice(), opts)),
            _ => None,
        }
    }

    /// Take a linear job apart; any other family is handed back intact
    /// so the caller can serve it through the generic path.
    pub fn into_linear(self) -> std::result::Result<(Csr, Vec<f64>, SolveOpts), Box<JobSpec>> {
        match self {
            JobSpec::Linear { matrix, b, opts } => Ok((matrix, b, opts)),
            other => Err(Box::new(other)),
        }
    }
}

/// Scheduling priority; within a priority class jobs run
/// earliest-deadline-first, then FIFO.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    Normal,
    High,
}

/// Per-submission options.
#[derive(Clone, Debug)]
pub struct SubmitOpts {
    pub priority: Priority,
    /// Budget from submission to execution START; a job still queued
    /// when it expires is failed with [`Error::Timeout`] instead of
    /// run.
    pub deadline: Option<Duration>,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        SubmitOpts {
            priority: Priority::Normal,
            deadline: None,
        }
    }
}

/// Family-specific payload of a completed job.
pub enum JobOutput {
    Linear(SolveOutcome),
    MultiRhs(Vec<SolveOutcome>),
    Nonlinear(NonlinearResult),
    Eig(EigResult),
    Adjoint {
        x: Vec<f64>,
        /// Solution of A^T lambda = gy ( = dL/db for the linear adjoint).
        lambda: Vec<f64>,
    },
    Dist {
        x: Vec<f64>,
        reports: Vec<DistSolveReport>,
    },
}

/// Convergence telemetry of a completed solve, surfaced end-to-end on
/// [`JobResult`] so clients (and trace spans) can see WHY a solve was
/// slow without re-running it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Convergence {
    /// Iterations the solve consumed (0 for purely direct solves).
    pub iters: usize,
    /// Final residual norm; the WORST across a batch or rank team.
    pub residual: f64,
    pub converged: bool,
}

impl Convergence {
    /// Derive the telemetry from a finished outcome.  `None` for
    /// families that carry no iteration data (adjoint pairs) and for
    /// failed jobs (the error already says why).
    pub fn of(outcome: &Result<JobOutput>) -> Option<Convergence> {
        let out = match outcome {
            Ok(o) => o,
            Err(_) => return None,
        };
        match out {
            // a successful linear/multi-RHS/eig outcome converged by
            // construction: non-convergence surfaces as Err upstream
            JobOutput::Linear(s) => Some(Convergence {
                iters: s.iters,
                residual: s.residual,
                converged: true,
            }),
            JobOutput::MultiRhs(outs) => Some(Convergence {
                iters: outs.iter().map(|s| s.iters).max().unwrap_or(0),
                residual: outs.iter().map(|s| s.residual).fold(0.0, f64::max),
                converged: true,
            }),
            JobOutput::Nonlinear(r) => Some(Convergence {
                iters: r.iters,
                residual: r.residual_norm,
                converged: r.converged,
            }),
            JobOutput::Eig(r) => Some(Convergence {
                iters: r.iters,
                residual: r.residuals.iter().copied().fold(0.0, f64::max),
                converged: true,
            }),
            JobOutput::Adjoint { .. } => None,
            JobOutput::Dist { reports, .. } => Some(Convergence {
                iters: reports.iter().map(|r| r.iters).max().unwrap_or(0),
                residual: reports.iter().map(|r| r.residual).fold(0.0, f64::max),
                converged: reports.iter().all(|r| r.converged),
            }),
        }
    }
}

/// The reply for one job, with queueing/service latency for the
/// metrics tables.
pub struct JobResult {
    pub id: u64,
    pub kind: JobKind,
    pub outcome: Result<JobOutput>,
    pub queue_seconds: f64,
    pub service_seconds: f64,
    /// How many requests shared the fused batch that served this one
    /// (1 for unfused jobs).
    pub batch_size: usize,
    /// Index of the worker that executed the job (usize::MAX when it
    /// never reached one, e.g. a queued-deadline timeout).
    pub worker: usize,
    /// Iteration/residual telemetry of the solve, when the family has
    /// any (see [`Convergence::of`]).
    pub convergence: Option<Convergence>,
}

/// Handle to an in-flight job.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    pub kind: JobKind,
    pub(crate) rx: Receiver<JobResult>,
}

impl Ticket {
    /// Block until the result arrives.  A worker that died without
    /// replying (process teardown) surfaces as a typed error, never a
    /// hang-forever on a dropped channel.
    pub fn wait(self) -> JobResult {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => JobResult {
                id: self.id,
                kind: self.kind,
                outcome: Err(Error::WorkerPanic(
                    "engine dropped the reply channel".into(),
                )),
                queue_seconds: 0.0,
                service_seconds: 0.0,
                batch_size: 1,
                worker: usize::MAX,
                convergence: None,
            },
        }
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<JobResult> {
        self.rx.try_recv().ok()
    }
}
