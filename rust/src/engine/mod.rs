//! The solve engine: ONE submission path for every solver family.
//!
//! The paper frames torch-sla's batched/auto-dispatch semantics as a
//! serving problem (§3.1): requests grouped by sparsity pattern
//! amortize one symbolic factorization.  The old coordinator served
//! only *linear* solves; this engine serves every family — linear,
//! multi-RHS, nonlinear (damped Newton), eigen (LOBPCG), adjoint
//! (forward + transpose from one factorization), and distributed
//! (engine-managed rank teams) — through one typed [`JobSpec`] and one
//! [`Engine::submit`] → [`Ticket`] → [`JobResult`] lifecycle.
//!
//! Scheduling:
//!
//! * **Windowed intake** — the scheduler collects a short window
//!   ([`BatchPolicy::window`]) and orders it by (priority, earliest
//!   deadline, arrival).
//! * **Multi-RHS fusion** — linear jobs sharing a (pattern, values)
//!   [`PatternKey`](fuse::PatternKey) fuse into one factorize-once
//!   batch; the worker re-verifies full equality (`verify_groups`)
//!   before acting on hash-keyed groups, so fusion is bitwise-identical
//!   to per-request solves (pinned by `tests/engine_serve.rs`).
//! * **Pattern-affinity routing** — each worker owns a factor-cache
//!   shard ([`crate::factor_cache::CacheShards`]); jobs are routed to
//!   the worker whose shard already holds their pattern, so warm
//!   factors are reused instead of re-built per worker.  Jobs without
//!   a pattern (nonlinear, distributed) go to the least-loaded worker.
//! * **Admission control** — a bounded pending count rejects submits
//!   with [`Error::QueueFull`] (backpressure); queued jobs whose
//!   deadline lapses fail with [`Error::Timeout`] instead of running.
//! * **Failure isolation** — a panicking job (e.g. inside a user
//!   residual) is caught per-unit and surfaced as
//!   [`Error::WorkerPanic`]; the worker pool survives.
//!
//! Per-kind latency histograms (p50/p95/p99), queue depth, and affinity
//! hit counters are readable through [`Engine::stats`]; `rsla serve-sim
//! --mixed` prints the table.  `coordinator::SolveService` remains as a
//! thin compatibility shim over this engine.

pub mod fuse;
pub mod job;
pub mod workload;

pub use fuse::{group_by_key, verify_groups, BatchPolicy};
pub use job::{
    Convergence, JobKind, JobOutput, JobResult, JobSpec, Priority, SubmitOpts, Ticket,
};

use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::adjoint::Transpose;
use crate::backend::dispatch::DIRECT_CROSSOVER_N;
use crate::backend::native_direct::residual_of;
use crate::backend::{Device, Dispatcher, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::direct::CachedFactor;
use crate::error::{Error, Result};
use crate::factor_cache::{CacheShards, CacheStats, DEFAULT_BUDGET_BYTES};
use crate::metrics::{self, names, LatencyHist};
use crate::sparse::key::{PatternKey, StructureKey};
use crate::sparse::Csr;
use crate::trace::{self, names as tn};
use crate::util::lock_recover;

/// Engine construction knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads (>= 1); each owns one factor-cache shard.
    pub workers: usize,
    /// Intake window + multi-RHS fusion policy (`max_batch <= 1`
    /// disables fusion, jobs are still windowed for ordering).
    pub fuse: BatchPolicy,
    /// Pattern-affinity routing; `false` = round-robin assignment (the
    /// bench baseline).
    pub affinity: bool,
    /// Admission-control bound on jobs in flight (submitted, not yet
    /// replied).  `usize::MAX` = unbounded, the shim default.
    pub max_pending: usize,
    /// Byte budget of each worker's factor-cache shard.
    pub shard_budget_bytes: u64,
    /// Latency-histogram horizon: `None` keeps every sample forever
    /// (the bench/report default); `Some((window, n_windows))` rotates
    /// generational histograms so [`Engine::stats`] quantiles reflect
    /// the last `window * n_windows` jobs of each kind — long-running
    /// servers use this so a cold-start burst can't pin p99 forever.
    pub hist_window: Option<(u64, usize)>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            fuse: BatchPolicy::default(),
            affinity: true,
            max_pending: usize::MAX,
            shard_budget_bytes: DEFAULT_BUDGET_BYTES,
            hist_window: None,
        }
    }
}

/// An admitted job travelling through the scheduler.
struct Envelope {
    id: u64,
    spec: JobSpec,
    priority: Priority,
    deadline: Option<Instant>,
    enqueued: Instant,
    seq: u64,
    reply: Box<dyn FnOnce(JobResult) + Send>,
}

/// What the scheduler hands a worker.  Units carry the scheduler's
/// pattern fingerprint so workers never pay a second O(nnz)
/// `PatternKey::of` pass on the serve path (pinned by
/// `tests/hash_count.rs`).
enum Unit {
    /// A single job, with its fingerprint when the family has an
    /// affinity matrix (`None` for nonlinear/distributed jobs).
    One(Envelope, Option<PatternKey>),
    /// Linear jobs sharing a (pattern, values) key, to be factorized
    /// once (after the worker's full-equality re-check).
    Fused(Vec<Envelope>, PatternKey),
}

/// State shared by submitters, the scheduler, and the workers.
struct Shared {
    pending: AtomicUsize,
    depths: Vec<AtomicUsize>,
    hists: Vec<LatencyHist>,
    registry: Arc<metrics::Registry>,
}

fn respond(shared: &Shared, reply: Box<dyn FnOnce(JobResult) + Send>, mut result: JobResult) {
    if result.convergence.is_none() {
        result.convergence = Convergence::of(&result.outcome);
    }
    trace::event_job(
        tn::JOB_REPLY,
        result.id,
        result.kind.name(),
        result.batch_size as u64,
    );
    if let Some(hist) = shared.hists.get(result.kind.idx()) {
        hist.record(result.queue_seconds + result.service_seconds);
    }
    shared.registry.incr(names::SERVICE_COMPLETED, 1);
    shared
        .registry
        .incr_labeled(names::ENGINE_COMPLETED, result.kind.name(), 1);
    shared.pending.fetch_sub(1, Ordering::Relaxed);
    // Reply closures are caller-supplied code running on an engine
    // thread: a panicking callback must not take the worker (and every
    // pattern affinity-pinned to it) down with its own job.
    if std::panic::catch_unwind(AssertUnwindSafe(move || reply(result))).is_err() {
        shared.registry.incr(names::ENGINE_REPLY_PANIC, 1);
    }
}

fn respond_timeout(env: Envelope, now: Instant, shared: &Shared) {
    let Envelope {
        id,
        spec,
        deadline,
        enqueued,
        reply,
        ..
    } = env;
    let kind = spec.kind();
    let waited = now.saturating_duration_since(enqueued);
    let allowed = deadline
        .map(|d| d.saturating_duration_since(enqueued))
        .unwrap_or_default();
    shared.registry.incr(names::ENGINE_TIMEOUT, 1);
    respond(
        shared,
        reply,
        JobResult {
            id,
            kind,
            outcome: Err(Error::Timeout {
                waited_ms: waited.as_millis() as u64,
                deadline_ms: allowed.as_millis() as u64,
            }),
            queue_seconds: waited.as_secs_f64(),
            service_seconds: 0.0,
            batch_size: 1,
            worker: usize::MAX,
            convergence: None,
        },
    );
}

fn expired(deadline: Option<Instant>, now: Instant) -> bool {
    deadline.map(|d| now >= d).unwrap_or(false)
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// Per-kind latency snapshot (seconds).
#[derive(Clone, Debug)]
pub struct KindStats {
    pub kind: JobKind,
    pub count: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Aggregate engine snapshot for reports and benches.
#[derive(Clone, Debug)]
pub struct EngineStats {
    pub kinds: Vec<KindStats>,
    /// Jobs admitted and not yet replied (queued + executing).
    pub queue_depth: usize,
    pub affinity_hits: u64,
    pub affinity_misses: u64,
    pub timeouts: u64,
    pub rejected: u64,
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Aggregated over all worker shards.
    pub cache: CacheStats,
}

impl EngineStats {
    /// Factor-cache hit rate across shards in [0, 1].
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.cache.hits_numeric + self.cache.hits_symbolic;
        let total = hits + self.cache.misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// The solve engine: scheduler thread + worker pool, one factor-cache
/// shard per worker, every solver family behind [`Engine::submit`].
pub struct Engine {
    intake: Mutex<Option<Sender<Envelope>>>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    shared: Arc<Shared>,
    shards: Arc<CacheShards>,
    pub metrics: Arc<metrics::Registry>,
    next_id: AtomicU64,
    max_pending: usize,
}

impl Engine {
    pub fn start(dispatcher: Arc<Dispatcher>, config: EngineConfig) -> Self {
        let workers = config.workers.max(1);
        let registry = Arc::new(metrics::Registry::new());
        let shared = Arc::new(Shared {
            pending: AtomicUsize::new(0),
            depths: (0..workers).map(|_| AtomicUsize::new(0)).collect(),
            hists: JobKind::ALL
                .iter()
                .map(|_| match config.hist_window {
                    Some((w, n)) => LatencyHist::windowed(w, n),
                    None => LatencyHist::new(),
                })
                .collect(),
            registry: registry.clone(),
        });
        let shards = Arc::new(CacheShards::new(workers, config.shard_budget_bytes));

        let mut threads = Vec::new();
        let mut worker_txs: Vec<Sender<Unit>> = Vec::new();
        for w in 0..workers {
            let (tx, rx) = channel::<Unit>();
            worker_txs.push(tx);
            let ctx = WorkerCtx {
                idx: w,
                disp: dispatcher.clone(),
                shards: shards.clone(),
                shared: shared.clone(),
            };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rsla-engine-worker-{w}"))
                    .spawn(move || worker_loop(rx, ctx))
                    // rsla-lint: allow(L1, spawn fails only on OS thread exhaustion at engine construction)
                    .expect("spawn engine worker"),
            );
        }
        let (intake_tx, intake_rx) = channel::<Envelope>();
        {
            let fuse = config.fuse.clone();
            let affinity = config.affinity;
            let shared = shared.clone();
            threads.insert(
                0,
                std::thread::Builder::new()
                    .name("rsla-engine-sched".into())
                    .spawn(move || scheduler_loop(intake_rx, worker_txs, fuse, affinity, shared))
                    // rsla-lint: allow(L1, spawn fails only on OS thread exhaustion at engine construction)
                    .expect("spawn engine scheduler"),
            );
        }

        Engine {
            intake: Mutex::new(Some(intake_tx)),
            threads: Mutex::new(threads),
            shared,
            shards,
            metrics: registry,
            next_id: AtomicU64::new(1),
            max_pending: config.max_pending,
        }
    }

    /// The process-global engine (CPU dispatcher, default config) that
    /// `SparseTensor::via_engine` submits through.
    pub fn global() -> &'static Engine {
        static GLOBAL: OnceLock<Engine> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            Engine::start(Arc::new(Dispatcher::new(None)), EngineConfig::default())
        })
    }

    /// Submit with default priority and no deadline.
    pub fn submit(&self, spec: JobSpec) -> Result<Ticket> {
        self.submit_with(spec, SubmitOpts::default())
    }

    /// Submit with explicit priority/deadline; returns a [`Ticket`] to
    /// wait on, or [`Error::QueueFull`] when admission control rejects.
    pub fn submit_with(&self, spec: JobSpec, opts: SubmitOpts) -> Result<Ticket> {
        let kind = spec.kind();
        let (tx, rx) = channel::<JobResult>();
        let id = self.submit_with_reply(
            spec,
            opts,
            Box::new(move |r| {
                let _ = tx.send(r);
            }),
        )?;
        Ok(Ticket { id, kind, rx })
    }

    /// Callback-form submission (the coordinator shim converts replies
    /// into its own response type without a forwarding thread).
    pub fn submit_with_reply(
        &self,
        spec: JobSpec,
        opts: SubmitOpts,
        reply: Box<dyn FnOnce(JobResult) + Send>,
    ) -> Result<u64> {
        let depth = self.shared.pending.load(Ordering::Relaxed);
        if depth >= self.max_pending {
            self.metrics.incr(names::ENGINE_REJECTED, 1);
            return Err(Error::QueueFull {
                depth,
                capacity: self.max_pending,
            });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        trace::event_job(tn::JOB_SUBMIT, id, spec.kind().name(), 0);
        let now = Instant::now();
        let env = Envelope {
            id,
            spec,
            priority: opts.priority,
            deadline: opts.deadline.map(|d| now + d),
            enqueued: now,
            seq: id,
            reply,
        };
        let guard = lock_recover(&self.intake);
        match guard.as_ref() {
            Some(tx) => {
                self.shared.pending.fetch_add(1, Ordering::Relaxed);
                if tx.send(env).is_err() {
                    self.shared.pending.fetch_sub(1, Ordering::Relaxed);
                    return Err(Error::InvalidProblem("engine scheduler stopped".into()));
                }
                Ok(id)
            }
            None => Err(Error::InvalidProblem("engine stopped".into())),
        }
    }

    /// Snapshot of per-kind latency quantiles, queue depth, affinity
    /// counters, and aggregated shard cache stats.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            kinds: JobKind::ALL
                .iter()
                .filter_map(|&k| {
                    let h = self.shared.hists.get(k.idx())?;
                    Some(KindStats {
                        kind: k,
                        count: h.count(),
                        p50: h.quantile(0.50),
                        p95: h.quantile(0.95),
                        p99: h.quantile(0.99),
                    })
                })
                .collect(),
            queue_depth: self.shared.pending.load(Ordering::Relaxed),
            affinity_hits: self.metrics.get(names::ENGINE_AFFINITY_HIT),
            affinity_misses: self.metrics.get(names::ENGINE_AFFINITY_MISS),
            timeouts: self.metrics.get(names::ENGINE_TIMEOUT),
            rejected: self.metrics.get(names::ENGINE_REJECTED),
            completed: self.metrics.get(names::SERVICE_COMPLETED),
            batches: self.metrics.get(names::SERVICE_BATCHES),
            batched_requests: self.metrics.get(names::SERVICE_BATCHED_REQUESTS),
            cache: self.shards.stats(),
        }
    }

    /// The per-worker factor-cache shards (tests and benches read
    /// per-shard warmth through this).
    pub fn shards(&self) -> &CacheShards {
        &self.shards
    }

    /// Graceful shutdown: stop intake, drain queues, join threads.
    /// Idempotent; in-flight jobs are served before workers exit.
    pub fn shutdown(&self) {
        let tx = lock_recover(&self.intake).take();
        drop(tx);
        let mut threads = lock_recover(&self.threads);
        for t in threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------

fn scheduler_loop(
    rx: Receiver<Envelope>,
    worker_txs: Vec<Sender<Unit>>,
    fuse_policy: BatchPolicy,
    affinity: bool,
    shared: Arc<Shared>,
) {
    let mut affinity_map: HashMap<StructureKey, usize> = HashMap::new();
    let mut rr = 0usize;
    loop {
        // block for the first job of the round
        let first = match rx.recv() {
            Ok(e) => e,
            Err(_) => break,
        };
        let mut window: Vec<Envelope> = vec![first];
        let deadline = Instant::now() + fuse_policy.window;
        while window.len() < fuse_policy.max_batch.max(1) * 4 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(e) => window.push(e),
                Err(_) => break,
            }
        }
        schedule_window(
            window,
            &worker_txs,
            &fuse_policy,
            affinity,
            &mut affinity_map,
            &mut rr,
            &shared,
        );
    }
    // dropping worker_txs lets the workers drain and exit
}

fn unit_priority(u: &Unit) -> Priority {
    match u {
        Unit::One(e, _) => e.priority,
        Unit::Fused(envs, _) => {
            envs.iter().map(|e| e.priority).max().unwrap_or(Priority::Normal)
        }
    }
}

fn unit_order_key(u: &Unit) -> (bool, Instant, u64) {
    // (no-deadline-last, earliest deadline, arrival)
    let (deadline, enqueued, seq) = match u {
        Unit::One(e, _) => (e.deadline, e.enqueued, e.seq),
        Unit::Fused(envs, _) => {
            let d = envs.iter().filter_map(|e| e.deadline).min();
            let s = envs.iter().map(|e| e.seq).min().unwrap_or(0);
            // group members keep arrival order, so the min IS the
            // first member's enqueue time
            let arrival = envs
                .iter()
                .map(|e| e.enqueued)
                .min()
                .unwrap_or_else(Instant::now);
            (d, arrival, s)
        }
    };
    (deadline.is_none(), deadline.unwrap_or(enqueued), seq)
}

/// Bound on the scheduler's pattern→worker map.  A process-lifetime
/// engine (`Engine::global`) serving unbounded distinct patterns must
/// not grow without limit; at the cap the map is cleared (warmth is
/// re-learned, correctness is unaffected).  64-byte-ish entries make
/// this ~1 MiB worst case.
const AFFINITY_MAP_CAP: usize = 16_384;

fn least_depth(depths: &[AtomicUsize]) -> usize {
    let mut best = 0usize;
    let mut best_depth = usize::MAX;
    for (i, d) in depths.iter().enumerate() {
        let v = d.load(Ordering::Relaxed);
        if v < best_depth {
            best = i;
            best_depth = v;
        }
    }
    best
}

fn schedule_window(
    window: Vec<Envelope>,
    worker_txs: &[Sender<Unit>],
    fuse_policy: &BatchPolicy,
    affinity: bool,
    affinity_map: &mut HashMap<StructureKey, usize>,
    rr: &mut usize,
    shared: &Shared,
) {
    // split fusable linear jobs from everything else, keeping arrival
    // order; each job's pattern is hashed ONCE here and the key rides
    // the unit to the worker's shard, so the serve path never re-hashes
    // (pinned by tests/hash_count.rs)
    let mut units: Vec<(Option<StructureKey>, Unit)> = Vec::new();
    let mut linear: Vec<(Envelope, PatternKey)> = Vec::new();
    for env in window {
        match &env.spec {
            JobSpec::Linear { matrix, .. } => {
                let key = PatternKey::of(matrix);
                linear.push((env, key));
            }
            _ => {
                let key = env.spec.affinity_matrix().map(PatternKey::of);
                let skey = key.as_ref().map(PatternKey::structure);
                units.push((skey, Unit::One(env, key)));
            }
        }
    }
    if !linear.is_empty() {
        let keys: Vec<PatternKey> = linear.iter().map(|(_, k)| k.clone()).collect();
        let groups = group_by_key(&keys, fuse_policy.max_batch);
        shared
            .registry
            .incr(names::SERVICE_BATCHES, groups.len() as u64);
        let mut slots: Vec<Option<Envelope>> =
            linear.into_iter().map(|(e, _)| Some(e)).collect();
        for group in groups {
            shared
                .registry
                .incr(names::SERVICE_BATCHED_REQUESTS, group.len() as u64);
            // group_by_key never emits an empty group; degrade to
            // skipping one rather than indexing on faith
            let key = match group.first().and_then(|&i| keys.get(i)) {
                Some(k) => k.clone(),
                None => continue,
            };
            let skey = Some(key.structure());
            let mut envs: Vec<Envelope> = group
                .iter()
                .filter_map(|&i| slots.get_mut(i).and_then(Option::take))
                .collect();
            if envs.len() == 1 {
                if let Some(env) = envs.pop() {
                    units.push((skey, Unit::One(env, Some(key))));
                }
            } else if !envs.is_empty() {
                units.push((skey, Unit::Fused(envs, key)));
            }
        }
    }
    // priority first, then earliest deadline, then arrival
    units.sort_by_key(|(_, u)| (std::cmp::Reverse(unit_priority(u)), unit_order_key(u)));

    for (key, unit) in units {
        // affinity routing on the unit's pattern, load balance otherwise
        let w = if !affinity {
            let w = *rr % worker_txs.len().max(1);
            *rr += 1;
            w
        } else {
            match key {
                Some(key) => match affinity_map.get(&key) {
                    Some(&w) => {
                        shared.registry.incr(names::ENGINE_AFFINITY_HIT, 1);
                        w
                    }
                    None => {
                        let w = least_depth(&shared.depths);
                        // bound the map: a process-lifetime engine fed
                        // unbounded distinct patterns must not leak;
                        // clearing forfeits warmth, never correctness
                        if affinity_map.len() >= AFFINITY_MAP_CAP {
                            affinity_map.clear();
                            shared.registry.incr(names::ENGINE_AFFINITY_MAP_RESET, 1);
                        }
                        affinity_map.insert(key, w);
                        shared.registry.incr(names::ENGINE_AFFINITY_MISS, 1);
                        w
                    }
                },
                None => least_depth(&shared.depths),
            }
        };
        if trace::enabled() {
            match &unit {
                Unit::One(e, _) => {
                    trace::event_job(tn::JOB_SCHEDULED, e.id, e.spec.kind().name(), w as u64);
                }
                Unit::Fused(envs, _) => {
                    for e in envs {
                        trace::event_job(tn::JOB_SCHEDULED, e.id, e.spec.kind().name(), w as u64);
                        trace::event_job(tn::JOB_FUSED, e.id, e.spec.kind().name(), envs.len() as u64);
                    }
                }
            }
        }
        let undeliverable = match worker_txs.get(w) {
            Some(tx) => {
                if let Some(d) = shared.depths.get(w) {
                    d.fetch_add(1, Ordering::Relaxed);
                }
                match tx.send(unit) {
                    Ok(()) => None,
                    Err(std::sync::mpsc::SendError(unit)) => {
                        if let Some(d) = shared.depths.get(w) {
                            d.fetch_sub(1, Ordering::Relaxed);
                        }
                        Some(unit)
                    }
                }
            }
            // w is always in range (every route is mod/over the worker
            // count); treat a miss like a dead worker anyway
            None => Some(unit),
        };
        if let Some(unit) = undeliverable {
            // worker gone (shutdown race): fail the jobs, don't hang
            // them — and un-pin every pattern routed to the dead worker
            // so later same-pattern jobs re-route to a live one
            affinity_map.retain(|_, &mut v| v != w);
            let envs = match unit {
                Unit::One(e, _) => vec![e],
                Unit::Fused(envs, _) => envs,
            };
            for env in envs {
                let Envelope {
                    id,
                    spec,
                    enqueued,
                    reply,
                    ..
                } = env;
                let kind = spec.kind();
                respond(
                    shared,
                    reply,
                    JobResult {
                        id,
                        kind,
                        outcome: Err(Error::WorkerPanic("worker pool stopped".into())),
                        queue_seconds: enqueued.elapsed().as_secs_f64(),
                        service_seconds: 0.0,
                        batch_size: 1,
                        worker: w,
                        convergence: None,
                    },
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------

struct WorkerCtx {
    idx: usize,
    disp: Arc<Dispatcher>,
    shards: Arc<CacheShards>,
    shared: Arc<Shared>,
}

fn worker_loop(rx: Receiver<Unit>, ctx: WorkerCtx) {
    loop {
        let unit = match rx.recv() {
            Ok(u) => u,
            Err(_) => break,
        };
        match unit {
            Unit::One(env, key) => serve_one(env, key, &ctx),
            Unit::Fused(envs, key) => serve_fused(envs, key, &ctx),
        }
        if let Some(d) = ctx.shared.depths.get(ctx.idx) {
            d.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Execute one job, catching panics so a bad residual (or any bug in a
/// solver path) fails THIS job instead of wedging the worker.  `key` is
/// the scheduler's fingerprint of the job's matrix, when it has one.
fn exec_caught(spec: JobSpec, key: Option<PatternKey>, ctx: &WorkerCtx) -> Result<JobOutput> {
    match std::panic::catch_unwind(AssertUnwindSafe(|| exec_spec(spec, key, ctx))) {
        Ok(r) => r,
        Err(p) => {
            ctx.shared.registry.incr(names::ENGINE_PANIC, 1);
            Err(Error::WorkerPanic(panic_msg(&*p)))
        }
    }
}

/// Factor through this worker's shard, re-using the scheduler's
/// fingerprint when the caller carries one.  When it doesn't, the key
/// is computed HERE, exactly once — `CacheShards` is keyed-only, so
/// every path to a shard pays the O(nnz) hash at most once (pinned by
/// `tests/hash_count.rs`).
fn shard_factor(
    ctx: &WorkerCtx,
    a: &Csr,
    key: Option<&PatternKey>,
    budget: u64,
) -> Result<Arc<CachedFactor>> {
    match key {
        Some(k) => {
            ctx.shards
                .factor_on_keyed(ctx.idx, a, k, budget, Some(&ctx.shared.registry))
        }
        None => {
            let k = PatternKey::of(a);
            ctx.shards
                .factor_on_keyed(ctx.idx, a, &k, budget, Some(&ctx.shared.registry))
        }
    }
}

fn serve_one(env: Envelope, key: Option<PatternKey>, ctx: &WorkerCtx) {
    let t0 = Instant::now();
    if expired(env.deadline, t0) {
        respond_timeout(env, t0, &ctx.shared);
        return;
    }
    let Envelope {
        id,
        spec,
        enqueued,
        reply,
        ..
    } = env;
    let kind = spec.kind();
    let queue_seconds = (t0 - enqueued).as_secs_f64();
    let outcome = {
        let structure_hash = key.as_ref().map(|k| k.structure_hash).unwrap_or(0);
        let _scope = trace::job_scope(id, kind.name(), structure_hash, ctx.idx as u32);
        trace::span_between(tn::JOB_QUEUED, enqueued, t0, 0);
        let _exec = trace::span(tn::JOB_EXEC);
        exec_caught(spec, key, ctx)
    };
    respond(
        &ctx.shared,
        reply,
        JobResult {
            id,
            kind,
            outcome,
            queue_seconds,
            service_seconds: t0.elapsed().as_secs_f64(),
            batch_size: 1,
            worker: ctx.idx,
            convergence: None,
        },
    );
}

fn serve_fused(envs: Vec<Envelope>, key: PatternKey, ctx: &WorkerCtx) {
    let t0 = Instant::now();
    let mut live: Vec<Envelope> = Vec::with_capacity(envs.len());
    for env in envs {
        if expired(env.deadline, t0) {
            respond_timeout(env, t0, &ctx.shared);
        } else {
            live.push(env);
        }
    }
    if live.is_empty() {
        return;
    }
    // Soundness re-check (PatternKey's contract): the scheduler groups
    // by 64-bit fingerprints, so before factorizing once for the whole
    // group verify the matrices are actually equal and split out any
    // mismatches into their own uniform sub-batches.
    let uniform = {
        let mats: Vec<&Csr> = live
            .iter()
            .filter_map(|e| e.spec.linear_parts().map(|(m, _, _)| m))
            .collect::<Vec<&Csr>>();
        if mats.len() == live.len() {
            verify_groups(&mats)
        } else {
            Vec::new()
        }
    };
    if uniform.is_empty() {
        // only linear jobs fuse; a non-linear spec in the unit means a
        // scheduler bug — serve every member individually rather than
        // panicking the worker
        for env in live {
            serve_one(env, None, ctx);
        }
        return;
    }
    if uniform.len() > 1 {
        ctx.shared
            .registry
            .incr(names::SERVICE_KEY_COLLISIONS, (uniform.len() - 1) as u64);
    }
    let mut slots: Vec<Option<Envelope>> = live.into_iter().map(Some).collect();
    for group in uniform {
        let sub: Vec<Envelope> = group
            .into_iter()
            .filter_map(|i| slots.get_mut(i).and_then(Option::take))
            .collect();
        serve_uniform(sub, &key, t0, ctx);
    }
}

/// True when the engine may serve a SINGLE job straight from a worker
/// shard.  Mirrors `Dispatcher::cache_eligible` — fully-auto policy,
/// CPU device, below the direct crossover — so shard-direct execution
/// never inverts the dispatcher's size/device routing: a large SPD
/// system the dispatcher would hand to CG, or an Accel-device request,
/// falls through to `disp.solve` exactly as it did pre-engine.
fn direct_eligible(a: &Csr, opts: &SolveOpts) -> bool {
    opts.backend.is_none()
        && opts.method == Method::Auto
        && opts.device == Device::Cpu
        && a.nrows <= DIRECT_CROSSOVER_N
}

/// The factorize-once gate for fused/multi-RHS batches — the old
/// coordinator's gate (fully-auto policy, SPD-looking or below the
/// crossover) plus the CPU-device guard, so Accel-device batches keep
/// their dispatcher semantics instead of being silently served on the
/// CPU shard.  Large non-SPD batches fall through to per-request
/// dispatch (iterative), as before.
fn batch_direct_eligible(a: &Csr, opts: &SolveOpts) -> bool {
    opts.backend.is_none()
        && opts.method == Method::Auto
        && opts.device == Device::Cpu
        && (a.looks_spd() || a.nrows <= DIRECT_CROSSOVER_N)
}

fn batched_label(method: &str) -> &'static str {
    match method {
        "cholesky+rcm" => "cholesky+rcm(batched)",
        "cholesky+rcm+sn" => "cholesky+rcm+sn(batched)",
        _ => "lu(batched)",
    }
}

/// Serve a verified-identical batch: factorize once through this
/// worker's shard (re-using the scheduler's key — no re-hash), sweep
/// every RHS.  Falls back to per-request execution when the matrix
/// cannot be factored (singular, over budget), any member opted out of
/// the auto policy, or a non-linear spec reached the batch (a
/// scheduler bug; served generically, never a panic).
fn serve_uniform(batch: Vec<Envelope>, key: &PatternKey, t0: Instant, ctx: &WorkerCtx) {
    let n = batch.len();
    let mut eligible = true;
    let mut budget = u64::MAX;
    for env in &batch {
        match env.spec.linear_parts() {
            Some((matrix, b, opts)) => {
                eligible &= batch_direct_eligible(matrix, opts) && matrix.nrows == b.len();
                budget = budget.min(opts.host_mem_budget);
            }
            None => eligible = false,
        }
    }
    let rep = if n > 1 && eligible {
        batch
            .first()
            .and_then(|e| e.spec.linear_parts())
            .map(|(matrix, _, _)| matrix.clone())
    } else {
        None
    };
    if let Some(a) = rep {
        // The fused path runs outside exec_caught, so it carries its
        // own panic guards: a factorization panic falls through to the
        // per-request path (which isolates per job), and a solve panic
        // fails THAT member only — the worker must survive either way.
        let factored = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ctx.shards
                .factor_on_keyed(ctx.idx, &a, key, budget, Some(&ctx.shared.registry))
        }));
        if factored.is_err() {
            ctx.shared.registry.incr(names::ENGINE_PANIC, 1);
        }
        if let Ok(Ok(f)) = factored {
            let bytes = f.bytes();
            let method = batched_label(f.method());
            for env in batch {
                let ts = Instant::now();
                let Envelope {
                    id,
                    spec,
                    enqueued,
                    reply,
                    ..
                } = env;
                let b = match spec.into_linear() {
                    Ok((_, b, _)) => b,
                    Err(spec) => {
                        // unreachable in a batch the eligibility loop
                        // verified all-linear; serve generically anyway
                        let kind = spec.kind();
                        let outcome = {
                            let _scope =
                                trace::job_scope(id, kind.name(), 0, ctx.idx as u32);
                            trace::span_between(tn::JOB_QUEUED, enqueued, t0, 0);
                            let _exec = trace::span(tn::JOB_EXEC);
                            exec_caught(*spec, None, ctx)
                        };
                        respond(
                            &ctx.shared,
                            reply,
                            JobResult {
                                id,
                                kind,
                                outcome,
                                queue_seconds: (t0 - enqueued).as_secs_f64(),
                                service_seconds: ts.elapsed().as_secs_f64(),
                                batch_size: n,
                                worker: ctx.idx,
                                convergence: None,
                            },
                        );
                        continue;
                    }
                };
                let outcome = {
                    let _scope = trace::job_scope(
                        id,
                        JobKind::Linear.name(),
                        key.structure_hash,
                        ctx.idx as u32,
                    );
                    trace::span_between(tn::JOB_QUEUED, enqueued, t0, 0);
                    let _exec = trace::span_arg(tn::JOB_EXEC, n as u64);
                    match std::panic::catch_unwind(AssertUnwindSafe(|| {
                        f.solve(&b).map(|x| {
                            let residual = residual_of(&a, &x, &b);
                            JobOutput::Linear(SolveOutcome {
                                x,
                                backend: "native-direct",
                                method,
                                iters: 0,
                                residual,
                                peak_bytes: bytes,
                            })
                        })
                    })) {
                        Ok(r) => r,
                        Err(p) => {
                            ctx.shared.registry.incr(names::ENGINE_PANIC, 1);
                            Err(Error::WorkerPanic(panic_msg(&*p)))
                        }
                    }
                };
                respond(
                    &ctx.shared,
                    reply,
                    JobResult {
                        id,
                        kind: JobKind::Linear,
                        outcome,
                        queue_seconds: (t0 - enqueued).as_secs_f64(),
                        service_seconds: ts.elapsed().as_secs_f64(),
                        batch_size: n,
                        worker: ctx.idx,
                        convergence: None,
                    },
                );
            }
            return;
        }
    }
    // per-request execution; batch_size stays n (these requests DID
    // share the scheduling batch) and each member re-uses the group's
    // key — it IS that member's fingerprint (they were grouped by it)
    for env in batch {
        let ts = Instant::now();
        let Envelope {
            id,
            spec,
            enqueued,
            reply,
            ..
        } = env;
        let kind = spec.kind();
        let key = spec.linear_parts().is_some().then(|| key.clone());
        let outcome = {
            let structure_hash = key.as_ref().map(|k| k.structure_hash).unwrap_or(0);
            let _scope = trace::job_scope(id, kind.name(), structure_hash, ctx.idx as u32);
            trace::span_between(tn::JOB_QUEUED, enqueued, t0, 0);
            let _exec = trace::span(tn::JOB_EXEC);
            exec_caught(spec, key, ctx)
        };
        respond(
            &ctx.shared,
            reply,
            JobResult {
                id,
                kind,
                outcome,
                queue_seconds: (t0 - enqueued).as_secs_f64(),
                service_seconds: ts.elapsed().as_secs_f64(),
                batch_size: n,
                worker: ctx.idx,
                convergence: None,
            },
        );
    }
}

// ---------------------------------------------------------------------
// Family adapters
// ---------------------------------------------------------------------

fn exec_spec(spec: JobSpec, key: Option<PatternKey>, ctx: &WorkerCtx) -> Result<JobOutput> {
    let key = key.as_ref();
    match spec {
        JobSpec::Linear { matrix, b, opts } => {
            exec_linear(&matrix, &b, &opts, key, ctx).map(JobOutput::Linear)
        }
        JobSpec::MultiRhs { matrix, bs, opts } => {
            exec_multi_rhs(&matrix, &bs, &opts, key, ctx).map(JobOutput::MultiRhs)
        }
        JobSpec::Nonlinear { residual, u0, opts } => {
            Ok(JobOutput::Nonlinear(exec_nonlinear(
                residual.as_ref(),
                &u0,
                &opts,
                ctx,
            )))
        }
        JobSpec::Eig { matrix, k, opts } => exec_eig(&matrix, k, &opts).map(JobOutput::Eig),
        JobSpec::Adjoint {
            matrix,
            b,
            gy,
            opts,
        } => exec_adjoint(&matrix, &b, &gy, &opts, key, ctx),
        JobSpec::Dist { tensor, b, opts } => {
            // launches the rank team named by `opts.backend`: thread
            // ranks in-process, or — for `CommBackend::Proc` — spawned
            // worker processes whose liveness is monitored and which
            // are reaped before this returns.  A worker dying mid-solve
            // surfaces here as `Error::RankDead` (typed, never a hang)
            // and flows to the ticket like any other job failure.
            let (x, reports) = tensor.solve(&b, &opts)?;
            Ok(JobOutput::Dist { x, reports })
        }
    }
}

fn exec_linear(
    a: &Csr,
    b: &[f64],
    opts: &SolveOpts,
    key: Option<&PatternKey>,
    ctx: &WorkerCtx,
) -> Result<SolveOutcome> {
    if a.nrows != b.len() {
        return Err(Error::InvalidProblem("rhs length mismatch".into()));
    }
    if direct_eligible(a, opts) {
        if let Ok(f) = shard_factor(ctx, a, key, opts.host_mem_budget) {
            let x = f.solve(b)?;
            let residual = residual_of(a, &x, b);
            return Ok(SolveOutcome {
                x,
                backend: "native-direct",
                method: f.method(),
                iters: 0,
                residual,
                peak_bytes: f.bytes(),
            });
        }
        // shard declined (singular / over budget): the dispatcher's
        // fallback chain decides, same as the old coordinator
    }
    ctx.disp.solve(
        &Problem {
            op: Operator::Csr(a),
            b,
        },
        opts,
    )
}

fn exec_multi_rhs(
    a: &Csr,
    bs: &[Vec<f64>],
    opts: &SolveOpts,
    key: Option<&PatternKey>,
    ctx: &WorkerCtx,
) -> Result<Vec<SolveOutcome>> {
    for b in bs {
        if a.nrows != b.len() {
            return Err(Error::InvalidProblem("rhs length mismatch".into()));
        }
    }
    if batch_direct_eligible(a, opts) {
        if let Ok(f) = shard_factor(ctx, a, key, opts.host_mem_budget) {
            let bytes = f.bytes();
            let method = batched_label(f.method());
            let xs = bs.iter().map(|b| f.solve(b)).collect::<Result<Vec<_>>>()?;
            // ONE fused k-column SpMV verifies every solution — per
            // column bitwise identical to the k separate matvec passes
            let residuals = block_residuals(a, &xs, bs);
            return Ok(xs
                .into_iter()
                .zip(residuals)
                .map(|(x, residual)| SolveOutcome {
                    x,
                    backend: "native-direct",
                    method,
                    iters: 0,
                    residual,
                    peak_bytes: bytes,
                })
                .collect());
        }
    }
    bs.iter()
        .map(|b| {
            ctx.disp.solve(
                &Problem {
                    op: Operator::Csr(a),
                    b,
                },
                opts,
            )
        })
        .collect()
}

/// Residual norms for a block of solutions against one matrix: one
/// fused k-column SpMV ([`crate::sparse::kernels::spmv_block`]) instead
/// of k separate `matvec` traversals.  Each column's SpMV and the
/// single-accumulator norm loop replicate `residual_of`'s FP schedule
/// exactly, so the reported residuals are bitwise identical to the
/// unfused path.
fn block_residuals(a: &Csr, xs: &[Vec<f64>], bs: &[Vec<f64>]) -> Vec<f64> {
    let k = xs.len();
    let n = a.nrows;
    let mut xb = vec![0.0; n * k];
    for (j, x) in xs.iter().enumerate() {
        for (i, v) in x.iter().enumerate() {
            if let Some(slot) = xb.get_mut(i * k + j) {
                *slot = *v;
            }
        }
    }
    let mut axb = vec![0.0; n * k];
    crate::sparse::kernels::spmv_block(a, &xb, &mut axb, k);
    bs.iter()
        .enumerate()
        .map(|(j, b)| {
            let mut r2 = 0.0;
            for (i, bi) in b.iter().enumerate() {
                let d = bi - axb.get(i * k + j).copied().unwrap_or(0.0);
                r2 += d * d;
            }
            r2.sqrt()
        })
        .collect()
}

fn exec_nonlinear(
    f: &dyn crate::nonlinear::Residual,
    u0: &[f64],
    opts: &crate::nonlinear::NewtonOpts,
    ctx: &WorkerCtx,
) -> crate::nonlinear::NonlinearResult {
    // Newton steps solve through THIS worker's shard, so repeated
    // nonlinear jobs inherit symbolic/numeric warmth from the shard
    // (the Jacobian pattern is fixed across iterations).
    let shards = ctx.shards.clone();
    let idx = ctx.idx;
    let reg = ctx.shared.registry.clone();
    let mut step = move |j: &Csr, rhs: &[f64]| -> Option<Vec<f64>> {
        // the Jacobian values change every step, so each step hashes
        // its matrix once here (the shards API is keyed-only)
        let key = PatternKey::of(j);
        let factor = shards
            .factor_on_keyed(idx, j, &key, u64::MAX, Some(&reg))
            .ok()?;
        factor.solve(rhs).ok()
    };
    crate::nonlinear::newton_with_step(f, u0, opts, &mut step)
}

fn exec_eig(
    a: &Csr,
    k: usize,
    opts: &crate::eigen::LobpcgOpts,
) -> Result<crate::eigen::EigResult> {
    if !a.is_symmetric(1e-10) {
        return Err(Error::InvalidProblem("eigsh needs symmetric".into()));
    }
    let m = crate::iterative::Jacobi::new(a)?;
    Ok(crate::eigen::lobpcg(a, &m, k, opts))
}

fn exec_adjoint(
    a: &Csr,
    b: &[f64],
    gy: &[f64],
    opts: &SolveOpts,
    key: Option<&PatternKey>,
    ctx: &WorkerCtx,
) -> Result<JobOutput> {
    if a.nrows != b.len() || a.nrows != gy.len() {
        return Err(Error::InvalidProblem("rhs length mismatch".into()));
    }
    if direct_eligible(a, opts) {
        if let Ok(f) = shard_factor(ctx, a, key, opts.host_mem_budget) {
            // ONE numeric factorization serves forward + transpose
            // (paper Eq. 3)
            let x = f.solve(b)?;
            let lambda = f.solve_t(gy)?;
            return Ok(JobOutput::Adjoint { x, lambda });
        }
    }
    // dispatcher route: the adjoint framework's black-box solver hook
    let solver = ctx.disp.solver_fn(opts.clone());
    let pattern = crate::sparse::Pattern::of(a);
    let x = solver(&pattern, &a.vals, b, Transpose::No)?;
    let lambda = solver(&pattern, &a.vals, gy, Transpose::Yes)?;
    Ok(JobOutput::Adjoint { x, lambda })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    fn engine(workers: usize, fuse: BatchPolicy) -> Engine {
        Engine::start(
            Arc::new(Dispatcher::new(None)),
            EngineConfig {
                workers,
                fuse,
                ..Default::default()
            },
        )
    }

    #[test]
    fn linear_roundtrip_through_submit() {
        let e = engine(2, BatchPolicy::default());
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(64);
        let t = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: b.clone(),
                opts: SolveOpts::default(),
            })
            .unwrap();
        let r = t.wait();
        assert_eq!(r.kind, JobKind::Linear);
        match r.outcome.unwrap() {
            JobOutput::Linear(out) => {
                assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
            }
            _ => panic!("wrong output family"),
        }
        e.shutdown();
    }

    #[test]
    fn priority_and_order_keys_are_well_formed() {
        // Priority ordering drives the scheduler sort
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
    }

    #[test]
    fn queue_full_admission_rejection() {
        let e = Engine::start(
            Arc::new(Dispatcher::new(None)),
            EngineConfig {
                workers: 1,
                max_pending: 0,
                ..Default::default()
            },
        );
        let sys = poisson2d(4, None);
        let err = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: vec![1.0; 16],
                opts: SolveOpts::default(),
            })
            .unwrap_err();
        assert!(matches!(err, Error::QueueFull { .. }));
        assert_eq!(e.stats().rejected, 1);
        e.shutdown();
    }

    #[test]
    fn stats_snapshot_has_all_kinds() {
        let e = engine(1, BatchPolicy::default());
        let s = e.stats();
        assert_eq!(s.kinds.len(), 6);
        assert_eq!(s.queue_depth, 0);
        e.shutdown();
    }
}
