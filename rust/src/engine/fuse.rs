//! Multi-RHS fusion policy: jobs whose matrices share (pattern, values)
//! coalesce into one factorize-once multi-RHS unit.
//!
//! Moved here from `coordinator::batcher` when the engine became the
//! one scheduling layer (the coordinator re-exports these names for
//! compatibility).  The key itself lives in [`crate::sparse::key`] (it
//! is shared with the factor cache); this module owns the fusion
//! *policy*: grouping by key, and the full-equality re-check that makes
//! hash-keyed groups sound (a 64-bit collision must never produce a
//! wrong answer).

use std::collections::HashMap;

pub use crate::sparse::key::PatternKey;
use crate::sparse::Csr;

/// Fusion/batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Max requests coalesced into one multi-RHS solve (<= 1 disables
    /// fusion; jobs are still windowed for scheduling).
    pub max_batch: usize,
    /// Max time the scheduler waits to fill a window.
    pub window: std::time::Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            window: std::time::Duration::from_millis(2),
        }
    }
}

/// Group indices of requests by pattern+values key, preserving arrival
/// order inside each group.
pub fn group_by_key(keys: &[PatternKey], max_batch: usize) -> Vec<Vec<usize>> {
    let mut groups: HashMap<&PatternKey, Vec<usize>> = HashMap::new();
    let mut order: Vec<&PatternKey> = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        let e = groups.entry(k).or_insert_with(|| {
            order.push(k);
            Vec::new()
        });
        e.push(i);
    }
    let mut out = Vec::new();
    for k in order {
        let Some(idxs) = groups.get(k) else { continue };
        for chunk in idxs.chunks(max_batch.max(1)) {
            out.push(chunk.to_vec());
        }
    }
    out
}

/// Soundness re-check for a key-grouped batch: split the group into
/// sub-groups whose matrices are *actually* equal (indptr, indices, and
/// values), preserving arrival order within each sub-group.
///
/// `group_by_key` groups by 64-bit fingerprints; two different matrices
/// can in principle land in one group.  The worker factorizes once per
/// group, so it must only ever see matrices that are bit-identical —
/// this function is that guarantee.  With no collision (the universal
/// case) it returns a single group and costs one O(nnz) comparison per
/// extra member.
pub fn verify_groups(mats: &[&Csr]) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = Vec::new();
    for (i, m) in mats.iter().enumerate() {
        let mut placed = false;
        for group in out.iter_mut() {
            let rep = match group.first().and_then(|&j| mats.get(j)) {
                Some(r) => *r,
                None => continue,
            };
            if rep.indptr == m.indptr && rep.indices == m.indices && rep.vals == m.vals {
                group.push(i);
                placed = true;
                break;
            }
        }
        if !placed {
            out.push(vec![i]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn grouping_respects_max_batch() {
        let a = poisson2d(4, None).matrix;
        let k = PatternKey::of(&a);
        let keys = vec![k.clone(); 7];
        let groups = group_by_key(&keys, 3);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0], vec![0, 1, 2]);
        assert_eq!(groups[2], vec![6]);
    }

    #[test]
    fn mixed_patterns_stay_separate() {
        let a = PatternKey::of(&poisson2d(4, None).matrix);
        let b = PatternKey::of(&poisson2d(5, None).matrix);
        let keys = vec![a.clone(), b.clone(), a.clone()];
        let groups = group_by_key(&keys, 8);
        assert_eq!(groups, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn verify_groups_splits_forced_collision() {
        // Simulate two different matrices landing in one key group (a
        // hash collision the worker must survive): the re-check splits
        // them so each factorize-once sub-batch is uniform.
        let a = poisson2d(4, None).matrix;
        let mut b = a.clone();
        b.vals[0] += 1.0; // same pattern, different values
        let groups = verify_groups(&[&a, &b, &a, &b, &b]);
        assert_eq!(groups, vec![vec![0, 2], vec![1, 3, 4]]);
    }

    #[test]
    fn verify_groups_keeps_identical_matrices_together() {
        let a = poisson2d(5, None).matrix;
        let groups = verify_groups(&[&a, &a, &a]);
        assert_eq!(groups, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn verify_groups_distinguishes_pattern_collisions() {
        // same nrows/nnz, different structure
        use crate::sparse::Coo;
        let mut c1 = Coo::new(3, 3);
        c1.push(0, 0, 1.0);
        c1.push(1, 1, 1.0);
        c1.push(2, 2, 1.0);
        let mut c2 = Coo::new(3, 3);
        c2.push(0, 1, 1.0);
        c2.push(1, 2, 1.0);
        c2.push(2, 0, 1.0);
        let (a, b) = (c1.to_csr(), c2.to_csr());
        assert_eq!(verify_groups(&[&a, &b]), vec![vec![0], vec![1]]);
    }
}
