//! `SparseTensor`: one sparsity pattern, a batch of value planes.

use std::sync::Arc;

use crate::adjoint::{self, SolveFn};
use crate::autograd::{Tape, Var};
use crate::backend::{Dispatcher, Operator, Problem, SolveOpts, SolveOutcome};
use crate::direct::SparseLu;
use crate::eigen::{EigResult, LobpcgOpts};
use crate::factor_cache::FactorCache;
use crate::error::{Error, Result};
use crate::sparse::poisson::StencilCoeffs;
use crate::sparse::{Csr, Pattern};

/// A sparse matrix — or a batch of matrices sharing ONE pattern.
///
/// The shared pattern is what makes batching cheap: direct backends
/// reuse the RCM ordering and symbolic envelope, the XLA backends reuse
/// one compiled artifact, and the distributed layer reuses one halo
/// plan (paper §3.1).
#[derive(Clone)]
pub struct SparseTensor {
    pattern: Pattern,
    /// B value planes, each of length pattern.nnz().
    vals: Vec<Vec<f64>>,
    /// Stencil view per batch element, when the operator came from a
    /// structured grid (unlocks the fused cg_poisson artifacts).
    stencil: Option<Vec<StencilCoeffs>>,
    dispatcher: Arc<Dispatcher>,
    /// Route `solve`/`solve_batch`/`eigsh` through the process-global
    /// solve engine (pattern-affinity scheduling, per-kind metrics)
    /// instead of calling the dispatcher inline.  Off by default;
    /// enable per tensor with [`SparseTensor::via_engine`] or process-
    /// wide with `RSLA_ENGINE=1`.
    use_engine: bool,
    /// Set by [`SparseTensor::with_dispatcher`]; a tensor with a
    /// caller-chosen dispatcher never routes through the global engine
    /// (whose workers hold the default dispatcher).
    custom_dispatcher: bool,
}

impl SparseTensor {
    /// Single matrix from CSR, CPU-native dispatcher.
    pub fn from_csr(m: Csr) -> Self {
        SparseTensor {
            pattern: Pattern::of(&m),
            vals: vec![m.vals],
            stencil: None,
            dispatcher: Arc::new(Dispatcher::new(None)),
            use_engine: false,
            custom_dispatcher: false,
        }
    }

    /// From COO triplets (duplicates sum), like the paper's
    /// `SparseTensor(val, row, col, shape)`.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        rows: Vec<usize>,
        cols: Vec<usize>,
        vals: Vec<f64>,
    ) -> Result<Self> {
        let coo = crate::sparse::Coo::from_triplets(nrows, ncols, rows, cols, vals)?;
        Ok(Self::from_csr(coo.to_csr()))
    }

    /// From a stencil operator (keeps the grid structure for fused
    /// accelerator artifacts).
    pub fn from_stencil(s: StencilCoeffs) -> Self {
        let m = s.to_csr();
        SparseTensor {
            pattern: Pattern::of(&m),
            vals: vec![m.vals],
            stencil: Some(vec![s]),
            dispatcher: Arc::new(Dispatcher::new(None)),
            use_engine: false,
            custom_dispatcher: false,
        }
    }

    /// Batch of value planes over one pattern.
    pub fn batched(pattern: Pattern, vals: Vec<Vec<f64>>) -> Result<Self> {
        for (i, v) in vals.iter().enumerate() {
            if v.len() != pattern.nnz() {
                return Err(Error::InvalidProblem(format!(
                    "batch element {i}: {} values != pattern nnz {}",
                    v.len(),
                    pattern.nnz()
                )));
            }
        }
        Ok(SparseTensor {
            pattern,
            vals,
            stencil: None,
            dispatcher: Arc::new(Dispatcher::new(None)),
            use_engine: false,
            custom_dispatcher: false,
        })
    }

    /// Attach a dispatcher (e.g. with XLA backends); the paper's
    /// `.cuda()` analog is `with_dispatcher(accel_dispatcher)` + Accel
    /// device in SolveOpts.
    pub fn with_dispatcher(mut self, d: Arc<Dispatcher>) -> Self {
        self.dispatcher = d;
        self.custom_dispatcher = true;
        self
    }

    /// Route solves/eigsh through the process-global solve engine
    /// ([`crate::engine::Engine::global`]): requests join the shared
    /// scheduling queue, gain pattern-affinity factor-cache locality and
    /// per-kind latency metrics, and may fuse with same-(pattern,
    /// values) traffic from other callers.  Results are identical to
    /// the inline path (the engine's direct route runs the same
    /// factorizations).
    ///
    /// The engine route only applies to tensors on the DEFAULT
    /// dispatcher with no stencil operator: the global engine's workers
    /// hold the default (native) dispatcher, so a tensor configured via
    /// [`SparseTensor::with_dispatcher`] (e.g. XLA backends) or one
    /// built from a stencil keeps its inline path — routing those
    /// through the engine would silently drop the caller's backend
    /// choice or the stencil fast path.
    pub fn via_engine(mut self, on: bool) -> Self {
        self.use_engine = on;
        self
    }

    fn engine_enabled(&self) -> bool {
        // read the env flag once per process: this sits on every
        // solve/eigsh call and must not take the environment lock
        static ENV_ENGINE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        let env_on = *ENV_ENGINE
            .get_or_init(|| std::env::var("RSLA_ENGINE").map(|v| v == "1").unwrap_or(false));
        (self.use_engine || env_on) && !self.custom_dispatcher && self.stencil.is_none()
    }

    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }

    pub fn batch_size(&self) -> usize {
        self.vals.len()
    }

    pub fn nrows(&self) -> usize {
        self.pattern.nrows
    }

    pub fn nnz(&self) -> usize {
        self.pattern.nnz()
    }

    pub fn vals(&self, b: usize) -> &[f64] {
        &self.vals[b]
    }

    /// CSR view of batch element `b`.
    pub fn to_csr(&self, b: usize) -> Csr {
        self.pattern.with_vals(self.vals[b].clone())
    }

    fn problem_op(&self, b: usize) -> (Option<&StencilCoeffs>, Csr) {
        let st = self.stencil.as_ref().map(|v| &v[b]);
        (st, self.to_csr(b))
    }

    /// y = A x (first batch element).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.to_csr(0).matvec(x)
    }

    /// Solve A x = b for the first batch element.
    pub fn solve(&self, b: &[f64], opts: &SolveOpts) -> Result<Vec<f64>> {
        Ok(self.solve_full(0, b, opts)?.x)
    }

    /// Solve with the full outcome report (backend, iters, memory).
    pub fn solve_full(&self, batch: usize, b: &[f64], opts: &SolveOpts) -> Result<SolveOutcome> {
        if self.engine_enabled() {
            let ticket = crate::engine::Engine::global().submit(crate::engine::JobSpec::Linear {
                matrix: self.to_csr(batch),
                b: b.to_vec(),
                opts: opts.clone(),
            })?;
            return match ticket.wait().outcome? {
                crate::engine::JobOutput::Linear(out) => Ok(out),
                _ => Err(Error::WorkerPanic(
                    "linear job produced a non-linear output".into(),
                )),
            };
        }
        let (st, csr) = self.problem_op(batch);
        let p = match st {
            Some(s) => Problem {
                op: Operator::Stencil(s),
                b,
            },
            None => Problem {
                op: Operator::Csr(&csr),
                b,
            },
        };
        self.dispatcher.solve(&p, opts)
    }

    /// Batched solve: one RHS per batch element, single symbolic
    /// factorization when the matrix is SPD and shared-pattern direct
    /// dispatch applies.
    pub fn solve_batch(&self, bs: &[Vec<f64>], opts: &SolveOpts) -> Result<Vec<Vec<f64>>> {
        if bs.len() != self.batch_size() && self.batch_size() == 1 {
            // one matrix, many rhs: ONE factorization serves the whole
            // sweep.  Through the engine this is a single MultiRhs job
            // (the worker's shard holds the factor); inline it goes
            // through the process-wide cache as before.
            if self.engine_enabled() {
                let ticket =
                    crate::engine::Engine::global().submit(crate::engine::JobSpec::MultiRhs {
                        matrix: self.to_csr(0),
                        bs: bs.to_vec(),
                        opts: opts.clone(),
                    })?;
                return match ticket.wait().outcome? {
                    crate::engine::JobOutput::MultiRhs(outs) => {
                        Ok(outs.into_iter().map(|o| o.x).collect())
                    }
                    _ => Err(Error::WorkerPanic(
                        "multi-rhs job produced a different output".into(),
                    )),
                };
            }
            let a = self.to_csr(0);
            let f = FactorCache::global().factor(&a, u64::MAX, None)?;
            return bs.iter().map(|b| f.solve(b)).collect();
        }
        if bs.len() != self.batch_size() {
            return Err(Error::InvalidProblem(format!(
                "{} rhs for batch of {}",
                bs.len(),
                self.batch_size()
            )));
        }
        (0..bs.len())
            .map(|i| Ok(self.solve_full(i, &bs[i], opts)?.x))
            .collect()
    }

    /// Differentiable solve: ONE adjoint node on `tape` (paper §3.2).
    /// `vals_var` must hold nnz values bound to this tensor's pattern.
    pub fn solve_ad(
        &self,
        tape: &Tape,
        vals_var: Var,
        b_var: Var,
        opts: &SolveOpts,
    ) -> Result<Var> {
        let solver = self.solver_fn(opts.clone());
        adjoint::solve_linear(tape, &self.pattern, vals_var, b_var, &solver)
    }

    /// The dispatcher as an adjoint-framework black-box solver.
    pub fn solver_fn(&self, opts: SolveOpts) -> SolveFn {
        self.dispatcher.solver_fn(opts)
    }

    /// Differentiable k smallest eigenvalues (first batch element).
    pub fn eigsh_ad(
        &self,
        tape: &Tape,
        vals_var: Var,
        k: usize,
        opts: &LobpcgOpts,
    ) -> Result<(Var, EigResult)> {
        adjoint::eigsh(tape, &self.pattern, vals_var, k, opts)
    }

    /// Non-differentiable eigsh (first batch element).
    pub fn eigsh(&self, k: usize, opts: &LobpcgOpts) -> Result<EigResult> {
        if self.engine_enabled() {
            let ticket = crate::engine::Engine::global().submit(crate::engine::JobSpec::Eig {
                matrix: self.to_csr(0),
                k,
                opts: opts.clone(),
            })?;
            return match ticket.wait().outcome? {
                crate::engine::JobOutput::Eig(r) => Ok(r),
                _ => Err(Error::WorkerPanic(
                    "eig job produced a different output".into(),
                )),
            };
        }
        let a = self.to_csr(0);
        if !a.is_symmetric(1e-10) {
            return Err(Error::InvalidProblem("eigsh needs symmetric".into()));
        }
        let m = crate::iterative::Jacobi::new(&a)?;
        Ok(crate::eigen::lobpcg(&a, &m, k, opts))
    }

    /// Determinant via sparse LU: det(A) = sign(P) * prod(diag U).
    /// Returns (sign, log|det|) to stay finite at scale.
    pub fn slogdet(&self) -> Result<(f64, f64)> {
        let a = self.to_csr(0);
        let f = SparseLu::factor(&a)?;
        Ok(f.slogdet())
    }

    pub fn det(&self) -> Result<f64> {
        let (sign, logabs) = self.slogdet()?;
        Ok(sign * logabs.exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn solve_roundtrip() {
        let sys = poisson2d(10, None);
        let t = SparseTensor::from_csr(sys.matrix.clone());
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(100);
        let x = t.solve(&b, &SolveOpts::default()).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-9);
    }

    #[test]
    fn stencil_tensor_keeps_structure() {
        let g = 12;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let t = SparseTensor::from_stencil(sys.coeffs.clone());
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let out = t.solve_full(0, &b, &SolveOpts::default()).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn multi_rhs_reuses_factorization() {
        let sys = poisson2d(8, None);
        let t = SparseTensor::from_csr(sys.matrix.clone());
        let mut rng = Prng::new(2);
        let bs: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(64)).collect();
        let xs = t.solve_batch(&bs, &SolveOpts::default()).unwrap();
        for (x, b) in xs.iter().zip(&bs) {
            assert!(util::rel_l2(&sys.matrix.matvec(x), b) < 1e-9);
        }
    }

    #[test]
    fn batched_shared_pattern() {
        let sys = poisson2d(6, None);
        let pattern = Pattern::of(&sys.matrix);
        let mut rng = Prng::new(3);
        // batch = base matrix with scaled values (stays SPD)
        let scales = [1.0, 2.0, 0.5];
        let vals: Vec<Vec<f64>> = scales
            .iter()
            .map(|s| sys.matrix.vals.iter().map(|v| v * s).collect())
            .collect();
        let t = SparseTensor::batched(pattern, vals).unwrap();
        assert_eq!(t.batch_size(), 3);
        let bs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(36)).collect();
        let xs = t.solve_batch(&bs, &SolveOpts::default()).unwrap();
        for ((x, b), s) in xs.iter().zip(&bs).zip(&scales) {
            let mut ax = sys.matrix.matvec(x);
            for v in ax.iter_mut() {
                *v *= s;
            }
            assert!(util::rel_l2(&ax, b) < 1e-9);
        }
    }

    #[test]
    fn solve_ad_gradients_flow() {
        let sys = poisson2d(6, None);
        let t = SparseTensor::from_csr(sys.matrix.clone());
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let b = tape.leaf_vec(vec![1.0; 36]);
        let x = t.solve_ad(&tape, vals, b, &SolveOpts::default()).unwrap();
        let loss = tape.dot(x, x);
        let g = tape.backward(loss);
        assert!(g.vec(vals).iter().any(|v| *v != 0.0));
        assert!(g.vec(b).iter().any(|v| *v != 0.0));
    }

    #[test]
    fn det_of_identity_and_diagonal() {
        use crate::sparse::Coo;
        let t = SparseTensor::from_csr(Csr::identity(5));
        assert!((t.det().unwrap() - 1.0).abs() < 1e-12);

        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, -3.0);
        coo.push(2, 2, 4.0);
        let t = SparseTensor::from_csr(coo.to_csr());
        assert!((t.det().unwrap() + 24.0).abs() < 1e-10);
        let (sign, logabs) = t.slogdet().unwrap();
        assert_eq!(sign, -1.0);
        assert!((logabs - 24.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn eigsh_entry_point() {
        let sys = poisson2d(8, None);
        let t = SparseTensor::from_csr(sys.matrix.clone());
        let r = t.eigsh(2, &LobpcgOpts::default()).unwrap();
        assert_eq!(r.values.len(), 2);
        assert!(r.values[0] > 0.0 && r.values[0] <= r.values[1]);
    }

    #[test]
    fn engine_path_matches_inline_path() {
        // via_engine routes through the process-global engine; results
        // must match the inline dispatcher path (same factorizations).
        let sys = poisson2d(8, None);
        let mut rng = Prng::new(7);
        let b = rng.normal_vec(64);
        let inline = SparseTensor::from_csr(sys.matrix.clone());
        let engined = SparseTensor::from_csr(sys.matrix.clone()).via_engine(true);
        let x0 = inline.solve(&b, &SolveOpts::default()).unwrap();
        let x1 = engined.solve(&b, &SolveOpts::default()).unwrap();
        assert!(util::rel_l2(&x1, &x0) < 1e-12);
        // multi-rhs sweep through a single MultiRhs job
        let bs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(64)).collect();
        let xs0 = inline.solve_batch(&bs, &SolveOpts::default()).unwrap();
        let xs1 = engined.solve_batch(&bs, &SolveOpts::default()).unwrap();
        assert_eq!(xs0, xs1, "engine multi-rhs must be bitwise identical");
        // eigsh as an Eig job
        let e0 = inline.eigsh(2, &crate::eigen::LobpcgOpts::default()).unwrap();
        let e1 = engined.eigsh(2, &crate::eigen::LobpcgOpts::default()).unwrap();
        for (a, b) in e0.values.iter().zip(&e1.values) {
            assert!((a - b).abs() < 1e-9 * b.abs().max(1.0));
        }
    }

    #[test]
    fn batched_rejects_wrong_nnz() {
        let sys = poisson2d(4, None);
        let pattern = Pattern::of(&sys.matrix);
        assert!(SparseTensor::batched(pattern, vec![vec![1.0; 3]]).is_err());
    }
}
