//! Differentiable variable-coefficient Poisson assembly on the tape —
//! the user-level code of the paper's inverse problem (§4.4): gradients
//! flow from the loss through the solve (adjoint node) AND through the
//! assembly `kappa -> A(kappa)` (ordinary tape ops), with
//! `kappa = softplus(theta)` enforcing positivity.

use std::sync::Arc;

use crate::autograd::{Tape, Var};
use crate::sparse::poisson::poisson2d;
use crate::sparse::Pattern;

/// Precomputed index maps for a g x g grid assembly.
pub struct PoissonAssembler {
    pub g: usize,
    pub pattern: Pattern,
    idx_up: Arc<Vec<usize>>,
    idx_dn: Arc<Vec<usize>>,
    idx_lf: Arc<Vec<usize>>,
    idx_rt: Arc<Vec<usize>>,
    /// entry -> position in the concatenated (5, g, g) planes.
    entry_map: Arc<Vec<usize>>,
    inv_h2: f64,
}

impl PoissonAssembler {
    pub fn new(g: usize) -> Self {
        let n = g * g;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let clampi = |i: isize, j: isize| -> usize {
            let ic = i.clamp(0, g as isize - 1) as usize;
            let jc = j.clamp(0, g as isize - 1) as usize;
            ic * g + jc
        };
        let mut up = vec![0usize; n];
        let mut dn = vec![0usize; n];
        let mut lf = vec![0usize; n];
        let mut rt = vec![0usize; n];
        for i in 0..g as isize {
            for j in 0..g as isize {
                let k = (i as usize) * g + j as usize;
                up[k] = clampi(i - 1, j);
                dn[k] = clampi(i + 1, j);
                lf[k] = clampi(i, j - 1);
                rt[k] = clampi(i, j + 1);
            }
        }
        // map stored CSR entries to plane positions
        let mut entry_map = vec![0usize; pattern.nnz()];
        for r in 0..n {
            for e in pattern.indptr[r]..pattern.indptr[r + 1] {
                let c = pattern.indices[e];
                entry_map[e] = if c == r {
                    r
                } else if c + g == r {
                    n + r // up neighbor (i-1, j)
                } else if c == r + g {
                    2 * n + r // down
                } else if c + 1 == r {
                    3 * n + r // left
                } else if c == r + 1 {
                    4 * n + r // right
                } else {
                    unreachable!("non-5-point entry") // rsla-lint: allow(L1, the assembler itself generated this pattern as exactly 5-point)
                };
            }
        }
        let h = 1.0 / (g as f64 + 1.0);
        PoissonAssembler {
            g,
            pattern,
            idx_up: Arc::new(up),
            idx_dn: Arc::new(dn),
            idx_lf: Arc::new(lf),
            idx_rt: Arc::new(rt),
            entry_map: Arc::new(entry_map),
            inv_h2: 1.0 / (h * h),
        }
    }

    /// kappa (g*g Var, positive) -> CSR values Var on `self.pattern`.
    /// Harmonic-mean faces, matching `sparse::poisson::stencil_coeffs`.
    pub fn assemble(&self, tape: &Tape, kappa: Var) -> Var {
        let face = |nbr_idx: &Arc<Vec<usize>>| -> Var {
            let kn = tape.gather(kappa, nbr_idx.clone());
            let prod = tape.mul(kappa, kn);
            let two_prod = tape.scale_const(2.0, prod);
            let sum = tape.add(kappa, kn);
            tape.div(two_prod, sum)
        };
        let fu = face(&self.idx_up);
        let fd = face(&self.idx_dn);
        let fl = face(&self.idx_lf);
        let fr = face(&self.idx_rt);
        let s1 = tape.add(fu, fd);
        let s2 = tape.add(fl, fr);
        let center_raw = tape.add(s1, s2);
        let center = tape.scale_const(self.inv_h2, center_raw);
        let up = tape.scale_const(-self.inv_h2, fu);
        let dn = tape.scale_const(-self.inv_h2, fd);
        let lf = tape.scale_const(-self.inv_h2, fl);
        let rt = tape.scale_const(-self.inv_h2, fr);
        let planes = tape.concat(&[center, up, dn, lf, rt]);
        tape.gather(planes, self.entry_map.clone())
    }

    /// Tikhonov smoothness regularizer ||grad_h kappa||^2 / n (paper
    /// §4.4): squared forward differences in both grid directions.
    pub fn smoothness(&self, tape: &Tape, kappa: Var) -> Var {
        let g = self.g;
        let n = g * g;
        // forward-difference neighbor indices (clamped at the far edge
        // so boundary rows contribute zero difference)
        let mut right = vec![0usize; n];
        let mut down = vec![0usize; n];
        for i in 0..g {
            for j in 0..g {
                let k = i * g + j;
                right[k] = if j + 1 < g { k + 1 } else { k };
                down[k] = if i + 1 < g { k + g } else { k };
            }
        }
        let kr = tape.gather(kappa, Arc::new(right));
        let kd = tape.gather(kappa, Arc::new(down));
        let dx = tape.sub(kr, kappa);
        let dy = tape.sub(kd, kappa);
        let sx = tape.dot(dx, dx);
        let sy = tape.dot(dy, dy);
        let s = tape.add_ss(sx, sy);
        tape.scale_const_s(1.0 / n as f64, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::{kappa_star, stencil_coeffs};
    use crate::util::{self, Prng};

    #[test]
    fn tape_assembly_matches_native_assembly() {
        let g = 12;
        let asm = PoissonAssembler::new(g);
        let kappa = kappa_star(g);
        let tape = Tape::new();
        let kv = tape.leaf_vec(kappa.clone());
        let vals = asm.assemble(&tape, kv);
        let got = tape.vec_of(vals);
        let want = stencil_coeffs(g, Some(&kappa)).to_csr().vals;
        assert!(util::max_abs_diff(&got, &want) < 1e-9, "assembly mismatch");
    }

    #[test]
    fn assembly_gradient_checks_against_fd() {
        let g = 5;
        let n = g * g;
        let asm = PoissonAssembler::new(g);
        let mut rng = Prng::new(0);
        let kappa0: Vec<f64> = (0..n).map(|_| 1.0 + 0.5 * rng.uniform()).collect();
        let w = rng.normal_vec(asm.pattern.nnz());

        let loss_of = |kappa: &[f64]| -> f64 {
            let tape = Tape::new();
            let kv = tape.leaf_vec(kappa.to_vec());
            let vals = asm.assemble(&tape, kv);
            let wv = tape.constant_vec(w.clone());
            tape.scalar_of(tape.dot(vals, wv))
        };

        let tape = Tape::new();
        let kv = tape.leaf_vec(kappa0.clone());
        let vals = asm.assemble(&tape, kv);
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(vals, wv);
        let grads = tape.backward(loss);
        let gk = grads.vec(kv).clone();

        let r = crate::gradcheck::check_direction(loss_of, &kappa0, &gk, 1e-6, 3, 1);
        assert!(r.rel_error < 1e-6, "rel err {}", r.rel_error);
    }

    #[test]
    fn smoothness_zero_for_constant_field() {
        let g = 8;
        let asm = PoissonAssembler::new(g);
        let tape = Tape::new();
        let kv = tape.leaf_vec(vec![3.0; g * g]);
        let s = asm.smoothness(&tape, kv);
        assert_eq!(tape.scalar_of(s), 0.0);
    }
}
