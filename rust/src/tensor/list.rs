//! `SparseTensorList`: a batch of matrices with DISTINCT patterns —
//! the paper's GNN-minibatch / irregular-mesh workload (§3.1).  Each
//! element dispatches independently with an isolated autograd graph.

use std::sync::Arc;

use crate::autograd::{Tape, Var};
use crate::backend::{Dispatcher, SolveOpts, SolveOutcome};
use crate::error::{Error, Result};
use crate::sparse::Csr;

use super::SparseTensor;

/// Batch over distinct sparsity patterns.
#[derive(Clone)]
pub struct SparseTensorList {
    items: Vec<SparseTensor>,
}

impl SparseTensorList {
    pub fn from_csrs(mats: Vec<Csr>) -> Self {
        SparseTensorList {
            items: mats.into_iter().map(SparseTensor::from_csr).collect(),
        }
    }

    pub fn with_dispatcher(mut self, d: Arc<Dispatcher>) -> Self {
        self.items = self
            .items
            .into_iter()
            .map(|t| t.with_dispatcher(d.clone()))
            .collect();
        self
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn get(&self, i: usize) -> &SparseTensor {
        &self.items[i]
    }

    /// Per-element solve; each element may land on a different backend.
    pub fn solve(&self, bs: &[Vec<f64>], opts: &SolveOpts) -> Result<Vec<Vec<f64>>> {
        if bs.len() != self.items.len() {
            return Err(Error::InvalidProblem(format!(
                "{} rhs for list of {}",
                bs.len(),
                self.items.len()
            )));
        }
        self.items
            .iter()
            .zip(bs)
            .map(|(t, b)| t.solve(b, opts))
            .collect()
    }

    /// Per-element solve with full outcome reports (router/batcher
    /// observability in the coordinator).
    pub fn solve_full(&self, bs: &[Vec<f64>], opts: &SolveOpts) -> Result<Vec<SolveOutcome>> {
        if bs.len() != self.items.len() {
            return Err(Error::InvalidProblem("rhs count mismatch".into()));
        }
        self.items
            .iter()
            .zip(bs)
            .map(|(t, b)| t.solve_full(0, b, opts))
            .collect()
    }

    /// Differentiable per-element solves on one tape: each element adds
    /// ONE adjoint node (isolated graphs joined only by the caller's
    /// loss), as in the paper's SparseTensorList semantics.
    pub fn solve_ad(
        &self,
        tape: &Tape,
        vals_vars: &[Var],
        b_vars: &[Var],
        opts: &SolveOpts,
    ) -> Result<Vec<Var>> {
        if vals_vars.len() != self.items.len() || b_vars.len() != self.items.len() {
            return Err(Error::InvalidProblem("var count mismatch".into()));
        }
        self.items
            .iter()
            .zip(vals_vars.iter().zip(b_vars))
            .map(|(t, (&v, &b))| t.solve_ad(tape, v, b, opts))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_graph_laplacian, random_spd};
    use crate::util::{self, Prng};

    fn sample_list(rng: &mut Prng) -> (SparseTensorList, Vec<Csr>) {
        let mats = vec![
            random_graph_laplacian(rng, 30, 4, 0.3),
            random_spd(rng, 25, 3, 1.0),
            random_graph_laplacian(rng, 40, 3, 0.2),
        ];
        (SparseTensorList::from_csrs(mats.clone()), mats)
    }

    #[test]
    fn distinct_patterns_solve() {
        let mut rng = Prng::new(0);
        let (list, mats) = sample_list(&mut rng);
        let bs: Vec<Vec<f64>> = mats.iter().map(|m| rng.normal_vec(m.nrows)).collect();
        let xs = list.solve(&bs, &SolveOpts::default()).unwrap();
        for ((x, b), m) in xs.iter().zip(&bs).zip(&mats) {
            assert!(util::rel_l2(&m.matvec(x), b) < 1e-9);
        }
    }

    #[test]
    fn rhs_count_checked() {
        let mut rng = Prng::new(1);
        let (list, _) = sample_list(&mut rng);
        assert!(list.solve(&[vec![1.0; 30]], &SolveOpts::default()).is_err());
    }

    #[test]
    fn isolated_autograd_graphs() {
        let mut rng = Prng::new(2);
        let (list, mats) = sample_list(&mut rng);
        let tape = Tape::new();
        let vals: Vec<Var> = mats.iter().map(|m| tape.leaf_vec(m.vals.clone())).collect();
        let bs: Vec<Var> = mats
            .iter()
            .map(|m| tape.leaf_vec(rng.normal_vec(m.nrows)))
            .collect();
        let before = tape.node_count();
        let xs = list.solve_ad(&tape, &vals, &bs, &SolveOpts::default()).unwrap();
        assert_eq!(tape.node_count() - before, 3, "one node per element");
        // joint loss; gradients reach every element's values
        let l0 = tape.dot(xs[0], xs[0]);
        let l1 = tape.dot(xs[1], xs[1]);
        let l2 = tape.dot(xs[2], xs[2]);
        let l01 = tape.add_ss(l0, l1);
        let loss = tape.add_ss(l01, l2);
        let g = tape.backward(loss);
        for v in &vals {
            assert!(g.vec(*v).iter().any(|x| *x != 0.0));
        }
    }
}
