//! Typed sparse-tensor hierarchy (paper §3.1):
//!
//! | layout      | single matrix                        | matrix list |
//! |-------------|--------------------------------------|-------------|
//! | local       | [`SparseTensor`]                     | [`SparseTensorList`] |
//! | distributed | [`crate::distributed::DSparseTensor`] | [`crate::distributed::DSparseTensorList`] |
//!
//! `SparseTensor` carries one sparsity pattern and a *batch* of value
//! planes sharing it, so one symbolic factorization / artifact / halo
//! plan serves the whole batch; `SparseTensorList` batches matrices
//! with distinct patterns (GNN minibatches), dispatching each element
//! independently.  All types expose the same surface: `.solve`,
//! `.matvec`, `.eigsh`, `.det`, plus autograd-aware `solve_ad`.

pub mod list;
pub mod poisson_ad;
pub mod sparse_tensor;

pub use crate::backend::{Device, Method, SolveOpts};
pub use list::SparseTensorList;
pub use poisson_ad::PoissonAssembler;
pub use sparse_tensor::SparseTensor;
