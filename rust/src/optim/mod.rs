//! Optimizers for the end-to-end training loops (paper §4.4 uses Adam).

/// Adam (Kingma & Ba 2015) over a flat parameter vector.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(n: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// One update step: params -= lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, params: &mut [f64], grads: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = ||x - c||^2
        let c = [3.0, -1.0, 0.5];
        let mut x = vec![0.0; 3];
        let mut adam = Adam::new(3, 0.1);
        for _ in 0..500 {
            let grads: Vec<f64> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            adam.step(&mut x, &grads);
        }
        for (xi, ci) in x.iter().zip(&c) {
            assert!((xi - ci).abs() < 1e-3, "{xi} vs {ci}");
        }
    }

    #[test]
    fn bias_correction_first_step() {
        // first step should move by ~lr in the gradient direction
        let mut x = vec![0.0];
        let mut adam = Adam::new(1, 0.01);
        adam.step(&mut x, &[1.0]);
        assert!((x[0] + 0.01).abs() < 1e-6);
    }
}
