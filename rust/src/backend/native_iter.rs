//! `native-iter`: the Eigen-CG/BiCGStab analog.  Jacobi-preconditioned
//! CG for SPD operators, BiCGStab (or GMRES on request) otherwise;
//! O(nnz) memory, measured via MemTracker.

use super::{Backend, Device, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::error::Result;
use crate::iterative::{bicgstab, cg, gmres, IterOpts, Jacobi, LinOp};
use crate::metrics::MemTracker;

pub struct NativeIter;

impl Backend for NativeIter {
    fn name(&self) -> &'static str {
        "native-iter"
    }

    fn device(&self) -> Device {
        Device::Cpu
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        if p.op.nrows() != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        if matches!(opts.method, Method::Cholesky | Method::Lu) {
            return Err("direct method requested".into());
        }
        if matches!(opts.method, Method::Cg | Method::Auto) && !p.op.is_spd_like() {
            if opts.method == Method::Cg {
                return Err("cg requires an SPD operator".into());
            }
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let mem = MemTracker::new();
        let iter_opts = IterOpts {
            tol: opts.tol,
            max_iters: opts.max_iters,
            record_history: false,
        };
        let spd = p.op.is_spd_like();

        // the operator applies natively (stencil stays matrix-free);
        // Jacobi needs the diagonal either way.
        let (result, method): (_, &'static str) = match &p.op {
            Operator::Stencil(s) => {
                let m = Jacobi::from_diag(&s.center);
                let _hold = mem.hold((s.n() * 8) as u64); // diag inverse
                (cg(*s, p.b, &m, &iter_opts, Some(&mem)), "cg+jacobi")
            }
            Operator::Csr(a) => {
                let _hold = mem.hold(crate::metrics::mem::csr_bytes(a.nrows, a.nnz()));
                let m = Jacobi::new(a)?;
                match opts.method {
                    Method::Gmres => (
                        gmres(*a as &dyn LinOp, p.b, &m, 50, &iter_opts, Some(&mem)),
                        "gmres50+jacobi",
                    ),
                    Method::Bicgstab => (
                        bicgstab(*a as &dyn LinOp, p.b, &m, &iter_opts, Some(&mem)),
                        "bicgstab+jacobi",
                    ),
                    _ if spd => (cg(*a, p.b, &m, &iter_opts, Some(&mem)), "cg+jacobi"),
                    _ => (
                        bicgstab(*a as &dyn LinOp, p.b, &m, &iter_opts, Some(&mem)),
                        "bicgstab+jacobi",
                    ),
                }
            }
        };
        // failing to reach tol is an ERROR at the backend boundary: the
        // dispatcher can then fall through to another backend, and a
        // caller never mistakes a stalled Krylov iterate for a solution.
        // Breakdown (non-SPD operator, degenerate recurrence) is
        // reported as its own error kind so callers can distinguish it
        // from an exhausted iteration budget.
        if !result.converged {
            if result.breakdown {
                return Err(crate::error::Error::Breakdown {
                    at: result.iters,
                    reason: format!(
                        "krylov breakdown after {} iterations (operator not SPD, or degenerate recurrence); residual {:.3e}",
                        result.iters, result.residual
                    ),
                });
            }
            return Err(crate::error::Error::NotConverged {
                iters: result.iters,
                residual: result.residual,
                tol: opts.tol,
            });
        }
        Ok(SolveOutcome {
            x: result.x,
            backend: self.name(),
            method,
            iters: result.iters,
            residual: result.residual,
            peak_bytes: mem.peak(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn stencil_cg_is_matrix_free() {
        let sys = poisson2d(16, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(256);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Stencil(&sys.coeffs),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "cg+jacobi");
        assert!(out.iters > 0);
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
        // matrix-free: working set ~ 6 n vectors, NOT nnz-scaled CSR
        assert!(out.peak_bytes < (10 * 256 * 8) as u64);
    }

    #[test]
    fn nonsymmetric_routes_to_bicgstab() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 4);
        let b = rng.normal_vec(80);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "bicgstab+jacobi");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn gmres_on_request() {
        let mut rng = Prng::new(2);
        let a = random_nonsymmetric(&mut rng, 50, 4);
        let b = rng.normal_vec(50);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    method: Method::Gmres,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(out.method, "gmres50+jacobi");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn breakdown_surfaces_as_breakdown_error() {
        use crate::sparse::Coo;
        // looks SPD (symmetric, positive diagonal) but is indefinite:
        // auto-method picks CG, which breaks down on pAp < 0.  The
        // backend must surface Error::Breakdown — the signal the
        // dispatcher's runtime-fallback path keys on — not NotConverged.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let b = vec![1.0, -1.0];
        let err = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    method: Method::Cg,
                    ..Default::default()
                },
            )
            .unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Breakdown { .. }),
            "expected Breakdown, got: {err}"
        );
    }

    #[test]
    fn cg_on_nonsymmetric_is_refused() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 20, 3);
        let b = vec![1.0; 20];
        let p = Problem {
            op: Operator::Csr(&a),
            b: &b,
        };
        assert!(NativeIter
            .supports(
                &p,
                &SolveOpts {
                    method: Method::Cg,
                    ..Default::default()
                }
            )
            .is_err());
    }
}
