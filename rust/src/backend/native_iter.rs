//! `native-iter`: the Eigen-CG/BiCGStab analog.  Jacobi-preconditioned
//! CG for SPD operators, BiCGStab (GMRES or MINRES on request)
//! otherwise; O(nnz) memory, measured via MemTracker.
//!
//! Routes straight into the generic [`crate::krylov`] kernels under
//! [`NullComm`] — the same bodies the distributed layer runs over rank
//! teams.

use super::{Backend, Device, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::error::Result;
use crate::iterative::{Identity, IterOpts, Jacobi};
use crate::krylov::{self, NullComm, SerialOp};
use crate::metrics::MemTracker;

pub struct NativeIter;

impl Backend for NativeIter {
    fn name(&self) -> &'static str {
        "native-iter"
    }

    fn device(&self) -> Device {
        Device::Cpu
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        if p.op.nrows() != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        if matches!(opts.method, Method::Cholesky | Method::Lu) {
            return Err("direct method requested".into());
        }
        if matches!(opts.method, Method::Cg | Method::Auto) && !p.op.is_spd_like() {
            if opts.method == Method::Cg {
                return Err("cg requires an SPD operator".into());
            }
        }
        if opts.method == Method::Minres {
            let symmetric = match &p.op {
                Operator::Stencil(_) => true, // 5-point stencil is symmetric
                // served from the factor cache when this matrix was ever
                // factored; falls back to one O(nnz) scan otherwise
                Operator::Csr(a) => crate::factor_cache::FactorCache::global().symmetry_of(a),
            };
            if !symmetric {
                return Err("minres requires a symmetric operator".into());
            }
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let _sp = crate::trace::span_arg(crate::trace::names::BACKEND_SOLVE, p.b.len() as u64);
        let mem = MemTracker::new();
        let iter_opts = IterOpts {
            tol: opts.tol,
            max_iters: opts.max_iters,
            record_history: false,
        };
        let spd = p.op.is_spd_like();

        // the operator applies natively (stencil stays matrix-free);
        // Jacobi needs the diagonal either way.
        let (result, method): (_, &'static str) = match &p.op {
            Operator::Stencil(s) => {
                let m = Jacobi::from_diag(&s.center);
                let _hold = mem.hold((s.n() * 8) as u64); // diag inverse
                // honor explicit method overrides (the stencil is SPD,
                // so Jacobi is a valid preconditioner for all of them)
                match opts.method {
                    Method::Minres => (
                        krylov::minres(&SerialOp(*s), p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                        "minres+jacobi",
                    ),
                    Method::Gmres => (
                        krylov::gmres(&SerialOp(*s), p.b, &m, 50, &NullComm, &iter_opts, Some(&mem)),
                        "gmres50+jacobi",
                    ),
                    Method::Bicgstab => (
                        krylov::bicgstab(&SerialOp(*s), p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                        "bicgstab+jacobi",
                    ),
                    _ => (
                        krylov::cg(&SerialOp(*s), p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                        "cg+jacobi",
                    ),
                }
            }
            Operator::Csr(a) => {
                let _hold = mem.hold(crate::metrics::mem::csr_bytes(a.nrows, a.nnz()));
                // roofline-tuned operator: the cost model picks CSR or
                // SELL-C-σ per matrix, recording the choice in the
                // process-global registry (`spmv.format.*`); either
                // kernel applies each vector in CSR's per-row FP order,
                // so solver iterates are unchanged
                let op = crate::sparse::TunedOp::new(a, Some(crate::metrics::Registry::global()));
                let _fmt_hold = mem.hold(op.extra_bytes());
                if opts.method == Method::Minres && !spd {
                    // symmetric-indefinite: MINRES needs an SPD M, which
                    // Jacobi cannot guarantee (diagonals may be zero or
                    // negative) — run unpreconditioned, and do NOT build
                    // the Jacobi below (its zero-diagonal check would
                    // reject exactly the saddle-point systems MINRES is
                    // for)
                    (
                        krylov::minres(&op, p.b, &Identity, &NullComm, &iter_opts, Some(&mem)),
                        "minres",
                    )
                } else {
                    let m = Jacobi::new(a)?;
                    match opts.method {
                        Method::Gmres => (
                            krylov::gmres(&op, p.b, &m, 50, &NullComm, &iter_opts, Some(&mem)),
                            "gmres50+jacobi",
                        ),
                        Method::Bicgstab => (
                            krylov::bicgstab(&op, p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                            "bicgstab+jacobi",
                        ),
                        // SPD-looking: Jacobi is a valid MINRES precond
                        Method::Minres => (
                            krylov::minres(&op, p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                            "minres+jacobi",
                        ),
                        _ if spd => (
                            krylov::cg(&op, p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                            "cg+jacobi",
                        ),
                        _ => (
                            krylov::bicgstab(&op, p.b, &m, &NullComm, &iter_opts, Some(&mem)),
                            "bicgstab+jacobi",
                        ),
                    }
                }
            }
        };
        // failing to reach tol is an ERROR at the backend boundary: the
        // dispatcher can then fall through to another backend, and a
        // caller never mistakes a stalled Krylov iterate for a solution.
        // Breakdown (non-SPD operator, degenerate recurrence) is
        // reported as its own error kind so callers can distinguish it
        // from an exhausted iteration budget.
        if !result.converged {
            if result.breakdown {
                return Err(crate::error::Error::Breakdown {
                    at: result.iters,
                    reason: format!(
                        "krylov breakdown after {} iterations (operator not SPD, or degenerate recurrence); residual {:.3e}",
                        result.iters, result.residual
                    ),
                });
            }
            return Err(crate::error::Error::NotConverged {
                iters: result.iters,
                residual: result.residual,
                tol: opts.tol,
            });
        }
        Ok(SolveOutcome {
            x: result.x,
            backend: self.name(),
            method,
            iters: result.iters,
            residual: result.residual,
            peak_bytes: mem.peak(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn stencil_cg_is_matrix_free() {
        let sys = poisson2d(16, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(256);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Stencil(&sys.coeffs),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "cg+jacobi");
        assert!(out.iters > 0);
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
        // matrix-free: working set ~ 6 n vectors, NOT nnz-scaled CSR
        assert!(out.peak_bytes < (10 * 256 * 8) as u64);
    }

    #[test]
    fn nonsymmetric_routes_to_bicgstab() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 4);
        let b = rng.normal_vec(80);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "bicgstab+jacobi");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn gmres_on_request() {
        let mut rng = Prng::new(2);
        let a = random_nonsymmetric(&mut rng, 50, 4);
        let b = rng.normal_vec(50);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    method: Method::Gmres,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(out.method, "gmres50+jacobi");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn minres_on_request_handles_symmetric_indefinite() {
        use crate::sparse::Coo;
        // Poisson - sigma I with sigma inside the spectrum: symmetric
        // indefinite — CG is refused/broken, MINRES converges.
        let g = 10;
        let n = g * g;
        let sys = poisson2d(g, None);
        let sigma = 30.0;
        let mut coo = Coo::with_capacity(n, n, sys.matrix.nnz());
        for r in 0..n {
            let (cols, vals) = sys.matrix.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, if *c == r { v - sigma } else { *v });
            }
        }
        let a = coo.to_csr();
        let mut rng = Prng::new(4);
        let b = rng.normal_vec(n);
        let out = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    method: Method::Minres,
                    tol: 1e-9,
                    ..Default::default()
                },
            )
            .unwrap();
        // the shifted matrix keeps a positive diagonal (Poisson's 1/h^2
        // scaling dwarfs the shift), so it LOOKS SPD and Jacobi — a
        // valid SPD preconditioner here — rides along
        assert_eq!(out.method, "minres+jacobi");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-6);
        // and the method override is refused on a nonsymmetric operator
        let mut rng = Prng::new(5);
        let ns = random_nonsymmetric(&mut rng, 20, 3);
        let p = Problem {
            op: Operator::Csr(&ns),
            b: &b[..20],
        };
        assert!(NativeIter
            .supports(
                &p,
                &SolveOpts {
                    method: Method::Minres,
                    ..Default::default()
                }
            )
            .is_err());
    }

    #[test]
    fn breakdown_surfaces_as_breakdown_error() {
        use crate::sparse::Coo;
        // looks SPD (symmetric, positive diagonal) but is indefinite:
        // auto-method picks CG, which breaks down on pAp < 0.  The
        // backend must surface Error::Breakdown — the signal the
        // dispatcher's runtime-fallback path keys on — not NotConverged.
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let b = vec![1.0, -1.0];
        let err = NativeIter
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    method: Method::Cg,
                    ..Default::default()
                },
            )
            .unwrap_err();
        assert!(
            matches!(err, crate::error::Error::Breakdown { .. }),
            "expected Breakdown, got: {err}"
        );
    }

    #[test]
    fn cg_on_nonsymmetric_is_refused() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 20, 3);
        let b = vec![1.0; 20];
        let p = Problem {
            op: Operator::Csr(&a),
            b: &b,
        };
        assert!(NativeIter
            .supports(
                &p,
                &SolveOpts {
                    method: Method::Cg,
                    ..Default::default()
                }
            )
            .is_err());
    }
}
