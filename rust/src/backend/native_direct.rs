//! `native-direct`: the SciPy-SuperLU analog.  Envelope Cholesky (+RCM)
//! for SPD-looking systems with LU fallback; Gilbert–Peierls LU for
//! general square systems.  Machine-precision solutions; fill measured
//! and charged against the host memory budget.

use super::{Backend, Device, Method, Problem, SolveOpts, SolveOutcome};
use crate::direct::{EnvelopeCholesky, SparseLu};
use crate::error::{Error, Result};
use crate::factor_cache::FactorCache;

pub struct NativeDirect;

impl Backend for NativeDirect {
    fn name(&self) -> &'static str {
        "native-direct"
    }

    fn device(&self) -> Device {
        Device::Cpu
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        let n = p.op.nrows();
        if n != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        match opts.method {
            Method::Auto | Method::Cholesky | Method::Lu => {}
            m => return Err(format!("method {m:?} is not a direct method")),
        }
        // cheap fill screen: envelope of the (possibly stencil) matrix
        // after RCM is bounded by bandwidth * n; refuse when even the
        // optimistic estimate blows the budget.
        let optimistic = (p.op.nnz() as u64) * 8;
        if optimistic > opts.host_mem_budget {
            return Err(format!(
                "input alone exceeds host budget ({optimistic} B)"
            ));
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let a = p.op.to_csr();
        let spd = p.op.is_spd_like();
        if opts.method == Method::Lu {
            // explicit-LU override keeps the uncached seed path: the
            // cache's family policy would pick Cholesky for SPD inputs
            let cap = (opts.host_mem_budget / 16) as usize;
            let f = SparseLu::factor_with_cap(&a, cap)?;
            let x = f.solve(p.b)?;
            let residual = residual_of(&a, &x, p.b);
            return Ok(SolveOutcome {
                x,
                backend: self.name(),
                method: "lu",
                iters: 0,
                residual,
                peak_bytes: f.bytes(),
            });
        }
        if spd {
            // pre-factorization fill check against the budget, kept
            // BEFORE any factorization so OOM semantics never depend on
            // cache warmth.  A verified cached symbolic analysis serves
            // the predicted fill without recomputing RCM; only a
            // symbolic miss pays the cold ordering pass.
            let fill = FactorCache::global()
                .chol_predicted_fill_bytes(&a)
                .unwrap_or_else(|| {
                    let perm = crate::direct::ordering::rcm(&a);
                    let pa = a.permute_sym(&perm);
                    EnvelopeCholesky::predicted_fill(&pa) as u64 * 8
                });
            if fill > opts.host_mem_budget {
                return Err(Error::OutOfMemory {
                    needed_bytes: fill,
                    budget_bytes: opts.host_mem_budget,
                });
            }
            if opts.method == Method::Cholesky {
                // explicit Cholesky must surface Breakdown (the seed's
                // contract) instead of the cache's silent LU fallback
                let f = EnvelopeCholesky::factor_rcm(&a)?;
                let x = f.solve(p.b);
                let residual = residual_of(&a, &x, p.b);
                return Ok(SolveOutcome {
                    x,
                    backend: self.name(),
                    method: "cholesky+rcm",
                    iters: 0,
                    residual,
                    peak_bytes: f.bytes(),
                });
            }
        }
        // factorize-once-per-(pattern, values) through the shared cache;
        // repeated solves (training loops, the batch service, adjoints)
        // reuse the numeric factor, same-pattern solves reuse the
        // symbolic analysis.  The cache re-applies the budget on hits.
        let f = FactorCache::global().factor(&a, opts.host_mem_budget, None)?;
        let x = f.solve(p.b)?;
        let residual = residual_of(&a, &x, p.b);
        Ok(SolveOutcome {
            x,
            backend: self.name(),
            method: f.method(),
            iters: 0,
            residual,
            peak_bytes: f.bytes(),
        })
    }
}

pub(crate) fn residual_of(a: &crate::sparse::Csr, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x);
    let mut r2 = 0.0;
    for i in 0..b.len() {
        let d = b[i] - ax[i];
        r2 += d * d;
    }
    r2.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Operator;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn spd_uses_cholesky() {
        let sys = poisson2d(12, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(144);
        let out = NativeDirect
            .solve(
                &Problem {
                    op: Operator::Csr(&sys.matrix),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "cholesky+rcm");
        assert!(out.residual < 1e-9);
        assert!(out.peak_bytes > 0);
    }

    #[test]
    fn general_uses_lu() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 60, 4);
        let b = rng.normal_vec(60);
        let out = NativeDirect
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts::default(),
            )
            .unwrap();
        assert_eq!(out.method, "lu");
        assert!(out.residual < 1e-9);
    }

    #[test]
    fn budget_produces_oom() {
        let sys = poisson2d(32, None);
        let b = vec![1.0; 1024];
        let out = NativeDirect.solve(
            &Problem {
                op: Operator::Csr(&sys.matrix),
                b: &b,
            },
            &SolveOpts {
                host_mem_budget: 10_000, // absurdly small
                ..Default::default()
            },
        );
        assert!(matches!(out, Err(Error::OutOfMemory { .. })));
    }

    #[test]
    fn stencil_operator_accepted() {
        let sys = poisson2d(10, None);
        let b = vec![1.0; 100];
        let p = Problem {
            op: Operator::Stencil(&sys.coeffs),
            b: &b,
        };
        assert!(NativeDirect.supports(&p, &SolveOpts::default()).is_ok());
        let out = NativeDirect.solve(&p, &SolveOpts::default()).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-9);
    }
}
