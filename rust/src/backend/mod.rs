//! Unified backend abstraction (paper §3.1).
//!
//! Five interchangeable backends behind one interface, selected by
//! device, problem size, and matrix properties:
//!
//! | paper backend       | rsla backend    | substrate |
//! |---------------------|-----------------|-----------|
//! | scipy (SuperLU/UMF) | `native-direct` | envelope Cholesky + RCM, Gilbert–Peierls LU |
//! | eigen (CG/BiCGStab) | `native-iter`   | rust CG / BiCGStab, Jacobi default |
//! | cudss (LU/Chol)     | `xla-direct`    | AOT dense Cholesky artifact via PJRT |
//! | pytorch-native CUDA | `xla-cg`        | AOT *fused* Jacobi-PCG artifact (Pallas SpMV inside `lax.while_loop`) |
//! | cupy (cupyx)        | `xla-hybrid`    | rust Krylov loop calling the AOT SpMV artifact per iteration |
//!
//! Adding a backend = implementing [`Backend`] and registering it with
//! the [`dispatch::Dispatcher`] (the paper's `select_backend` hook).

pub mod dispatch;
pub mod native_direct;
pub mod native_iter;
pub mod xla_cg;
pub mod xla_direct;
pub mod xla_hybrid;

pub use dispatch::Dispatcher;

use crate::error::Result;
use crate::sparse::poisson::StencilCoeffs;
use crate::sparse::Csr;

/// Where the user asked the solve to run (the paper dispatches on the
/// input tensor's device; we carry it explicitly).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Device {
    Cpu,
    /// The simulated accelerator: AOT XLA artifacts through PJRT, with a
    /// device-memory budget enforced by the backends.
    Accel,
}

/// Solver method override (paper: `method=` keyword).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Method {
    Auto,
    Cholesky,
    Lu,
    Cg,
    Bicgstab,
    Gmres,
    /// Symmetric (possibly indefinite) systems — served by the generic
    /// MINRES kernel on the native-iter backend.
    Minres,
}

/// Per-solve options (paper: keyword arguments on `.solve`).
#[derive(Clone, Debug)]
pub struct SolveOpts {
    pub device: Device,
    /// Force a specific backend by name (None = auto-dispatch).
    pub backend: Option<String>,
    pub method: Method,
    pub tol: f64,
    pub max_iters: usize,
    /// Simulated accelerator memory budget in bytes (the H200-analog
    /// capacity; Table 3's OOM rows are violations of this).
    pub accel_mem_budget: u64,
    /// Host memory budget for direct-solver fill.
    pub host_mem_budget: u64,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts {
            device: Device::Cpu,
            backend: None,
            method: Method::Auto,
            tol: 1e-10,
            max_iters: 100_000,
            accel_mem_budget: 512 << 20, // 512 MiB "device"
            host_mem_budget: 8 << 30,
        }
    }
}

impl SolveOpts {
    pub fn on_accel() -> Self {
        SolveOpts {
            device: Device::Accel,
            ..Default::default()
        }
    }
}

/// The operator handed to backends.  Stencil form flows through so the
/// accelerator backends can pick the fused grid artifacts.
pub enum Operator<'a> {
    Csr(&'a Csr),
    Stencil(&'a StencilCoeffs),
}

impl<'a> Operator<'a> {
    pub fn nrows(&self) -> usize {
        match self {
            Operator::Csr(a) => a.nrows,
            Operator::Stencil(s) => s.n(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Operator::Csr(a) => a.nnz(),
            Operator::Stencil(s) => 5 * s.n(),
        }
    }

    /// Materialize CSR (cheap for Csr, assembly for Stencil).
    pub fn to_csr(&self) -> Csr {
        match self {
            Operator::Csr(a) => (*a).clone(),
            Operator::Stencil(s) => s.to_csr(),
        }
    }

    pub fn is_spd_like(&self) -> bool {
        match self {
            Operator::Csr(a) => a.looks_spd(),
            // variable-coefficient diffusion stencils are SPD by
            // construction when center > 0
            Operator::Stencil(s) => s.center.iter().all(|&c| c > 0.0),
        }
    }
}

/// A solve problem: operator + right-hand side.
pub struct Problem<'a> {
    pub op: Operator<'a>,
    pub b: &'a [f64],
}

/// What a backend reports back (feeds the coordinator metrics and the
/// bench tables).
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub x: Vec<f64>,
    pub backend: &'static str,
    pub method: &'static str,
    /// 0 for direct solves.
    pub iters: usize,
    pub residual: f64,
    /// Measured peak working-set bytes (factor fill or Krylov vectors).
    pub peak_bytes: u64,
}

/// A solver backend.  `supports` is the registration predicate the
/// dispatcher consults (paper: "registering its applicability conditions
/// through select_backend").
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;
    fn device(&self) -> Device;
    /// Err(reason) when this backend cannot take the problem.
    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String>;
    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome>;
}
