//! `xla-direct`: the cuDSS analog — an accelerator-resident direct
//! solver behind the PJRT runtime.
//!
//! Executes the AOT `dense_solve_n{N}` artifact (hand-written Cholesky +
//! triangular solves in primitive HLO; see python/compile/model.py).
//! Problems are padded to the next artifact size with an identity
//! diagonal block, mirroring how cuDSS plans are shape-specialized.
//! The n^2 dense footprint is charged against the accelerator budget —
//! at scale this backend OOMs first, exactly like the paper's cuDSS
//! column in Table 3.

use super::{Backend, Device, Method, Problem, SolveOpts, SolveOutcome};
use crate::error::{Error, Result};
use crate::runtime::{Arg, RuntimeHandle};

/// Artifact sizes baked by aot.py (must match model.DENSE_SIZES).
pub const DENSE_SIZES: [usize; 5] = [64, 256, 1024, 2048, 4096];

pub struct XlaDirect {
    registry: RuntimeHandle,
}

impl XlaDirect {
    pub fn new(registry: RuntimeHandle) -> Self {
        XlaDirect { registry }
    }

    fn pick_size(n: usize) -> Option<usize> {
        DENSE_SIZES.iter().copied().find(|&s| s >= n)
    }
}

impl Backend for XlaDirect {
    fn name(&self) -> &'static str {
        "xla-direct"
    }

    fn device(&self) -> Device {
        Device::Accel
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        let n = p.op.nrows();
        if n != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        if matches!(
            opts.method,
            Method::Cg | Method::Bicgstab | Method::Gmres | Method::Minres
        ) {
            return Err("iterative method requested".into());
        }
        if !p.op.is_spd_like() {
            return Err("dense Cholesky artifact needs an SPD operator".into());
        }
        let padded = Self::pick_size(n).ok_or_else(|| {
            format!("n={n} exceeds largest dense artifact ({})", DENSE_SIZES[DENSE_SIZES.len() - 1])
        })?;
        let bytes = (padded * padded * 8) as u64;
        if bytes > opts.accel_mem_budget {
            return Err(format!(
                "dense n^2 footprint {bytes} B exceeds accel budget {}",
                opts.accel_mem_budget
            ));
        }
        if !self.registry.has(&format!("dense_solve_n{padded}")) {
            return Err(format!("artifact dense_solve_n{padded} missing"));
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let n = p.op.nrows();
        let padded = Self::pick_size(n).ok_or(Error::BackendUnavailable {
            backend: "xla-direct".into(),
            reason: "too large".into(),
        })?;
        let bytes = (padded * padded * 8) as u64;
        if bytes > opts.accel_mem_budget {
            return Err(Error::OutOfMemory {
                needed_bytes: bytes,
                budget_bytes: opts.accel_mem_budget,
            });
        }
        let a = p.op.to_csr();
        // densify + identity padding
        let mut dense = vec![0f64; padded * padded];
        for r in 0..n {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                dense[r * padded + c] += v;
            }
        }
        for r in n..padded {
            dense[r * padded + r] = 1.0;
        }
        let mut rhs = vec![0f64; padded];
        rhs[..n].copy_from_slice(p.b);

        let out = self.registry.run(
            &format!("dense_solve_n{padded}"),
            &[
                Arg::tensor(dense, vec![padded, padded]),
                Arg::vec(rhs),
            ],
        )?;
        let x_full = out[0].as_f64();
        let x = x_full[..n].to_vec();
        let residual = super::native_direct::residual_of(&a, &x, p.b);
        Ok(SolveOutcome {
            x,
            backend: self.name(),
            method: "dense-cholesky(pjrt)",
            iters: 0,
            residual,
            peak_bytes: bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Operator;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    /// Skips (returns None) when the AOT artifacts / PJRT bindings are
    /// unavailable in this build.
    fn backend() -> Option<XlaDirect> {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Some(XlaDirect::new(h)),
            Err(e) => {
                eprintln!("skipping xla-direct test: {e}");
                None
            }
        }
    }

    #[test]
    fn solves_small_poisson_via_pjrt() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(7, None); // n = 49, pads to 64
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(49);
        let out = be
            .solve(
                &Problem {
                    op: Operator::Csr(&sys.matrix),
                    b: &b,
                },
                &SolveOpts::on_accel(),
            )
            .unwrap();
        assert_eq!(out.backend, "xla-direct");
        assert!(out.residual < 1e-8, "residual {}", out.residual);
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);
    }

    #[test]
    fn oom_beyond_budget() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(40, None); // n = 1600 -> pads to 2048 -> 33 MB
        let b = vec![1.0; 1600];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        let opts = SolveOpts {
            device: Device::Accel,
            accel_mem_budget: 1 << 20, // 1 MiB device
            ..Default::default()
        };
        assert!(be.supports(&p, &opts).is_err());
    }

    #[test]
    fn too_large_unsupported() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(96, None); // n = 9216 > largest artifact (4096)
        let b = vec![1.0; 96 * 96];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        assert!(be.supports(&p, &SolveOpts::on_accel()).is_err());
    }

    #[test]
    fn n4096_supported_within_default_budget() {
        // the cuDSS-analog mid-range: a 4096^2 f64 dense footprint is
        // 128 MiB — inside the default 512 MiB device budget, OOM under
        // a 64 MiB one (Table 3's regime boundary).
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(64, None);
        let b = vec![1.0; 4096];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        assert!(be.supports(&p, &SolveOpts::on_accel()).is_ok());
        let tight = SolveOpts {
            device: Device::Accel,
            accel_mem_budget: 64 << 20,
            ..Default::default()
        };
        assert!(be.supports(&p, &tight).is_err());
    }
}
