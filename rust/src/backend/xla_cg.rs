//! `xla-cg`: the pytorch-native-CUDA-CG analog — the *fused* iterative
//! backend.  One PJRT execution runs the whole Jacobi-PCG loop
//! (`lax.while_loop` around the Pallas SpMV kernel), so there is no
//! per-iteration host round trip; this is the backend that wins at
//! large DOF in Table 3.
//!
//! Stencil problems hit `cg_poisson_g{G}` directly; general SPD CSR
//! problems are converted to ELL and padded up to the next
//! `cg_ell_n{N}_s8` artifact (identity rows for padding).

use std::sync::Arc;

use super::{Backend, Device, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::error::{Error, Result};
use crate::runtime::{Arg, RuntimeHandle};
use crate::sparse::graphs::to_ell;

/// Grid sizes baked by aot.py (model.GRID_SIZES).
pub const GRID_SIZES: [usize; 5] = [32, 64, 128, 256, 512];
/// ELL sizes baked by aot.py (model.ELL_SIZES), all with 8 slots.
pub const ELL_SIZES: [usize; 3] = [4096, 16384, 65536];
pub const ELL_SLOTS: usize = 8;

pub struct XlaCg {
    registry: RuntimeHandle,
}

impl XlaCg {
    pub fn new(registry: RuntimeHandle) -> Self {
        XlaCg { registry }
    }

    fn ell_size(n: usize) -> Option<usize> {
        ELL_SIZES.iter().copied().find(|&s| s >= n)
    }

    /// Iterative working set on the simulated device: matrix (ELL or
    /// stencil planes) + 6 Krylov vectors.
    fn footprint(p: &Problem) -> u64 {
        let n = p.op.nrows();
        let mat = match &p.op {
            Operator::Stencil(_) => 5 * n * 8,
            Operator::Csr(_) => {
                let padded = Self::ell_size(n).unwrap_or(n);
                padded * ELL_SLOTS * 12
            }
        };
        (mat + 6 * n * 8) as u64
    }
}

impl Backend for XlaCg {
    fn name(&self) -> &'static str {
        "xla-cg"
    }

    fn device(&self) -> Device {
        Device::Accel
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        let n = p.op.nrows();
        if n != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        if !matches!(opts.method, Method::Auto | Method::Cg) {
            return Err("method not served by the fused CG artifact".into());
        }
        if !p.op.is_spd_like() {
            return Err("fused CG artifact needs an SPD operator".into());
        }
        match &p.op {
            Operator::Stencil(s) => {
                if !GRID_SIZES.contains(&s.g) {
                    return Err(format!("no cg_poisson artifact for g={}", s.g));
                }
                if !self.registry.has(&format!("cg_poisson_g{}", s.g)) {
                    return Err("artifact missing".into());
                }
            }
            Operator::Csr(a) => {
                let padded = Self::ell_size(n)
                    .ok_or_else(|| format!("n={n} exceeds largest ELL artifact"))?;
                let max_row = (0..a.nrows).map(|r| a.row(r).0.len()).max().unwrap_or(0);
                if max_row > ELL_SLOTS {
                    return Err(format!("row with {max_row} nnz exceeds {ELL_SLOTS} ELL slots"));
                }
                if !self.registry.has(&format!("cg_ell_n{padded}_s{ELL_SLOTS}")) {
                    return Err("artifact missing".into());
                }
            }
        }
        let fp = Self::footprint(p);
        if fp > opts.accel_mem_budget {
            return Err(format!(
                "working set {fp} B exceeds accel budget {}",
                opts.accel_mem_budget
            ));
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let n = p.op.nrows();
        let fp = Self::footprint(p);
        if fp > opts.accel_mem_budget {
            return Err(Error::OutOfMemory {
                needed_bytes: fp,
                budget_bytes: opts.accel_mem_budget,
            });
        }
        let max_iters = opts.max_iters.min(i32::MAX as usize) as i32;
        match &p.op {
            Operator::Stencil(s) => {
                let g = s.g;
                let out = self.registry.run(
                    &format!("cg_poisson_g{g}"),
                    &[
                        Arg::tensor(s.to_planes(), vec![5, g, g]),
                        Arg::tensor(p.b.to_vec(), vec![g, g]),
                        Arg::ScalarI32(max_iters),
                        Arg::ScalarF64(opts.tol),
                    ],
                )?;
                let x = out[0].as_f64().clone();
                let rr = out[1].scalar_f64();
                let iters = out[2].scalar_i32() as usize;
                Ok(SolveOutcome {
                    x,
                    backend: self.name(),
                    method: "fused-cg-stencil(pjrt)",
                    iters,
                    residual: rr.sqrt(),
                    peak_bytes: fp,
                })
            }
            Operator::Csr(a) => {
                let padded = Self::ell_size(n).ok_or_else(|| Error::BackendUnavailable {
                    backend: "xla-cg".into(),
                    reason: format!("no compiled ELL size covers n={n}"),
                })?;
                // pad with identity rows so the extra unknowns are inert
                let (mut cols, mut vals) = to_ell(a, ELL_SLOTS).ok_or_else(|| {
                    Error::BackendUnavailable {
                        backend: "xla-cg".into(),
                        reason: "ELL conversion failed".into(),
                    }
                })?;
                cols.resize(padded * ELL_SLOTS, 0);
                vals.resize(padded * ELL_SLOTS, 0.0);
                let mut diag = a.diag();
                diag.resize(padded, 1.0);
                for r in n..padded {
                    cols[r * ELL_SLOTS] = r as i32;
                    vals[r * ELL_SLOTS] = 1.0;
                }
                let mut rhs = p.b.to_vec();
                rhs.resize(padded, 0.0);
                let out = self.registry.run(
                    &format!("cg_ell_n{padded}_s{ELL_SLOTS}"),
                    &[
                        Arg::I32(Arc::new(cols), vec![padded, ELL_SLOTS]),
                        Arg::tensor(vals, vec![padded, ELL_SLOTS]),
                        Arg::vec(diag),
                        Arg::vec(rhs),
                        Arg::ScalarI32(max_iters),
                        Arg::ScalarF64(opts.tol),
                    ],
                )?;
                let x = out[0].as_f64()[..n].to_vec();
                let rr = out[1].scalar_f64();
                let iters = out[2].scalar_i32() as usize;
                Ok(SolveOutcome {
                    x,
                    backend: self.name(),
                    method: "fused-cg-ell(pjrt)",
                    iters,
                    residual: rr.sqrt(),
                    peak_bytes: fp,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::bounded_degree_laplacian;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    /// Skips (returns None) when the AOT artifacts / PJRT bindings are
    /// unavailable in this build.
    fn backend() -> Option<XlaCg> {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Some(XlaCg::new(h)),
            Err(e) => {
                eprintln!("skipping xla-cg test: {e}");
                None
            }
        }
    }

    #[test]
    fn stencil_fused_cg() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let g = 32;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let out = be
            .solve(
                &Problem {
                    op: Operator::Stencil(&sys.coeffs),
                    b: &b,
                },
                &SolveOpts {
                    tol: 1e-9,
                    ..SolveOpts::on_accel()
                },
            )
            .unwrap();
        assert_eq!(out.method, "fused-cg-stencil(pjrt)");
        assert!(out.iters > 10);
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-7);
    }

    #[test]
    fn general_csr_pads_to_ell_artifact() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let mut rng = Prng::new(1);
        let n = 3000; // pads to 4096
        let a = bounded_degree_laplacian(&mut rng, n, 7, 0.5);
        let b = rng.normal_vec(n);
        let out = be
            .solve(
                &Problem {
                    op: Operator::Csr(&a),
                    b: &b,
                },
                &SolveOpts {
                    tol: 1e-9,
                    ..SolveOpts::on_accel()
                },
            )
            .unwrap();
        assert_eq!(out.method, "fused-cg-ell(pjrt)");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-7);
    }

    #[test]
    fn unsupported_grid_size_refused() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(33, None); // g=33 has no artifact
        let b = vec![1.0; 33 * 33];
        let p = Problem {
            op: Operator::Stencil(&sys.coeffs),
            b: &b,
        };
        assert!(be.supports(&p, &SolveOpts::on_accel()).is_err());
    }

    #[test]
    fn dense_rows_refused() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let mut rng = Prng::new(2);
        let a = crate::sparse::graphs::random_spd(&mut rng, 64, 12, 1.0);
        let b = vec![1.0; 64];
        let p = Problem {
            op: Operator::Csr(&a),
            b: &b,
        };
        // rows have up to ~40 nnz > 8 slots
        assert!(be.supports(&p, &SolveOpts::on_accel()).is_err());
    }
}
