//! `xla-hybrid`: the CuPy analog — a host-driven Krylov loop whose SpMV
//! runs on the accelerator runtime, one PJRT execution per iteration.
//!
//! This models a library whose kernels live behind a per-call runtime
//! boundary: each iteration pays kernel-launch overhead (the reason the
//! paper's cuDSS/cupy lose to fused CG at small problem sizes), while
//! dot products and vector updates stay on the host.  Used by the
//! ablation bench to quantify the fused-vs-hybrid gap.

use std::sync::Arc;

use super::{Backend, Device, Method, Operator, Problem, SolveOpts, SolveOutcome};
use crate::error::{Error, Result};
use crate::runtime::{Arg, RuntimeHandle};
use crate::util::{dot, xpby_inplace};

pub struct XlaHybrid {
    registry: RuntimeHandle,
}

impl XlaHybrid {
    pub fn new(registry: RuntimeHandle) -> Self {
        XlaHybrid { registry }
    }
}

impl Backend for XlaHybrid {
    fn name(&self) -> &'static str {
        "xla-hybrid"
    }

    fn device(&self) -> Device {
        Device::Accel
    }

    fn supports(&self, p: &Problem, opts: &SolveOpts) -> std::result::Result<(), String> {
        if p.op.nrows() != p.b.len() {
            return Err("rhs length mismatch".into());
        }
        if !matches!(opts.method, Method::Auto | Method::Cg) {
            return Err("method not served by the hybrid CG loop".into());
        }
        if !p.op.is_spd_like() {
            return Err("hybrid CG needs an SPD operator".into());
        }
        match &p.op {
            Operator::Stencil(s) => {
                if !self.registry.has(&format!("stencil_spmv_g{}", s.g)) {
                    return Err(format!("no stencil_spmv artifact for g={}", s.g));
                }
            }
            Operator::Csr(_) => {
                return Err("hybrid backend serves stencil operators (use xla-cg for ELL)".into())
            }
        }
        Ok(())
    }

    fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let s = match &p.op {
            Operator::Stencil(s) => *s,
            Operator::Csr(_) => {
                return Err(Error::BackendUnavailable {
                    backend: "xla-hybrid".into(),
                    reason: "stencil-only".into(),
                })
            }
        };
        let g = s.g;
        let n = g * g;
        let planes = Arc::new(s.to_planes());
        let artifact = format!("stencil_spmv_g{g}");
        let spmv = |v: &[f64]| -> Result<Vec<f64>> {
            let out = self.registry.run(
                &artifact,
                &[
                    Arg::F64(planes.clone(), vec![5, g, g]),
                    Arg::tensor(v.to_vec(), vec![g, g]),
                ],
            )?;
            Ok(out[0].as_f64().clone())
        };

        // Jacobi-PCG with the device SpMV
        let inv_diag: Vec<f64> = s.center.iter().map(|c| 1.0 / c).collect();
        let mut x = vec![0f64; n];
        let mut r = p.b.to_vec();
        let mut z: Vec<f64> = r.iter().zip(&inv_diag).map(|(a, d)| a * d).collect();
        let mut pdir = z.clone();
        let mut rz = dot(&r, &z);
        let mut rr = dot(&r, &r);
        let tol2 = opts.tol * opts.tol;
        let mut iters = 0;
        while iters < opts.max_iters && rr > tol2 {
            let ap = spmv(&pdir)?;
            let alpha = rz / dot(&pdir, &ap);
            for i in 0..n {
                x[i] += alpha * pdir[i];
                r[i] -= alpha * ap[i];
            }
            for i in 0..n {
                z[i] = r[i] * inv_diag[i];
            }
            let rz_new = dot(&r, &z);
            let beta = rz_new / rz;
            xpby_inplace(&z, beta, &mut pdir);
            rz = rz_new;
            rr = dot(&r, &r);
            iters += 1;
        }
        Ok(SolveOutcome {
            x,
            backend: self.name(),
            method: "hybrid-cg(pjrt-spmv/iter)",
            iters,
            residual: rr.sqrt(),
            peak_bytes: ((5 * n + 6 * n) * 8) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    /// Skips (returns None) when the AOT artifacts / PJRT bindings are
    /// unavailable in this build.
    fn backend() -> Option<XlaHybrid> {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Some(XlaHybrid::new(h)),
            Err(e) => {
                eprintln!("skipping xla-hybrid test: {e}");
                None
            }
        }
    }

    #[test]
    fn hybrid_cg_solves_poisson() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let g = 32;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let out = be
            .solve(
                &Problem {
                    op: Operator::Stencil(&sys.coeffs),
                    b: &b,
                },
                &SolveOpts {
                    tol: 1e-9,
                    ..SolveOpts::on_accel()
                },
            )
            .unwrap();
        assert!(out.iters > 10);
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-7);
    }

    #[test]
    fn hybrid_matches_fused_solution() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let g = 32;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let opts = SolveOpts {
            tol: 1e-10,
            ..SolveOpts::on_accel()
        };
        let p = Problem {
            op: Operator::Stencil(&sys.coeffs),
            b: &b,
        };
        let hybrid = be.solve(&p, &opts).unwrap();
        let fused = super::super::xla_cg::XlaCg::new(RuntimeHandle::spawn_default().unwrap())
            .solve(&p, &opts)
            .unwrap();
        assert!(util::max_abs_diff(&hybrid.x, &fused.x) < 1e-6);
    }

    #[test]
    fn csr_refused() {
        let be = match backend() {
            Some(b) => b,
            None => return,
        };
        let sys = poisson2d(8, None);
        let b = vec![1.0; 64];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        assert!(be.supports(&p, &SolveOpts::on_accel()).is_err());
    }
}
