//! Auto-dispatch: the paper's `select_backend` policy (§3.1).
//!
//! Priority rules:
//! 1. match the requested device;
//! 2. Accel: prefer `xla-direct` below the direct crossover (and within
//!    the device budget), else `xla-cg` (fused), else `xla-hybrid`;
//! 3. Cpu: prefer `native-direct` below the fill budget, else
//!    `native-iter`;
//! 4. explicit `backend=` / `method=` overrides skip the policy;
//! 5. a backend failing at runtime (OOM, breakdown) falls through to the
//!    next candidate, and the decision is recorded in the metrics
//!    registry.
//!
//! The dispatcher is consumed two ways: inline (a `SparseTensor` or
//! CLI call solves directly), and per-worker — every
//! [`crate::engine`] worker holds an `Arc<Dispatcher>` handle and
//! falls back to this chain whenever its shard-local direct path
//! declines a job (explicit backend/method overrides, singular or
//! over-budget factorizations, Accel devices).

use std::sync::Arc;

use super::{Backend, Device, Method, Problem, SolveOpts, SolveOutcome};
use crate::adjoint::{SolveFn, Transpose};
use crate::error::{Error, Result};
use crate::factor_cache::FactorCache;
use crate::metrics;
use crate::runtime::RuntimeHandle;
use crate::sparse::Pattern;

/// Paper's "direct solvers are often fastest below ~1e5 DOF": our
/// scaled-down crossover for preferring a direct backend.
pub const DIRECT_CROSSOVER_N: usize = 20_000;

pub struct Dispatcher {
    backends: Vec<Box<dyn Backend>>,
    pub metrics: Arc<metrics::Registry>,
}

impl Dispatcher {
    /// Full five-backend stack.  `registry` may be shared with other
    /// components; pass `None` to build a CPU-only dispatcher (no
    /// artifacts needed — used by unit tests and pure-native runs).
    pub fn new(registry: Option<RuntimeHandle>) -> Self {
        let mut backends: Vec<Box<dyn Backend>> = vec![
            Box::new(super::native_direct::NativeDirect),
            Box::new(super::native_iter::NativeIter),
        ];
        if let Some(reg) = registry {
            backends.push(Box::new(super::xla_direct::XlaDirect::new(reg.clone())));
            backends.push(Box::new(super::xla_cg::XlaCg::new(reg.clone())));
            backends.push(Box::new(super::xla_hybrid::XlaHybrid::new(reg)));
        }
        Dispatcher {
            backends,
            metrics: Arc::new(metrics::Registry::new()),
        }
    }

    /// The "just give me everything available" constructor: wires the
    /// PJRT runtime when `artifacts/` exists (full five-backend stack),
    /// and degrades to the two native backends otherwise.  Examples and
    /// integration tests use this so they run with or without
    /// `make artifacts`.
    pub fn default_full() -> Arc<Self> {
        match RuntimeHandle::spawn_default() {
            Ok(h) => Arc::new(Dispatcher::new(Some(h))),
            Err(e) => {
                log::warn!("PJRT runtime unavailable ({e}); native backends only");
                Arc::new(Dispatcher::new(None))
            }
        }
    }

    /// Register an additional backend (the paper's extension point for
    /// PETSc/Trilinos/hypre/learned preconditioners).
    pub fn register(&mut self, b: Box<dyn Backend>) {
        self.backends.push(b);
    }

    pub fn backend_names(&self) -> Vec<&'static str> {
        self.backends.iter().map(|b| b.name()).collect()
    }

    /// Ordered candidate list for a problem under the policy rules.
    fn candidates(&self, p: &Problem, opts: &SolveOpts) -> Vec<&dyn Backend> {
        if let Some(name) = &opts.backend {
            return self
                .backends
                .iter()
                .filter(|b| b.name() == name)
                .map(|b| b.as_ref())
                .collect();
        }
        let n = p.op.nrows();
        let prefer_direct = n <= DIRECT_CROSSOVER_N;
        // `native-direct` closes every chain: when the PJRT backends
        // refuse (missing artifacts, size, SPD-ness) AND `native-iter`
        // breaks down (e.g. CG on a small non-SPD system), the solve
        // must still reach the one backend that can always factor.
        let order: Vec<&'static str> = match (opts.device, prefer_direct) {
            (Device::Accel, true) => vec![
                "xla-direct",
                "xla-cg",
                "xla-hybrid",
                "native-iter",
                "native-direct",
            ],
            (Device::Accel, false) => vec![
                "xla-cg",
                "xla-hybrid",
                "xla-direct",
                "native-iter",
                "native-direct",
            ],
            (Device::Cpu, true) => vec!["native-direct", "native-iter"],
            (Device::Cpu, false) => vec!["native-iter", "native-direct"],
        };
        order
            .iter()
            .filter_map(|name| {
                self.backends
                    .iter()
                    .find(|b| b.name() == *name)
                    .map(|b| b.as_ref())
            })
            .collect()
    }

    /// Resolve the backend that WOULD serve the problem (for tests /
    /// the `rsla explain` CLI).
    pub fn select(&self, p: &Problem, opts: &SolveOpts) -> Option<&'static str> {
        self.candidates(p, opts)
            .into_iter()
            .find(|b| b.supports(p, opts).is_ok())
            .map(|b| b.name())
    }

    /// Solve with policy + fallback.
    pub fn solve(&self, p: &Problem, opts: &SolveOpts) -> Result<SolveOutcome> {
        let mut last_err: Option<Error> = None;
        for b in self.candidates(p, opts) {
            match b.supports(p, opts) {
                Ok(()) => {}
                Err(reason) => {
                    log::debug!("backend {} refused: {reason}", b.name());
                    self.metrics
                        .incr_labeled(metrics::names::DISPATCH_REFUSED, b.name(), 1);
                    // keep the refusal reason: if no candidate accepts —
                    // in particular when the user forced `backend=` —
                    // the caller sees WHY (e.g. a memory-budget OOM).
                    last_err = Some(Error::BackendUnavailable {
                        backend: b.name().into(),
                        reason,
                    });
                    continue;
                }
            }
            match b.solve(p, opts) {
                Ok(out) => {
                    self.metrics
                        .incr_labeled(metrics::names::DISPATCH_SOLVED, b.name(), 1);
                    return Ok(out);
                }
                Err(e) => {
                    // runtime fallback (e.g. OOM mid-solve, breakdown)
                    self.metrics
                        .incr_labeled(metrics::names::DISPATCH_FAILED, b.name(), 1);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::BackendUnavailable {
            backend: "auto".into(),
            reason: "no backend supports this problem".into(),
        }))
    }

    /// True when `solver_fn` may serve the request straight from the
    /// pattern-keyed factor cache: fully-auto policy (explicit backend
    /// or method overrides go through dispatch so their seed semantics
    /// — e.g. forced LU on an SPD matrix, Cholesky breakdown surfacing
    /// — are preserved), CPU device, and a problem small enough that
    /// the policy prefers a direct solver anyway.
    fn cache_eligible(opts: &SolveOpts, n: usize) -> bool {
        opts.backend.is_none()
            && opts.method == Method::Auto
            && opts.device == Device::Cpu
            && n <= DIRECT_CROSSOVER_N
    }

    /// Adapt the dispatcher into the adjoint framework's black-box
    /// solver hook.  `self` is moved behind an Arc so the closure can be
    /// shared with tape nodes.
    ///
    /// Solves are served from the process-wide [`FactorCache`] whenever
    /// the dispatch policy would pick a direct backend: ONE numeric
    /// factorization per (pattern, values) pair serves the forward solve
    /// AND every `Transpose::Yes` adjoint solve (paper §3.2.3) — the
    /// seed's per-backward LU rebuild and per-call `is_symmetric` scan
    /// are gone.  Cache hit/miss/eviction counters land in
    /// `self.metrics` under `factor_cache.*`.
    pub fn solver_fn(self: &Arc<Self>, opts: SolveOpts) -> SolveFn {
        let this = self.clone();
        Arc::new(move |pattern: &Pattern, vals: &[f64], rhs: &[f64], transpose: Transpose| {
            let a = pattern.with_vals(vals.to_vec());
            let mut cache_decline: Option<Error> = None;
            if Self::cache_eligible(&opts, a.nrows) {
                match FactorCache::global().factor(&a, opts.host_mem_budget, Some(&this.metrics))
                {
                    Ok(f) => {
                        return match transpose {
                            Transpose::No => f.solve(rhs),
                            Transpose::Yes => f.solve_t(rhs),
                        };
                    }
                    // singular / over-budget: forward solves fall
                    // through to the dispatcher's backend chain below;
                    // the error is kept so the adjoint path doesn't
                    // repeat the identical failed factorization
                    Err(e) => {
                        log::debug!("factor cache declined ({e}); dispatching");
                        cache_decline = Some(e);
                    }
                }
            }
            // symmetry gates only the transpose path, so don't pay the
            // O(nnz) probe/scan on forward calls at all; the cache
            // probe (a PatternKey hash) is only worth it where the
            // cache could actually hold the matrix
            let transpose_nonsym = transpose == Transpose::Yes && {
                let symmetric = if a.nrows <= DIRECT_CROSSOVER_N {
                    FactorCache::global().symmetry_of(&a)
                } else {
                    a.is_symmetric(1e-12)
                };
                !symmetric
            };
            if transpose_nonsym {
                // nonsymmetric adjoint needs a direct transpose solve;
                // a decline above would only repeat itself
                if let Some(e) = cache_decline {
                    return Err(e);
                }
                // within the direct crossover it is served (and
                // retained) by the cache UNDER THE CALLER'S BUDGET,
                // while oversized systems keep the seed's one-shot LU
                // so a single huge factor cannot flush the process-wide
                // cache
                if a.nrows <= DIRECT_CROSSOVER_N {
                    let f = FactorCache::global()
                        .factor(&a, opts.host_mem_budget, Some(&this.metrics))?;
                    return f.solve_t(rhs);
                }
                let f = crate::direct::SparseLu::factor(&a)?;
                return f.solve_t(rhs);
            }
            let p = Problem {
                op: super::Operator::Csr(&a),
                b: rhs,
            };
            Ok(this.solve(&p, &opts)?.x)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Operator;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    fn cpu_dispatcher() -> Dispatcher {
        Dispatcher::new(None)
    }

    #[test]
    fn small_cpu_problem_prefers_direct() {
        let sys = poisson2d(10, None);
        let b = vec![1.0; 100];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        let d = cpu_dispatcher();
        assert_eq!(d.select(&p, &SolveOpts::default()), Some("native-direct"));
    }

    #[test]
    fn oom_direct_falls_back_to_iterative() {
        let sys = poisson2d(40, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(1600);
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        let d = cpu_dispatcher();
        let opts = SolveOpts {
            host_mem_budget: 300_000, // too small for the factor fill
            tol: 1e-9,
            ..Default::default()
        };
        let out = d.solve(&p, &opts).unwrap();
        assert_eq!(out.backend, "native-iter");
        assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-7);
        assert!(d.metrics.get("dispatch.failed.native-direct") + d.metrics.get("dispatch.refused.native-direct") >= 1);
    }

    #[test]
    fn explicit_backend_override() {
        let sys = poisson2d(10, None);
        let b = vec![1.0; 100];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        let d = cpu_dispatcher();
        let out = d
            .solve(
                &p,
                &SolveOpts {
                    backend: Some("native-iter".into()),
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(out.backend, "native-iter");
    }

    #[test]
    fn unknown_backend_errors() {
        let sys = poisson2d(6, None);
        let b = vec![1.0; 36];
        let p = Problem {
            op: Operator::Csr(&sys.matrix),
            b: &b,
        };
        let d = cpu_dispatcher();
        assert!(d
            .solve(
                &p,
                &SolveOpts {
                    backend: Some("petsc".into()),
                    ..Default::default()
                }
            )
            .is_err());
    }

    #[test]
    fn accel_chain_ends_in_native_direct() {
        // Regression: neither Accel branch used to include
        // `native-direct`, so a CPU-only dispatcher serving an Accel
        // request had no way out when `native-iter` broke down.  CG on
        // this symmetric-looking but indefinite system breaks down at
        // iteration 1; the chain must fall through to the direct LU.
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        assert!(a.looks_spd(), "test needs a looks-SPD indefinite matrix");
        let b = vec![1.0, -1.0];
        let p = Problem {
            op: Operator::Csr(&a),
            b: &b,
        };
        let d = cpu_dispatcher();
        let out = d.solve(&p, &SolveOpts::on_accel()).unwrap();
        assert_eq!(out.backend, "native-direct");
        assert!(util::rel_l2(&a.matvec(&out.x), &b) < 1e-10);
        assert!(
            d.metrics.get("dispatch.failed.native-iter") >= 1,
            "native-iter must have been tried and failed first"
        );
    }

    #[test]
    fn solver_fn_factors_once_per_forward_backward_pass() {
        // Acceptance: at most one numeric factorization per (pattern,
        // values) pair across a forward + backward (transpose) pass,
        // observable through the dispatcher's own metrics registry.
        let mut rng = Prng::new(0xFAC7);
        let a = random_nonsymmetric(&mut rng, 37, 4);
        let pattern = crate::sparse::Pattern::of(&a);
        let d = Arc::new(cpu_dispatcher());
        let f = d.solver_fn(SolveOpts::default());
        let b = rng.normal_vec(37);
        let gy = rng.normal_vec(37);

        let x = f(&pattern, &a.vals, &b, Transpose::No).unwrap();
        let lambda = f(&pattern, &a.vals, &gy, Transpose::Yes).unwrap();
        // plus a second forward (training-loop shape): still one factorization
        let x2 = f(&pattern, &a.vals, &b, Transpose::No).unwrap();

        assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-9);
        let mut atl = vec![0.0; 37];
        a.spmv_t(&lambda, &mut atl);
        assert!(util::rel_l2(&atl, &gy) < 1e-9);
        assert_eq!(x, x2, "cached forward must be bit-stable");

        let factorizations = d.metrics.get("factor_cache.numeric_factorizations");
        assert!(
            factorizations <= 1,
            "expected at most one numeric factorization, got {factorizations}"
        );
        assert!(
            d.metrics.get("factor_cache.hit.numeric") >= 2,
            "backward and repeat solves must be cache hits"
        );
    }

    #[test]
    fn solver_fn_respects_iterative_overrides() {
        // an explicit iterative backend/method must bypass the factor
        // cache and go through dispatch
        let sys = poisson2d(8, None);
        let pattern = crate::sparse::Pattern::of(&sys.matrix);
        let d = Arc::new(cpu_dispatcher());
        let f = d.solver_fn(SolveOpts {
            backend: Some("native-iter".into()),
            tol: 1e-11,
            ..Default::default()
        });
        let b = vec![1.0; 64];
        let x = f(&pattern, &sys.matrix.vals, &b, Transpose::No).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-8);
        assert_eq!(
            d.metrics.get("factor_cache.numeric_factorizations"),
            0,
            "iterative override must not factor"
        );
        assert!(d.metrics.get("dispatch.solved.native-iter") >= 1);
    }

    #[test]
    fn solver_fn_handles_nonsymmetric_transpose() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 30, 4);
        let pattern = crate::sparse::Pattern::of(&a);
        let d = Arc::new(cpu_dispatcher());
        let f = d.solver_fn(SolveOpts::default());
        let b = rng.normal_vec(30);
        let xt = f(&pattern, &a.vals, &b, Transpose::Yes).unwrap();
        let mut atx = vec![0.0; 30];
        a.spmv_t(&xt, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-9);
    }
}
