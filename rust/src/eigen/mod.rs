//! Symmetric eigensolvers — the `.eigsh` entry point substrate.
//!
//! * [`dense_sym::jacobi_eigh`] — cyclic Jacobi for the small dense
//!   (Rayleigh–Ritz) problems inside the iterative eigensolvers.
//! * [`lanczos::lanczos`] — Lanczos with full reorthogonalization for a
//!   few extreme eigenpairs.
//! * [`lobpcg::lobpcg`] — locally optimal block PCG (Knyazev 2001), the
//!   paper's distributed-capable eigensolver, here in its stabilized
//!   orthogonal-basis form.

pub mod dense_sym;
pub mod lanczos;
pub mod lobpcg;

pub use dense_sym::jacobi_eigh;
pub use lanczos::lanczos;
pub use lobpcg::{lobpcg, LobpcgOpts};

/// Result of an iterative eigensolve: `values` ascending, `vectors[j]`
/// the eigenvector for `values[j]`, unit 2-norm.
#[derive(Clone, Debug)]
pub struct EigResult {
    pub values: Vec<f64>,
    pub vectors: Vec<Vec<f64>>,
    pub iters: usize,
    /// Per-pair final residual ||A v - lambda v||.
    pub residuals: Vec<f64>,
}
