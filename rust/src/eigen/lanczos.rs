//! Lanczos with full reorthogonalization for symmetric operators.

use super::dense_sym::jacobi_eigh;
use super::EigResult;
use crate::iterative::LinOp;
use crate::util::{dot, norm2, Prng};

/// `which` end of the spectrum to return.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Which {
    Smallest,
    Largest,
}

/// Compute `k` extreme eigenpairs of symmetric `a` with at most
/// `max_dim` Lanczos vectors (full reorthogonalization).
pub fn lanczos(a: &dyn LinOp, k: usize, which: Which, max_dim: usize, seed: u64) -> EigResult {
    let n = a.nrows();
    assert_eq!(n, a.ncols());
    let m = max_dim.min(n).max(k + 2).min(n);

    let mut rng = Prng::new(seed);
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut alpha = Vec::with_capacity(m);
    let mut beta: Vec<f64> = Vec::with_capacity(m);

    let mut v = rng.normal_vec(n);
    let nv = norm2(&v);
    for x in v.iter_mut() {
        *x /= nv;
    }
    q.push(v);

    let mut w = vec![0f64; n];
    for j in 0..m {
        a.apply(&q[j], &mut w);
        let aj = dot(&w, &q[j]);
        alpha.push(aj);
        // w -= alpha_j q_j + beta_{j-1} q_{j-1}
        for i in 0..n {
            w[i] -= aj * q[j][i];
        }
        if j > 0 {
            let bj = beta[j - 1];
            for i in 0..n {
                w[i] -= bj * q[j - 1][i];
            }
        }
        // full reorthogonalization (twice for stability)
        for _ in 0..2 {
            for qi in &q {
                let c = dot(&w, qi);
                if c != 0.0 {
                    for i in 0..n {
                        w[i] -= c * qi[i];
                    }
                }
            }
        }
        let bj = norm2(&w);
        if j + 1 == m || bj < 1e-13 {
            break;
        }
        beta.push(bj);
        let mut qn = w.clone();
        for x in qn.iter_mut() {
            *x /= bj;
        }
        q.push(qn);
    }

    // tridiagonal dense eig
    let dim = q.len();
    let mut t = vec![0f64; dim * dim];
    for i in 0..dim {
        t[i * dim + i] = alpha[i];
        if i + 1 < dim {
            t[i * dim + i + 1] = beta[i];
            t[(i + 1) * dim + i] = beta[i];
        }
    }
    let (tvals, tvecs) = jacobi_eigh(&t, dim);

    // pick k from the requested end (tvals ascending)
    let idx: Vec<usize> = match which {
        Which::Smallest => (0..k.min(dim)).collect(),
        Which::Largest => (dim - k.min(dim)..dim).rev().collect(),
    };
    let mut values = Vec::new();
    let mut vectors = Vec::new();
    let mut residuals = Vec::new();
    for &i in &idx {
        let lam = tvals[i];
        let mut vec_n = vec![0f64; n];
        for (j, qj) in q.iter().enumerate() {
            let c = tvecs[i][j];
            for l in 0..n {
                vec_n[l] += c * qj[l];
            }
        }
        let nv = norm2(&vec_n);
        for x in vec_n.iter_mut() {
            *x /= nv;
        }
        let mut av = vec![0f64; n];
        a.apply(&vec_n, &mut av);
        let res = (0..n)
            .map(|l| (av[l] - lam * vec_n[l]).powi(2))
            .sum::<f64>()
            .sqrt();
        values.push(lam);
        vectors.push(vec_n);
        residuals.push(res);
    }
    EigResult {
        values,
        vectors,
        iters: dim,
        residuals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn smallest_eigenvalues_of_laplacian() {
        // continuous eigenvalues of -Δ on unit square: pi^2 (p^2 + q^2);
        // FD eigenvalues: (4/h^2)(sin^2(p pi h/2) + sin^2(q pi h/2)) with
        // h = 1/(g+1).
        let g = 12;
        let sys = poisson2d(g, None);
        let r = lanczos(&sys.matrix, 4, Which::Smallest, 80, 0);
        let h = 1.0 / (g as f64 + 1.0);
        let lam = |p: f64, q: f64| {
            (4.0 / (h * h))
                * ((p * std::f64::consts::PI * h / 2.0).sin().powi(2)
                    + (q * std::f64::consts::PI * h / 2.0).sin().powi(2))
        };
        let expected = {
            let mut v = vec![lam(1.0, 1.0), lam(1.0, 2.0), lam(2.0, 1.0), lam(2.0, 2.0)];
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        };
        for (got, want) in r.values.iter().zip(&expected) {
            assert!(
                (got - want).abs() < 1e-6 * want,
                "eig {got} vs expected {want}"
            );
        }
        for res in &r.residuals {
            assert!(*res < 1e-6, "residual {res}");
        }
    }

    #[test]
    fn largest_matches_power_iteration_scale() {
        let g = 10;
        let sys = poisson2d(g, None);
        let r = lanczos(&sys.matrix, 1, Which::Largest, 60, 1);
        let h = 1.0 / (g as f64 + 1.0);
        // largest FD eigenvalue ~ 8/h^2 * sin^2(g pi h / 2)
        let upper = 8.0 / (h * h);
        assert!(r.values[0] <= upper && r.values[0] > 0.5 * upper);
    }

    #[test]
    fn eigenvectors_unit_norm() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = lanczos(&sys.matrix, 3, Which::Smallest, 50, 2);
        for v in &r.vectors {
            let n2: f64 = v.iter().map(|x| x * x).sum();
            assert!((n2 - 1.0).abs() < 1e-10);
        }
    }
}
