//! LOBPCG (Knyazev 2001) in the stabilized orthogonal-basis form:
//! Rayleigh–Ritz on an orthonormalized [X, W, P] basis each iteration.
//!
//! The same template the paper distributes (§3.3): the only non-local
//! operations are the operator apply and inner products, which the
//! distributed layer swaps for halo-exchange SpMV and all_reduce.

use super::dense_sym::{jacobi_eigh, matmul};
use super::EigResult;
use crate::iterative::{LinOp, Precond};
use crate::util::{dot, norm2, Prng};

#[derive(Clone, Debug)]
pub struct LobpcgOpts {
    pub tol: f64,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for LobpcgOpts {
    fn default() -> Self {
        LobpcgOpts {
            tol: 1e-8,
            max_iters: 500,
            seed: 0,
        }
    }
}

/// `k` smallest eigenpairs of symmetric `a` with preconditioner `m`.
pub fn lobpcg(a: &dyn LinOp, m: &dyn Precond, k: usize, opts: &LobpcgOpts) -> EigResult {
    let n = a.nrows();
    assert!(k >= 1 && 3 * k < n, "lobpcg needs 3k < n");
    let mut rng = Prng::new(opts.seed);

    // X: k column vectors
    let mut x: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(n)).collect();
    orthonormalize(&mut x);
    let mut p: Vec<Vec<f64>> = Vec::new();

    let mut values = vec![0f64; k];
    let mut iters = 0;
    let mut residuals = vec![f64::INFINITY; k];

    let mut w_buf = vec![0f64; n];
    for it in 0..opts.max_iters {
        iters = it + 1;
        // Rayleigh quotients + residuals
        let ax: Vec<Vec<f64>> = x
            .iter()
            .map(|xi| {
                a.apply(xi, &mut w_buf);
                w_buf.clone()
            })
            .collect();
        let mut ws: Vec<Vec<f64>> = Vec::with_capacity(k);
        let mut worst = 0.0f64;
        for j in 0..k {
            let lam = dot(&x[j], &ax[j]);
            values[j] = lam;
            let r: Vec<f64> = (0..n).map(|i| ax[j][i] - lam * x[j][i]).collect();
            let rn = norm2(&r);
            residuals[j] = rn;
            worst = worst.max(rn / lam.abs().max(1.0));
            let mut z = vec![0f64; n];
            m.apply(&r, &mut z);
            ws.push(z);
        }
        if worst < opts.tol {
            break;
        }
        // basis S = [X, W, P], orthonormalized with deflation of
        // near-dependent directions
        let mut s: Vec<Vec<f64>> = Vec::with_capacity(3 * k);
        s.extend(x.iter().cloned());
        s.extend(ws);
        s.extend(p.iter().cloned());
        orthonormalize(&mut s);
        let d = s.len();
        // projected operator T = S^T A S (row-major d x d)
        let as_: Vec<Vec<f64>> = s
            .iter()
            .map(|si| {
                a.apply(si, &mut w_buf);
                w_buf.clone()
            })
            .collect();
        let mut t = vec![0f64; d * d];
        for i in 0..d {
            for j in i..d {
                let v = dot(&s[i], &as_[j]);
                t[i * d + j] = v;
                t[j * d + i] = v;
            }
        }
        let (_tvals, tvecs) = jacobi_eigh(&t, d);
        // new X = S * C[:, :k]; P = the non-X component of the update
        let mut c = vec![0f64; d * k];
        for (j, tv) in tvecs.iter().take(k).enumerate() {
            for i in 0..d {
                c[i * k + j] = tv[i];
            }
        }
        let sc = {
            // S as (n x d) row-major
            let mut sm = vec![0f64; n * d];
            for (j, sj) in s.iter().enumerate() {
                for i in 0..n {
                    sm[i * d + j] = sj[i];
                }
            }
            matmul(&sm, &c, n, d, k)
        };
        let x_new: Vec<Vec<f64>> = (0..k)
            .map(|j| (0..n).map(|i| sc[i * k + j]).collect())
            .collect();
        // P = X_new - X (X^T X_new): the locally-optimal direction memory
        let mut p_new: Vec<Vec<f64>> = Vec::with_capacity(k);
        for j in 0..k {
            let mut pj = x_new[j].clone();
            for xi in &x {
                let cij = dot(xi, &x_new[j]);
                for l in 0..n {
                    pj[l] -= cij * xi[l];
                }
            }
            let np = norm2(&pj);
            if np > 1e-12 {
                for v in pj.iter_mut() {
                    *v /= np;
                }
                p_new.push(pj);
            }
        }
        x = x_new;
        orthonormalize(&mut x);
        p = p_new;
    }

    // sort pairs ascending by value
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&i, &j| values[i].partial_cmp(&values[j]).unwrap());
    EigResult {
        values: order.iter().map(|&i| values[i]).collect(),
        vectors: order.iter().map(|&i| x[i].clone()).collect(),
        iters,
        residuals: order.iter().map(|&i| residuals[i]).collect(),
    }
}

/// In-place modified Gram–Schmidt; drops near-dependent vectors.
fn orthonormalize(vs: &mut Vec<Vec<f64>>) {
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(vs.len());
    for v in vs.drain(..) {
        let mut w = v;
        for _ in 0..2 {
            for u in &out {
                let c = dot(&w, u);
                if c != 0.0 {
                    for i in 0..w.len() {
                        w[i] -= c * u[i];
                    }
                }
            }
        }
        let nw = norm2(&w);
        if nw > 1e-10 {
            for x in w.iter_mut() {
                *x /= nw;
            }
            out.push(w);
        }
    }
    *vs = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn matches_lanczos_on_poisson() {
        let g = 10;
        let sys = poisson2d(g, None);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = lobpcg(
            &sys.matrix,
            &m,
            4,
            &LobpcgOpts {
                tol: 1e-9,
                max_iters: 300,
                seed: 0,
            },
        );
        let l = super::super::lanczos::lanczos(
            &sys.matrix,
            4,
            super::super::lanczos::Which::Smallest,
            90,
            0,
        );
        for (a, b) in r.values.iter().zip(&l.values) {
            assert!((a - b).abs() < 1e-6 * b, "{a} vs {b}");
        }
    }

    #[test]
    fn eigenpairs_satisfy_equation() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = lobpcg(&sys.matrix, &Identity, 3, &LobpcgOpts::default());
        for (lam, v) in r.values.iter().zip(&r.vectors) {
            let av = sys.matrix.matvec(v);
            let res: f64 = av
                .iter()
                .zip(v)
                .map(|(a, x)| (a - lam * x) * (a - lam * x))
                .sum::<f64>()
                .sqrt();
            assert!(res < 1e-6 * lam, "residual {res} for lambda {lam}");
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = lobpcg(&sys.matrix, &Identity, 3, &LobpcgOpts::default());
        for i in 0..3 {
            for j in 0..3 {
                let d = dot(&r.vectors[i], &r.vectors[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-6, "gram[{i}][{j}] = {d}");
            }
        }
    }
}
