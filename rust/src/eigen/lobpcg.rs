//! LOBPCG (Knyazev 2001) in the stabilized orthogonal-basis form:
//! Rayleigh–Ritz on an orthonormalized [X, W, P] basis each iteration.
//!
//! The recurrence lives in [`crate::krylov::lobpcg`], written once over
//! `LinearOperator x Communicator` — the only non-local operations are
//! the operator apply and inner products (paper §3.3), so the serial
//! and distributed eigensolvers share one body.  This wrapper is the
//! serial entry point ([`NullComm`]).

use super::EigResult;
use crate::iterative::{LinOp, Precond};
use crate::krylov::{NullComm, SerialOp};

#[derive(Clone, Debug)]
pub struct LobpcgOpts {
    pub tol: f64,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for LobpcgOpts {
    fn default() -> Self {
        LobpcgOpts {
            tol: 1e-8,
            max_iters: 500,
            seed: 0,
        }
    }
}

/// `k` smallest eigenpairs of symmetric `a` with preconditioner `m`.
pub fn lobpcg(a: &dyn LinOp, m: &dyn Precond, k: usize, opts: &LobpcgOpts) -> EigResult {
    assert_eq!(a.nrows(), a.ncols(), "lobpcg needs a square operator");
    crate::krylov::lobpcg(&SerialOp(a), m, k, &NullComm, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::poisson::poisson2d;
    use crate::util::dot;

    #[test]
    fn matches_lanczos_on_poisson() {
        let g = 10;
        let sys = poisson2d(g, None);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = lobpcg(
            &sys.matrix,
            &m,
            4,
            &LobpcgOpts {
                tol: 1e-9,
                max_iters: 300,
                seed: 0,
            },
        );
        let l = super::super::lanczos::lanczos(
            &sys.matrix,
            4,
            super::super::lanczos::Which::Smallest,
            90,
            0,
        );
        for (a, b) in r.values.iter().zip(&l.values) {
            assert!((a - b).abs() < 1e-6 * b, "{a} vs {b}");
        }
    }

    #[test]
    fn eigenpairs_satisfy_equation() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = lobpcg(&sys.matrix, &Identity, 3, &LobpcgOpts::default());
        for (lam, v) in r.values.iter().zip(&r.vectors) {
            let av = sys.matrix.matvec(v);
            let res: f64 = av
                .iter()
                .zip(v)
                .map(|(a, x)| (a - lam * x) * (a - lam * x))
                .sum::<f64>()
                .sqrt();
            assert!(res < 1e-6 * lam, "residual {res} for lambda {lam}");
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = lobpcg(&sys.matrix, &Identity, 3, &LobpcgOpts::default());
        for i in 0..3 {
            for j in 0..3 {
                let d = dot(&r.vectors[i], &r.vectors[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-6, "gram[{i}][{j}] = {d}");
            }
        }
    }
}
