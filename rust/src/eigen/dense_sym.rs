//! Dense symmetric eigensolver (cyclic Jacobi rotations).
//!
//! Used for the Rayleigh–Ritz projections inside Lanczos/LOBPCG (the
//! projected problems are at most ~3k x 3k) and as the exact reference
//! in eigensolver tests.  Row-major storage.

/// Eigendecomposition of a symmetric matrix `a` (row-major n x n).
/// Returns (values ascending, vectors) with `vectors[j]` the unit
/// eigenvector of `values[j]`.
pub fn jacobi_eigh(a: &[f64], n: usize) -> (Vec<f64>, Vec<Vec<f64>>) {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    // v = identity; accumulates rotations (columns are eigenvectors)
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + frob(&m, n)) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // accumulate rotation into v (columns)
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // extract and sort
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let vectors: Vec<Vec<f64>> = pairs
        .iter()
        .map(|&(_, col)| (0..n).map(|r| v[r * n + col]).collect())
        .collect();
    (values, vectors)
}

fn frob(m: &[f64], n: usize) -> f64 {
    let _ = n;
    m.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Small dense row-major matmul helper used by the block eigensolvers:
/// C (p x r) = A (p x q) * B (q x r).
pub fn matmul(a: &[f64], b: &[f64], p: usize, q: usize, r: usize) -> Vec<f64> {
    assert_eq!(a.len(), p * q);
    assert_eq!(b.len(), q * r);
    let mut c = vec![0f64; p * r];
    for i in 0..p {
        for k in 0..q {
            let aik = a[i * q + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..r {
                c[i * r + j] += aik * b[k * r + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn diagonal_matrix() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (vals, vecs) = jacobi_eigh(&a, 3);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
        assert!((vecs[0][1].abs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_symmetric_reconstructs() {
        let n = 12;
        let mut rng = Prng::new(1);
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let (vals, vecs) = jacobi_eigh(&a, n);
        // A v = lambda v for each pair
        for (lam, v) in vals.iter().zip(&vecs) {
            for i in 0..n {
                let av: f64 = (0..n).map(|j| a[i * n + j] * v[j]).sum();
                assert!((av - lam * v[i]).abs() < 1e-9, "residual too large");
            }
            let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-10);
        }
        // eigenvectors orthogonal
        for i in 0..n {
            for j in i + 1..n {
                let d: f64 = vecs[i].iter().zip(&vecs[j]).map(|(a, b)| a * b).sum();
                assert!(d.abs() < 1e-9);
            }
        }
    }

    #[test]
    fn two_by_two_known() {
        // [[2, 1], [1, 2]] -> eigenvalues 1, 3
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (vals, _) = jacobi_eigh(&a, 2);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn matmul_small() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let c = matmul(&a, &b, 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }
}
