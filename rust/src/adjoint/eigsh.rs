//! Eigenvalue adjoint via the Hellmann–Feynman theorem (paper Eq. 4)
//! and eigenVECTOR adjoints via one deflated linear solve per pair
//! (paper §3.2.2: "Eigenvector gradients require one additional
//! deflated linear solve per eigenpair").
//!
//! For the symmetric problem A v = lambda v with ||v|| = 1, the
//! eigenvalue gradient is the rank-1 outer product `v_i v_j` restricted
//! to the sparsity pattern — an O(nnz) evaluation with NO additional
//! linear solve.  Valid for simple (non-degenerate) eigenvalues; the
//! forward result carries the residuals so callers can detect clusters.
//!
//! For a loss touching the eigenvector, first-order perturbation theory
//! gives `dv = -(A - lambda I)^+ (I - v v^T) dA v`, so the adjoint is
//! `dL/dA_ij = -w_i v_j` where `w` solves the *deflated* system
//! `(A - lambda I) w = (I - v v^T) dL/dv` restricted to the orthogonal
//! complement of `v` — symmetric and indefinite, which is exactly what
//! [`crate::iterative::minres`] handles.

use std::rc::Rc;

use crate::autograd::{CustomOp, Tape, Value, Var};
use crate::eigen::{lobpcg, EigResult, LobpcgOpts};
use crate::error::{Error, Result};
use crate::iterative::{IterOpts, Jacobi, LinOp, Precond};
use crate::sparse::{Csr, Pattern};

struct EigshOp {
    pattern: Pattern,
    entry_rows: std::sync::Arc<Vec<usize>>,
    /// Eigenvectors stashed for Hellmann–Feynman (k x n).
    vectors: Vec<Vec<f64>>,
}

impl CustomOp for EigshOp {
    fn name(&self) -> &'static str {
        "eigsh_adjoint"
    }

    fn backward(&self, _out_val: &Value, out_grad: &Value, _inputs: &[&Value]) -> Vec<Option<Value>> {
        let gy = out_grad.as_vec(); // one gradient per eigenvalue
        let mut dvals = vec![0.0; self.pattern.nnz()];
        for (j, v) in self.vectors.iter().enumerate() {
            let gj = gy[j];
            if gj == 0.0 {
                continue;
            }
            for k in 0..dvals.len() {
                dvals[k] += gj * v[self.entry_rows[k]] * v[self.pattern.indices[k]];
            }
        }
        vec![Some(Value::V(dvals))]
    }

    fn saved_bytes(&self) -> usize {
        self.vectors.iter().map(|v| v.len() * 8).sum::<usize>() + self.entry_rows.len() * 8
    }
}

/// Differentiable `k` smallest eigenvalues of the symmetric matrix
/// (pattern, vals).  Returns (eigenvalues Var, full EigResult).
pub fn eigsh(
    tape: &Tape,
    pattern: &Pattern,
    vals: Var,
    k: usize,
    opts: &LobpcgOpts,
) -> Result<(Var, EigResult)> {
    let vals_v = tape.vec_of(vals);
    let a = pattern.with_vals(vals_v);
    if !a.is_symmetric(1e-10) {
        return Err(Error::InvalidProblem(
            "eigsh requires a symmetric matrix".into(),
        ));
    }
    let precond = Jacobi::new(&a)?;
    let result = lobpcg(&a, &precond as &dyn Precond, k, opts);

    let mut entry_rows = vec![0usize; pattern.nnz()];
    for r in 0..pattern.nrows {
        for kk in pattern.indptr[r]..pattern.indptr[r + 1] {
            entry_rows[kk] = r;
        }
    }
    let op = EigshOp {
        pattern: pattern.clone(),
        entry_rows: std::sync::Arc::new(entry_rows),
        vectors: result.vectors.clone(),
    };
    let var = tape.custom(Rc::new(op), vec![vals], Value::V(result.values.clone()));
    Ok((var, result))
}

// -------------------------------------------------------------------
// Eigenvector adjoint: the deflated solve (paper §3.2.2).
// -------------------------------------------------------------------

/// The projected-and-shifted operator P (A - lambda I) P with
/// P = I - v v^T: symmetric, nonsingular on span{v}^perp.
struct DeflatedOp<'a> {
    a: &'a Csr,
    lambda: f64,
    v: &'a [f64],
}

impl DeflatedOp<'_> {
    fn project(&self, x: &mut [f64]) {
        let c = crate::util::dot(self.v, x);
        for (xi, vi) in x.iter_mut().zip(self.v) {
            *xi -= c * vi;
        }
    }
}

impl LinOp for DeflatedOp<'_> {
    fn nrows(&self) -> usize {
        self.a.nrows
    }
    fn ncols(&self) -> usize {
        self.a.ncols
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        // y = P (A - lambda I) P x; callers keep x in v^perp already but
        // project defensively on both sides for exact symmetry.
        let mut px = x.to_vec();
        self.project(&mut px);
        self.a.spmv(&px, y);
        for i in 0..y.len() {
            y[i] -= self.lambda * px[i];
        }
        self.project(y);
    }
}

struct EigshVectorOp {
    pattern: Pattern,
    entry_rows: std::sync::Arc<Vec<usize>>,
    value: f64,
    vector: Vec<f64>,
    solve_tol: f64,
    solve_iters: usize,
}

impl CustomOp for EigshVectorOp {
    fn name(&self) -> &'static str {
        "eigsh_vector_adjoint"
    }

    fn backward(&self, _out_val: &Value, out_grad: &Value, inputs: &[&Value]) -> Vec<Option<Value>> {
        let gv = out_grad.as_vec(); // dL/dv
        let vals = inputs[0].as_vec();
        let a = self.pattern.with_vals(vals.to_vec());
        // rhs = (I - v v^T) gv
        let mut rhs = gv.clone();
        let c = crate::util::dot(&self.vector, &rhs);
        for (ri, vi) in rhs.iter_mut().zip(&self.vector) {
            *ri -= c * vi;
        }
        // one deflated solve: (A - lambda I) w = rhs on v^perp —
        // symmetric indefinite, served by the generic MINRES kernel
        // through its serial entry point (the same body the distributed
        // layer runs over rank teams)
        let op = DeflatedOp {
            a: &a,
            lambda: self.value,
            v: &self.vector,
        };
        let res = crate::iterative::minres(
            &op,
            &rhs,
            &crate::iterative::Identity,
            &IterOpts {
                tol: self.solve_tol,
                max_iters: self.solve_iters,
                record_history: false,
            },
            None,
        );
        let w = res.x;
        // dL/dA_ij = -w_i v_j  (+ symmetrized contribution -v_i w_j is
        // implicit: autograd treats each stored entry independently, and
        // the FD check perturbs symmetric pairs together)
        let mut dvals = vec![0.0; self.pattern.nnz()];
        for k in 0..dvals.len() {
            dvals[k] = -w[self.entry_rows[k]] * self.vector[self.pattern.indices[k]];
        }
        vec![Some(Value::V(dvals))]
    }

    fn saved_bytes(&self) -> usize {
        self.vector.len() * 8 + self.entry_rows.len() * 8
    }
}

/// Differentiable eigenPAIRS: returns `(values Var, vector Vars, raw
/// result)`.  Each eigenvector enters the tape as its own O(1) node
/// whose backward runs ONE deflated MINRES solve (paper §3.2.2); the
/// eigenvalues share the Hellmann–Feynman node of [`eigsh`].
///
/// Requires simple (well-separated) eigenvalues — the deflated system
/// is singular beyond span{v}^perp at a degenerate pair.
pub fn eigsh_with_vectors(
    tape: &Tape,
    pattern: &Pattern,
    vals: Var,
    k: usize,
    opts: &LobpcgOpts,
) -> Result<(Var, Vec<Var>, EigResult)> {
    let (lams, result) = eigsh(tape, pattern, vals, k, opts)?;
    let mut entry_rows = vec![0usize; pattern.nnz()];
    for r in 0..pattern.nrows {
        for kk in pattern.indptr[r]..pattern.indptr[r + 1] {
            entry_rows[kk] = r;
        }
    }
    let entry_rows = std::sync::Arc::new(entry_rows);
    let mut vecs = Vec::with_capacity(k);
    for j in 0..k {
        let op = EigshVectorOp {
            pattern: pattern.clone(),
            entry_rows: entry_rows.clone(),
            value: result.values[j],
            vector: result.vectors[j].clone(),
            solve_tol: (opts.tol * 1e-2).max(1e-13),
            solve_iters: 50_000,
        };
        let var = tape.custom(
            Rc::new(op),
            vec![vals],
            Value::V(result.vectors[j].clone()),
        );
        vecs.push(var);
    }
    Ok((lams, vecs, result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;
    use crate::util::Prng;

    #[test]
    fn eigenvalue_gradient_matches_finite_differences() {
        // NOTE: the constant-coefficient Laplacian has the DEGENERATE
        // pair lambda(1,2) = lambda(2,1) where Hellmann-Feynman is
        // ill-defined (paper §3.2.2 targets simple eigenvalues), so the
        // check runs on a generic graph Laplacian with simple spectrum.
        let mut rng_m = Prng::new(7);
        let a_mat = crate::sparse::graphs::random_graph_laplacian(&mut rng_m, 36, 4, 0.5);
        let sys_matrix = a_mat;
        let pattern = Pattern::of(&sys_matrix);
        let mut rng = Prng::new(0);

        let tape = Tape::new();
        let vals = tape.leaf_vec(sys_matrix.vals.clone());
        let opts = LobpcgOpts {
            tol: 1e-10,
            max_iters: 500,
            seed: 1,
        };
        let (lams, res) = eigsh(&tape, &pattern, vals, 3, &opts).unwrap();
        assert!(res.residuals.iter().all(|r| *r < 1e-6));
        // L = sum of weighted eigenvalues
        let w: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(lams, wv);
        let grads = tape.backward(loss);
        let dvals = grads.vec(vals).clone();

        // FD on symmetric entry PAIRS (perturbing one stored entry of a
        // symmetric matrix breaks symmetry; perturb (i,j) and (j,i)
        // together and halve, matching d/dA_sym semantics)
        let eps = 1e-5;
        let solve_vals = |v: &[f64]| {
            let a = pattern.with_vals(v.to_vec());
            let m = Jacobi::new(&a).unwrap();
            let r = lobpcg(&a, &m, 3, &opts);
            r.values
                .iter()
                .zip(&w)
                .map(|(l, wi)| l * wi)
                .sum::<f64>()
        };
        let mut checked = 0;
        for k in [0usize, pattern.nnz() / 2] {
            let r = (0..pattern.nrows)
                .find(|&r| pattern.indptr[r] <= k && k < pattern.indptr[r + 1])
                .unwrap();
            let c = pattern.indices[k];
            let ksym = pattern.find(c, r).unwrap();
            let mut vp = sys_matrix.vals.clone();
            vp[k] += eps;
            if ksym != k {
                vp[ksym] += eps;
            }
            let mut vm = sys_matrix.vals.clone();
            vm[k] -= eps;
            if ksym != k {
                vm[ksym] -= eps;
            }
            let fd = (solve_vals(&vp) - solve_vals(&vm)) / (2.0 * eps);
            let analytic = if ksym == k {
                dvals[k]
            } else {
                dvals[k] + dvals[ksym]
            };
            assert!(
                (analytic - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "entry {k}: analytic {analytic} vs fd {fd}"
            );
            checked += 1;
        }
        assert!(checked > 0);
    }

    #[test]
    fn eigenvector_gradient_matches_finite_differences() {
        // One deflated solve per pair (paper §3.2.2).  The loss
        // L = (u^T v)^2 is sign-invariant, so LOBPCG's arbitrary
        // eigenvector sign under perturbation cannot corrupt the FD
        // reference.
        let mut rng_m = Prng::new(13);
        let a_mat = crate::sparse::graphs::random_graph_laplacian(&mut rng_m, 30, 4, 0.5);
        let pattern = Pattern::of(&a_mat);
        let mut rng = Prng::new(2);
        let u = rng.normal_vec(30);
        let opts = LobpcgOpts {
            tol: 1e-12,
            max_iters: 3000,
            seed: 4,
        };

        let tape = Tape::new();
        let vals = tape.leaf_vec(a_mat.vals.clone());
        let (_lams, vecs, res) = eigsh_with_vectors(&tape, &pattern, vals, 2, &opts).unwrap();
        assert!(res.residuals.iter().all(|r| *r < 1e-8));
        // check separation (simple eigenvalues)
        assert!((res.values[1] - res.values[0]).abs() > 1e-3);

        let uv = tape.constant_vec(u.clone());
        let s = tape.dot(vecs[1], uv); // second-smallest pair
        let loss = tape.mul_ss(s, s);
        let grads = tape.backward(loss);
        let dvals = grads.vec(vals).clone();

        let loss_of_vals = |v: &[f64]| {
            let a = pattern.with_vals(v.to_vec());
            let m = Jacobi::new(&a).unwrap();
            let r = lobpcg(&a, &m, 2, &opts);
            let d = crate::util::dot(&r.vectors[1], &u);
            d * d
        };
        // FD on symmetric entry pairs
        let eps = 1e-6;
        let mut worst: f64 = 0.0;
        for k in [0usize, pattern.nnz() / 3, 2 * pattern.nnz() / 3] {
            let r = (0..pattern.nrows)
                .find(|&r| pattern.indptr[r] <= k && k < pattern.indptr[r + 1])
                .unwrap();
            let c = pattern.indices[k];
            let ksym = pattern.find(c, r).unwrap();
            let mut vp = a_mat.vals.clone();
            let mut vm = a_mat.vals.clone();
            vp[k] += eps;
            vm[k] -= eps;
            if ksym != k {
                vp[ksym] += eps;
                vm[ksym] -= eps;
            }
            let fd = (loss_of_vals(&vp) - loss_of_vals(&vm)) / (2.0 * eps);
            let analytic = if ksym == k {
                dvals[k]
            } else {
                dvals[k] + dvals[ksym]
            };
            let rel = (analytic - fd).abs() / fd.abs().max(1e-8);
            worst = worst.max(rel);
        }
        assert!(
            worst < 1e-3,
            "eigenvector adjoint vs FD rel error {worst}"
        );
    }

    #[test]
    fn eigenvector_node_count_is_one_per_pair() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let before = tape.node_count();
        let k = 3;
        let (_l, vecs, _r) =
            eigsh_with_vectors(&tape, &pattern, vals, k, &LobpcgOpts::default()).unwrap();
        assert_eq!(vecs.len(), k);
        // one Hellmann-Feynman node + k vector nodes
        assert_eq!(tape.node_count() - before, 1 + k);
    }

    #[test]
    fn rejects_nonsymmetric() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(8, 8);
        for i in 0..8 {
            coo.push(i, i, 2.0);
        }
        coo.push(0, 1, 1.0); // no mirror
        let a = coo.to_csr();
        let pattern = Pattern::of(&a);
        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        assert!(eigsh(&tape, &pattern, vals, 2, &LobpcgOpts::default()).is_err());
    }

    #[test]
    fn one_node_regardless_of_lobpcg_iters() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let before = tape.node_count();
        let (_, res) = eigsh(&tape, &pattern, vals, 2, &LobpcgOpts::default()).unwrap();
        assert!(res.iters > 3, "want a multi-iteration forward");
        assert_eq!(tape.node_count() - before, 1);
    }
}
