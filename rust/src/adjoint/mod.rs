//! The implicit-function-theorem adjoint framework (paper §3.2).
//!
//! Solves enter the autograd tape as **single custom nodes** that stash
//! only the solution and whatever the Jacobian application needs —
//! never the solver iterates — so the graph is O(1) nodes and
//! O(n + nnz) memory regardless of forward iteration count (Table 2).
//!
//! Three instances (paper §3.2.2):
//!
//! * [`linear::solve_linear`] — residual F = A x - b, backward is one
//!   adjoint solve `A^T lambda = dL/dx` plus the sparse outer product
//!   `dA_ij = -lambda_i x_j` materialized on the pattern (Eq. 3).
//! * [`nonlinear::solve_nonlinear`] — general F(u, theta) = 0 converged
//!   by Newton/Picard/Anderson; backward is one linear adjoint solve
//!   `J^T lambda = dL/du` at the converged state plus one VJP (Eq. 2).
//! * [`eigsh::eigsh`] — symmetric eigenvalues; backward is the
//!   Hellmann–Feynman outer product `d lambda / dA_ij = v_i v_j` on the
//!   pattern (Eq. 4), no extra solve.
//!
//! The forward solver is a black box ([`SolveFn`]): any of the five
//! backends may serve it, and the adjoint solve may even use a different
//! backend (paper §3.2.3).

pub mod eigsh;
pub mod linear;
pub mod nonlinear;

pub use eigsh::{eigsh, eigsh_with_vectors};
pub use linear::{solve_linear, LinearSolveOp};
pub use nonlinear::{solve_nonlinear, solve_nonlinear_with, NonlinearMethod, ResidualFactory};

use std::sync::Arc;

use crate::error::Result;
use crate::sparse::Pattern;

/// Whether the adjoint solve needs A or A^T.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Transpose {
    No,
    Yes,
}

/// A black-box linear solver over (pattern, values): the bridge between
/// the adjoint framework and the backend dispatcher.  Implementations
/// must honor `Transpose::Yes` (direct backends reuse their
/// factorization; CG on SPD systems ignores it since A = A^T).
pub type SolveFn =
    Arc<dyn Fn(&Pattern, &[f64], &[f64], Transpose) -> Result<Vec<f64>> + Send + Sync>;

/// Reference SolveFn built on the native substrate: Cholesky+RCM for
/// SPD-looking matrices, sparse LU otherwise, served through the
/// pattern-keyed factor cache so the forward solve and the adjoint
/// (`Transpose::Yes`) solve share ONE numeric factorization — and
/// training loops that re-solve on updated values reuse the symbolic
/// analysis.  Used by tests and as the default when no dispatcher is
/// wired.
pub fn native_solver() -> SolveFn {
    Arc::new(|pattern, vals, rhs, transpose| {
        let a = pattern.with_vals(vals.to_vec());
        let f = crate::factor_cache::FactorCache::global().factor(&a, u64::MAX, None)?;
        match transpose {
            Transpose::No => f.solve(rhs),
            Transpose::Yes => f.solve_t(rhs),
        }
    })
}

/// Matrix-free SolveFn over the unified Krylov substrate: CG when the
/// matrix is symmetric, BiCGStab otherwise, with `Transpose::Yes`
/// served by the SAME kernel through the [`TransposedOp`] wrapper — the
/// adjoint solve is defined once against the operator, not per
/// deployment.  For factorization-averse regimes (huge systems, frozen
/// memory budgets); training loops that can afford factors should
/// prefer [`native_solver`]'s cache.
pub fn krylov_solver(tol: f64, max_iters: usize) -> SolveFn {
    use crate::iterative::{Identity, IterOpts, Jacobi, Precond};
    use crate::krylov::{self, LinearOperator, NullComm, TransposedOp};
    Arc::new(move |pattern, vals, rhs, transpose| {
        let a = pattern.with_vals(vals.to_vec());
        let opts = IterOpts {
            tol,
            max_iters,
            record_history: false,
        };
        let m: Box<dyn Precond> = match Jacobi::new(&a) {
            Ok(j) => Box::new(j),
            Err(_) => Box::new(Identity),
        };
        // symmetry served from the factor cache when this (pattern,
        // values) was ever factored (mixed direct/iterative pipelines);
        // for purely matrix-free use nothing is cached, so this still
        // degrades to one O(nnz) scan per call.  Positive diagonal is
        // the cheap O(n) SPD screen on top.
        let symmetric = crate::factor_cache::FactorCache::global().symmetry_of(&a);
        let spd_like = symmetric && a.diag().iter().all(|&di| di > 0.0);
        let t_op = TransposedOp(&a as &dyn LinearOperator);
        let op: &dyn LinearOperator = match transpose {
            Transpose::No => &a,
            Transpose::Yes => &t_op,
        };
        let res = if spd_like {
            let r = krylov::cg(op, rhs, &*m, &NullComm, &opts, None);
            if r.breakdown {
                // positive diagonal but indefinite: CG's pAp > 0
                // assumption failed — the breakdown flag exists exactly
                // so callers retry instead of erroring (PR 1); rerun on
                // the same substrate with BiCGStab
                krylov::bicgstab(op, rhs, &*m, &NullComm, &opts, None)
            } else {
                r
            }
        } else {
            krylov::bicgstab(op, rhs, &*m, &NullComm, &opts, None)
        };
        Ok(res.require_converged(tol)?.x)
    })
}
