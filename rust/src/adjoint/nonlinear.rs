//! Nonlinear-solve adjoint (paper §3.2.2, "Nonlinear systems").
//!
//! Forward: converge F(u; theta) = 0 by Newton (possibly many inner
//! linear solves).  Backward: ONE adjoint linear solve
//! `J^T lambda = dL/du` at the converged u*, then
//! `dL/dtheta = -lambda^T dF/dtheta` via the residual's VJP — the tape
//! sees a single node regardless of forward iteration count.

use std::rc::Rc;

use crate::autograd::{CustomOp, Tape, Value, Var};
use crate::error::Result;
use crate::nonlinear::{newton, NewtonOpts, Residual};

/// Factory producing the residual for a given parameter vector theta.
pub type ResidualFactory = Rc<dyn Fn(&[f64]) -> Box<dyn Residual>>;

struct NonlinearSolveOp {
    factory: ResidualFactory,
}

impl CustomOp for NonlinearSolveOp {
    fn name(&self) -> &'static str {
        "nonlinear_solve_adjoint"
    }

    fn backward(&self, out_val: &Value, out_grad: &Value, inputs: &[&Value]) -> Vec<Option<Value>> {
        let u_star = out_val.as_vec();
        let gy = out_grad.as_vec();
        let theta = inputs[0].as_vec();
        let residual = (self.factory)(theta);
        // J^T lambda = dL/du at the converged state.  The forward
        // Newton loop factored J with the same pattern, so the cached
        // factorization (or at least its symbolic half) serves the
        // transpose solve without building J^T at all.
        let j = residual.jacobian(u_star);
        let lambda = crate::factor_cache::FactorCache::global()
            .solve_t(&j, gy, None)
            .expect("adjoint solve failed"); // rsla-lint: allow(L1, autograd backward has no error channel; adjoint failure must abort)
        // dL/dtheta = -lambda^T dF/dtheta
        let mut dtheta = residual.vjp_theta(u_star, &lambda);
        for d in dtheta.iter_mut() {
            *d = -*d;
        }
        vec![Some(Value::V(dtheta))]
    }
}

/// Forward iteration used to converge F(u, theta) = 0 before the
/// adjoint is taken (paper §3.2.2: "converged by Newton, Picard, or
/// Anderson acceleration... Eq. (2) applies directly").  The BACKWARD
/// pass is identical for all three — one adjoint solve at u* — because
/// the IFT only sees the converged state, not the iteration that
/// produced it.
#[derive(Clone, Debug)]
pub enum NonlinearMethod {
    Newton(crate::nonlinear::NewtonOpts),
    /// Relaxed fixed-point iteration on u <- u - relax * F(u).
    Picard(crate::nonlinear::PicardOpts),
    /// Anderson acceleration with the given history depth.
    Anderson {
        depth: usize,
        opts: crate::nonlinear::PicardOpts,
    },
}

/// Differentiable nonlinear solve: records ONE node on the tape.
///
/// Because the adjoint is taken at the converged state, the gradient is
/// exact only once `F(u*, theta) ~ 0`; early termination biases it
/// (paper §3.2.2) — callers control that trade-off through `opts`.
pub fn solve_nonlinear(
    tape: &Tape,
    factory: ResidualFactory,
    theta: Var,
    u0: &[f64],
    opts: &NewtonOpts,
) -> Result<(Var, crate::nonlinear::NonlinearResult)> {
    solve_nonlinear_with(
        tape,
        factory,
        theta,
        u0,
        &NonlinearMethod::Newton(opts.clone()),
    )
}

/// Jacobi-scaled fixed-point map G(u) = u - D^{-1} F(u) with D the
/// Jacobian diagonal at u0: makes Picard/Anderson convergence
/// independent of the residual's overall scaling (a raw `u - F(u)` map
/// diverges whenever ||J|| > 2, e.g. any h^-2-scaled PDE operator).
fn jacobi_scaled_map<'r>(
    r: &'r dyn crate::nonlinear::Residual,
    u0: &[f64],
) -> impl Fn(&[f64], &mut [f64]) + 'r {
    let j0 = r.jacobian(u0);
    let inv_diag: Vec<f64> = j0
        .diag()
        .iter()
        .map(|d| if *d != 0.0 { 1.0 / d } else { 1.0 })
        .collect();
    let n = r.dim();
    move |u: &[f64], out: &mut [f64]| {
        let mut f = vec![0.0; n];
        r.eval(u, &mut f);
        for i in 0..n {
            out[i] = u[i] - inv_diag[i] * f[i];
        }
    }
}

/// [`solve_nonlinear`] with an explicit forward method (the paper's
/// `method='newton'|'picard'|'anderson'` keyword).
pub fn solve_nonlinear_with(
    tape: &Tape,
    factory: ResidualFactory,
    theta: Var,
    u0: &[f64],
    method: &NonlinearMethod,
) -> Result<(Var, crate::nonlinear::NonlinearResult)> {
    let theta_v = tape.vec_of(theta);
    let residual = (factory)(&theta_v);
    let result = match method {
        NonlinearMethod::Newton(opts) => newton(residual.as_ref(), u0, opts),
        NonlinearMethod::Picard(opts) => {
            let g = jacobi_scaled_map(residual.as_ref(), u0);
            crate::nonlinear::picard(g, u0, opts)
        }
        NonlinearMethod::Anderson { depth, opts } => {
            let g = jacobi_scaled_map(residual.as_ref(), u0);
            crate::nonlinear::anderson(g, u0, *depth, opts)
        }
    };
    let op = NonlinearSolveOp { factory };
    let var = tape.custom(Rc::new(op), vec![theta], Value::V(result.u.clone()));
    Ok((var, result))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::{poisson2d, PoissonSystem};
    use crate::sparse::{Coo, Csr};
    use crate::util::{dot, Prng};

    /// F(u; theta) = A u + u^2 - theta (theta is the forcing field) —
    /// the paper's nonlinear example with theta as the parameter.
    struct Forced {
        sys: PoissonSystem,
        theta: Vec<f64>,
    }

    impl Residual for Forced {
        fn dim(&self) -> usize {
            self.theta.len()
        }
        fn eval(&self, u: &[f64], out: &mut [f64]) {
            self.sys.matrix.spmv(u, out);
            for i in 0..u.len() {
                out[i] += u[i] * u[i] - self.theta[i];
            }
        }
        fn jacobian(&self, u: &[f64]) -> Csr {
            let a = &self.sys.matrix;
            let n = a.nrows;
            let mut coo = Coo::with_capacity(n, n, a.nnz() + n);
            for r in 0..n {
                let (cols, vals) = a.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    coo.push(r, *c, *v);
                }
                coo.push(r, r, 2.0 * u[r]);
            }
            coo.to_csr()
        }
        fn vjp_theta(&self, _u: &[f64], w: &[f64]) -> Vec<f64> {
            // dF/dtheta = -I, so w^T dF/dtheta = -w
            w.iter().map(|x| -x).collect()
        }
    }

    fn factory(g: usize) -> ResidualFactory {
        Rc::new(move |theta: &[f64]| {
            Box::new(Forced {
                sys: poisson2d(g, None),
                theta: theta.to_vec(),
            }) as Box<dyn Residual>
        })
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let g = 6;
        let n = g * g;
        let mut rng = Prng::new(0);
        let theta0: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.5).collect();
        let w = rng.normal_vec(n);
        let fac = factory(g);

        let tape = Tape::new();
        let theta = tape.leaf_vec(theta0.clone());
        let opts = NewtonOpts {
            tol: 1e-13,
            ..NewtonOpts::default()
        };
        let (u, res) = solve_nonlinear(&tape, fac.clone(), theta, &vec![0.0; n], &opts).unwrap();
        assert!(res.converged);
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(u, wv);
        let grads = tape.backward(loss);
        let dtheta = grads.vec(theta).clone();

        // central finite differences on a few components
        let eps = 1e-6;
        for i in [0usize, n / 3, n - 1] {
            let solve_at = |tv: &[f64]| {
                let r = (fac)(tv);
                let out = newton(r.as_ref(), &vec![0.0; n], &opts);
                assert!(out.converged);
                dot(&out.u, &w)
            };
            let mut tp = theta0.clone();
            tp[i] += eps;
            let mut tm = theta0.clone();
            tm[i] -= eps;
            let fd = (solve_at(&tp) - solve_at(&tm)) / (2.0 * eps);
            assert!(
                (dtheta[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "dtheta[{i}] {} vs fd {fd}",
                dtheta[i]
            );
        }
    }

    #[test]
    fn one_node_many_newton_iters() {
        let g = 5;
        let n = g * g;
        let tape = Tape::new();
        let theta = tape.leaf_vec(vec![1.0; n]);
        let before = tape.node_count();
        let (_, res) = solve_nonlinear(
            &tape,
            factory(g),
            theta,
            &vec![0.0; n],
            &NewtonOpts::default(),
        )
        .unwrap();
        assert!(res.iters >= 2, "want a multi-iteration forward");
        assert_eq!(tape.node_count() - before, 1);
    }

    #[test]
    fn all_three_forward_methods_give_the_same_gradient() {
        // paper §3.2.2: the adjoint only sees the converged state, so
        // Newton, Picard, and Anderson forwards must all produce the
        // same u* and the same dL/dtheta.
        let g = 5;
        let n = g * g;
        let mut rng = Prng::new(4);
        let theta0: Vec<f64> = (0..n).map(|_| 0.5 + rng.uniform()).collect();

        let run = |method: &NonlinearMethod| {
            let tape = Tape::new();
            let theta = tape.leaf_vec(theta0.clone());
            let (u, res) =
                solve_nonlinear_with(&tape, factory(g), theta, &vec![0.0; n], method).unwrap();
            assert!(res.converged, "forward did not converge: {method:?}");
            let loss = tape.dot(u, u);
            let grads = tape.backward(loss);
            (tape.vec_of(u), grads.vec(theta).clone())
        };

        let newton_out = run(&NonlinearMethod::Newton(NewtonOpts::default()));
        let picard_out = run(&NonlinearMethod::Picard(crate::nonlinear::PicardOpts {
            tol: 1e-12,
            max_iters: 100_000,
            relax: 0.1, // F has Jacobian ~ Poisson: heavy damping needed
        }));
        let anderson_out = run(&NonlinearMethod::Anderson {
            depth: 5,
            opts: crate::nonlinear::PicardOpts {
                tol: 1e-12,
                max_iters: 100_000,
                relax: 0.9,
            },
        });
        assert!(crate::util::rel_l2(&picard_out.0, &newton_out.0) < 1e-8);
        assert!(crate::util::rel_l2(&anderson_out.0, &newton_out.0) < 1e-8);
        assert!(crate::util::rel_l2(&picard_out.1, &newton_out.1) < 1e-7);
        assert!(crate::util::rel_l2(&anderson_out.1, &newton_out.1) < 1e-7);
    }

    #[test]
    fn backward_is_one_linear_solve() {
        // Table 5: forward cost = #Newton solves, backward cost = 1 solve.
        let g = 5;
        let n = g * g;
        let tape = Tape::new();
        let theta = tape.leaf_vec(vec![1.0; n]);
        let opts = NewtonOpts {
            max_iters: 5,
            fixed_iters: true,
            ..NewtonOpts::default()
        };
        let (u, res) = solve_nonlinear(&tape, factory(g), theta, &vec![0.0; n], &opts).unwrap();
        assert_eq!(res.linear_solves, 5);
        let s = tape.sum(u);
        let grads = tape.backward(s);
        // gradient exists and is finite
        assert!(grads.vec(theta).iter().all(|g| g.is_finite()));
    }
}
