//! Linear-solve adjoint (paper Eq. 3).

use std::rc::Rc;

use super::{SolveFn, Transpose};
use crate::autograd::{CustomOp, Tape, Value, Var};
use crate::sparse::Pattern;

/// The O(1) tape node for x = A^{-1} b.
///
/// Stashes: the pattern handle (Arc'd structure) and the per-entry row
/// index used by the O(nnz) gradient assembly.  The solution x* is the
/// node's output value; A's values and b are the inputs' values — no
/// duplicate storage, matching the O(n + nnz) bound of Table 2.
pub struct LinearSolveOp {
    pattern: Pattern,
    /// row index of each stored entry (nnz-length).
    entry_rows: std::sync::Arc<Vec<usize>>,
    solver: SolveFn,
}

impl LinearSolveOp {
    pub fn new(pattern: Pattern, solver: SolveFn) -> Self {
        let mut entry_rows = vec![0usize; pattern.nnz()];
        for r in 0..pattern.nrows {
            for k in pattern.indptr[r]..pattern.indptr[r + 1] {
                entry_rows[k] = r;
            }
        }
        LinearSolveOp {
            pattern,
            entry_rows: std::sync::Arc::new(entry_rows),
            solver,
        }
    }
}

impl CustomOp for LinearSolveOp {
    fn name(&self) -> &'static str {
        "linear_solve_adjoint"
    }

    fn backward(&self, out_val: &Value, out_grad: &Value, inputs: &[&Value]) -> Vec<Option<Value>> {
        let x = out_val.as_vec();
        let gy = out_grad.as_vec();
        let vals = inputs[0].as_vec();
        // one adjoint solve: A^T lambda = dL/dx
        let lambda = (self.solver)(&self.pattern, vals, gy, Transpose::Yes)
            .expect("adjoint solve failed"); // rsla-lint: allow(L1, autograd backward has no error channel; adjoint failure must abort)
        // dL/dA_ij = -lambda_i x_j on the pattern (O(nnz))
        let mut dvals = vec![0.0; vals.len()];
        for k in 0..dvals.len() {
            dvals[k] = -lambda[self.entry_rows[k]] * x[self.pattern.indices[k]];
        }
        // dL/db = lambda
        vec![Some(Value::V(dvals)), Some(Value::V(lambda))]
    }

    fn saved_bytes(&self) -> usize {
        self.entry_rows.len() * 8
    }
}

/// Differentiable sparse solve: records ONE node on the tape.
///
/// `vals` (nnz values bound to `pattern`) and `b` are tape variables;
/// the returned Var holds x with gradients flowing to both via the
/// adjoint rules.  The forward solve itself runs through `solver` —
/// backend-agnostic, iterates never touch the tape.
pub fn solve_linear(
    tape: &Tape,
    pattern: &Pattern,
    vals: Var,
    b: Var,
    solver: &SolveFn,
) -> crate::error::Result<Var> {
    let vals_v = tape.vec_of(vals);
    let b_v = tape.vec_of(b);
    let x = (solver)(pattern, &vals_v, &b_v, Transpose::No)?;
    let op = LinearSolveOp::new(pattern.clone(), solver.clone());
    Ok(tape.custom(Rc::new(op), vec![vals, b], Value::V(x)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::native_solver;
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::Prng;

    /// L(x) = <w, x> so dL/dx = w; then analytically dL/db = A^{-T} w
    /// and dL/dA = -lambda x^T.
    #[test]
    fn gradients_match_finite_differences_spd() {
        let g = 6;
        let n = g * g;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let pattern = Pattern::of(&sys.matrix);
        let mut rng = Prng::new(0);
        let b0 = rng.normal_vec(n);
        let w = rng.normal_vec(n);
        let solver = native_solver();

        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let b = tape.leaf_vec(b0.clone());
        let x = solve_linear(&tape, &pattern, vals, b, &solver).unwrap();
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(x, wv);
        let grads = tape.backward(loss);

        let db = grads.vec(b).clone();
        let dvals = grads.vec(vals).clone();

        // finite differences on b
        let eps = 1e-6;
        for i in [0usize, n / 2, n - 1] {
            let mut bp = b0.clone();
            bp[i] += eps;
            let xp = crate::direct::direct_solve(&sys.matrix, &bp).unwrap();
            let mut bm = b0.clone();
            bm[i] -= eps;
            let xm = crate::direct::direct_solve(&sys.matrix, &bm).unwrap();
            let fd = (crate::util::dot(&xp, &w) - crate::util::dot(&xm, &w)) / (2.0 * eps);
            assert!(
                (db[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "db[{i}] {} vs {fd}",
                db[i]
            );
        }
        // finite differences on a few matrix entries
        for k in [0usize, pattern.nnz() / 2, pattern.nnz() - 1] {
            let mut vp = sys.matrix.vals.clone();
            vp[k] += eps;
            let ap = pattern.with_vals(vp);
            let xp = crate::direct::direct_solve(&ap, &b0).unwrap();
            let mut vm = sys.matrix.vals.clone();
            vm[k] -= eps;
            let am = pattern.with_vals(vm);
            let xm = crate::direct::direct_solve(&am, &b0).unwrap();
            let fd = (crate::util::dot(&xp, &w) - crate::util::dot(&xm, &w)) / (2.0 * eps);
            assert!(
                (dvals[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "dvals[{k}] {} vs {fd}",
                dvals[k]
            );
        }
    }

    #[test]
    fn nonsymmetric_adjoint_uses_transpose() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 30, 4);
        let pattern = Pattern::of(&a);
        let b0 = rng.normal_vec(30);
        let w = rng.normal_vec(30);
        let solver = native_solver();

        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        let b = tape.leaf_vec(b0.clone());
        let x = solve_linear(&tape, &pattern, vals, b, &solver).unwrap();
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(x, wv);
        let grads = tape.backward(loss);
        // db must equal A^{-T} w
        let f = crate::direct::SparseLu::factor(&a).unwrap();
        let lambda = f.solve_t(&w).unwrap();
        let db = grads.vec(b);
        for i in 0..30 {
            assert!((db[i] - lambda[i]).abs() < 1e-9, "db[{i}]");
        }
    }

    #[test]
    fn krylov_solver_adjoint_matches_native_solver() {
        // the matrix-free SolveFn (generic CG/BiCGStab + TransposedOp
        // under NullComm) must produce the same gradients as the
        // factorization-backed native solver
        let g = 6;
        let n = g * g;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let pattern = Pattern::of(&sys.matrix);
        let mut rng = Prng::new(3);
        let b0 = rng.normal_vec(n);
        let w = rng.normal_vec(n);

        let run = |solver: crate::adjoint::SolveFn| {
            let tape = Tape::new();
            let vals = tape.leaf_vec(sys.matrix.vals.clone());
            let b = tape.leaf_vec(b0.clone());
            let x = solve_linear(&tape, &pattern, vals, b, &solver).unwrap();
            let wv = tape.constant_vec(w.clone());
            let loss = tape.dot(x, wv);
            let grads = tape.backward(loss);
            (grads.vec(b).clone(), grads.vec(vals).clone())
        };
        let (db_n, dv_n) = run(native_solver());
        let (db_k, dv_k) = run(crate::adjoint::krylov_solver(1e-12, 100_000));
        assert!(crate::util::rel_l2(&db_k, &db_n) < 1e-7);
        assert!(crate::util::rel_l2(&dv_k, &dv_n) < 1e-6);

        // nonsymmetric: the transpose route through TransposedOp
        let a = random_nonsymmetric(&mut rng, 30, 4);
        let pat = Pattern::of(&a);
        let bb = rng.normal_vec(30);
        let ww = rng.normal_vec(30);
        let solver = crate::adjoint::krylov_solver(1e-12, 100_000);
        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        let b = tape.leaf_vec(bb.clone());
        let x = solve_linear(&tape, &pat, vals, b, &solver).unwrap();
        let wv = tape.constant_vec(ww.clone());
        let loss = tape.dot(x, wv);
        let grads = tape.backward(loss);
        // db must equal A^{-T} w
        let f = crate::direct::SparseLu::factor(&a).unwrap();
        let lambda = f.solve_t(&ww).unwrap();
        assert!(crate::util::rel_l2(grads.vec(b), &lambda) < 1e-7);
    }

    #[test]
    fn tape_is_o1_nodes_per_solve() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let solver = native_solver();
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let b = tape.leaf_vec(vec![1.0; g * g]);
        let before = tape.node_count();
        let _x = solve_linear(&tape, &pattern, vals, b, &solver).unwrap();
        assert_eq!(tape.node_count() - before, 1, "solve must add ONE node");
    }

    #[test]
    fn solution_is_exact() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let solver = native_solver();
        let tape = Tape::new();
        let vals = tape.constant_vec(sys.matrix.vals.clone());
        let mut rng = Prng::new(2);
        let b0 = rng.normal_vec(g * g);
        let b = tape.constant_vec(b0.clone());
        let x = solve_linear(&tape, &pattern, vals, b, &solver).unwrap();
        let xv = tape.vec_of(x);
        assert!(crate::util::rel_l2(&sys.matrix.matvec(&xv), &b0) < 1e-10);
    }
}
