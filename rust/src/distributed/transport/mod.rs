//! Process-separated rank teams: the [`ProcComm`] backend implements
//! [`Communicator`]/[`Transport`] over REAL process boundaries, so the
//! distributed layer's claims (round counts, byte counts, deterministic
//! reduction order, dead-rank behavior) are exercised against actual
//! OS-level isolation instead of threads sharing an address space.
//!
//! # Architecture
//!
//! * **Control plane** — a Unix-domain socket (`ctl.sock`) in a
//!   per-team session directory.  The parent binds it BEFORE spawning;
//!   each worker re-execs the current executable (`current_exe`), finds
//!   its identity in `RSLA_PROC_*` environment variables, binds its
//!   data-plane endpoint, and says hello (its rank, 8 bytes LE).  The
//!   parent then ships each rank its job (share + RHS + routing) as one
//!   length-prefixed blob and waits for one result blob per rank.
//! * **Data plane** — either shared-memory rings ([`shm`]): one SPSC
//!   byte ring per ordered rank pair under `/dev/shm`; or a
//!   localhost-socket mesh ([`socket`]) as the fallback.  Both carry
//!   identical tagged frames ([`wire::encode_data_frame`]).
//! * **Collectives** — `all_reduce` is hub-and-spoke through rank 0,
//!   which folds contributions in RANK-ASCENDING order — the canonical
//!   reduction order of [`Communicator::all_reduce`] — so a ProcComm
//!   solve is bitwise identical to the same solve over `LocalComm`
//!   (pinned in `tests/proc_comm.rs`).  One `all_reduce` is ONE
//!   reduction round and ZERO algorithmic bytes on every backend; the
//!   physical reduction traffic is visible separately in
//!   [`TransportStats::wire_bytes`].
//! * **Liveness** — the parent polls worker exit status whenever it
//!   would block on the control plane, and every blocking transport
//!   operation carries a deadline.  A worker that dies (or goes silent)
//!   before reporting surfaces as [`Error::RankDead`] and the whole
//!   team is killed and reaped — never a hang.
//!
//! Lock hierarchy (lint L2): `ProcComm.peer_streams` (tier 4) may be
//! held while recording into `ProcComm.wait_hist` (tier 5), never the
//! reverse; neither may be held while entering shallower tiers.

use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::krylov::Communicator;
use crate::metrics::{names as mn, Registry};
use crate::trace::names as tn;
use crate::util::lock_recover;

use super::comm::{Transport, TransportStats};
use super::dist_solver::{
    dist_cg, dist_cg_ca, dist_cg_pipelined, dist_gmres, DistIterOpts, DistMethod, DistSolveReport,
};
use super::halo::DistCsr;

pub mod shm;
pub mod socket;
pub mod wire;

const ENV_RANK: &str = "RSLA_PROC_RANK";
const ENV_SIZE: &str = "RSLA_PROC_SIZE";
const ENV_DIR: &str = "RSLA_PROC_DIR";
const ENV_TRANSPORT: &str = "RSLA_PROC_TRANSPORT";
const ENV_TIMEOUT_MS: &str = "RSLA_PROC_TIMEOUT_MS";
/// Test hook: a worker with this variable set exits (code 101) after
/// receiving its job and before solving — the dead-rank injection used
/// by `tests/krylov_equivalence.rs`.
const ENV_FAIL: &str = "RSLA_PROC_FAIL";

const CTL_TICK: Duration = Duration::from_millis(100);
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// Reduction frames use a disjoint tag namespace from halo traffic.
const AR_TAG_BASE: u64 = 1 << 62;

/// Which physical transport a process team runs over.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// Shared-memory rings under `/dev/shm` (one per ordered pair).
    #[default]
    Shm,
    /// Unix-domain-socket mesh (fallback; also an independent
    /// implementation to cross-check the rings against).
    Socket,
}

/// Options for spawning a process rank team.
#[derive(Clone, Debug)]
pub struct ProcOpts {
    pub kind: TransportKind,
    /// Deadline for the whole team lifecycle (spawn → reports) and for
    /// each blocking transport operation inside the workers.
    pub timeout_ms: u64,
    /// Payload capacity of each shared-memory ring, in bytes.
    pub ring_cap: u64,
    /// Arguments for the re-exec'd worker.  Empty for binaries whose
    /// `main` calls [`maybe_run_worker`] first; libtest binaries pass
    /// `["proc_worker_entry", "--exact"]` so only the worker-entry
    /// test runs (see [`ProcOpts::for_tests`]).
    pub worker_args: Vec<String>,
    /// Test hook: make this rank die after receiving its job.
    pub fail_rank: Option<usize>,
}

impl Default for ProcOpts {
    fn default() -> Self {
        ProcOpts {
            kind: TransportKind::Shm,
            timeout_ms: 120_000,
            ring_cap: 1 << 20,
            worker_args: Vec::new(),
            fail_rank: None,
        }
    }
}

impl ProcOpts {
    /// Options for use inside `cargo test` binaries: the re-exec'd
    /// child runs only the `proc_worker_entry` test, which calls
    /// [`maybe_run_worker`].
    pub fn for_tests(kind: TransportKind) -> Self {
        ProcOpts {
            kind,
            worker_args: vec!["proc_worker_entry".into(), "--exact".into()],
            ..ProcOpts::default()
        }
    }
}

/// Rank-team execution backend for `DSparseTensor::solve`.
#[derive(Clone, Debug, Default)]
pub enum CommBackend {
    /// Thread ranks over in-process channels (`LocalComm`).
    #[default]
    Local,
    /// Worker processes over [`ProcComm`].
    Proc(ProcOpts),
}

fn ring_path(dir: &Path, from: usize, to: usize) -> PathBuf {
    dir.join(format!("ring_{from}_{to}.dat"))
}

fn ctl_path(dir: &Path) -> PathBuf {
    dir.join("ctl.sock")
}

/// Per-team session directory: prefer `/dev/shm` (memory-backed) so
/// ring traffic never touches a disk, fall back to the system tmpdir.
fn session_dir() -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let shm = Path::new("/dev/shm");
    let base = if shm.is_dir() {
        shm.to_path_buf()
    } else {
        std::env::temp_dir()
    };
    base.join(format!(
        "rsla-proc-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ))
}

// ---- data-plane endpoint --------------------------------------------

/// Lazily-opened per-peer channels of one endpoint; guarded by
/// `ProcComm.peer_streams` (lock tier 4).
enum Mesh {
    Shm {
        dir: PathBuf,
        writers: Vec<Option<shm::RingWriter>>,
        readers: Vec<Option<shm::RingReader>>,
    },
    Socket(socket::SocketMesh),
}

impl Mesh {
    fn send_bytes(&mut self, me: usize, to: usize, frame: &[u8], deadline: Instant) -> Result<u64> {
        match self {
            Mesh::Shm { dir, writers, .. } => {
                let slot = writers
                    .get_mut(to)
                    .ok_or_else(|| Error::Distributed(format!("no such rank {to}")))?;
                if slot.is_none() {
                    *slot = Some(shm::RingWriter::open(&ring_path(dir, me, to))?);
                }
                match slot.as_mut() {
                    Some(w) => w.write_all(frame, deadline),
                    None => Err(Error::Distributed("ring writer vanished".into())),
                }
            }
            Mesh::Socket(m) => m.send_bytes(to, frame, deadline),
        }
    }

    fn recv_bytes(
        &mut self,
        me: usize,
        from: usize,
        buf: &mut [u8],
        deadline: Instant,
    ) -> Result<u64> {
        match self {
            Mesh::Shm { dir, readers, .. } => {
                let slot = readers
                    .get_mut(from)
                    .ok_or_else(|| Error::Distributed(format!("no such rank {from}")))?;
                if slot.is_none() {
                    *slot = Some(shm::RingReader::open(&ring_path(dir, from, me))?);
                }
                match slot.as_mut() {
                    Some(r) => r.read_exact(buf, deadline),
                    None => Err(Error::Distributed("ring reader vanished".into())),
                }
            }
            Mesh::Socket(m) => m.recv_bytes(from, buf, deadline),
        }
    }
}

/// [`Communicator`]/[`Transport`] endpoint of a process rank team.
///
/// Counter semantics mirror `LocalComm` exactly so reports are
/// backend-comparable: `bytes_sent` counts ALGORITHMIC point-to-point
/// payload bytes (halo traffic, `8 * len`), `reduce_rounds` counts one
/// per `all_reduce` on every rank.  Physical wire traffic — including
/// the hub-and-spoke reduction frames, which the algorithmic model
/// prices as latency (rounds), not bandwidth — is reported separately
/// via [`Transport::transport_stats`].
pub struct ProcComm {
    rank: usize,
    nranks: usize,
    timeout: Duration,
    /// Lock tier 4 (see `lint/lock_order.rs`).
    peer_streams: Mutex<Mesh>,
    /// Doorbell/backpressure waits in microseconds; lock tier 5.
    wait_hist: Mutex<Vec<u64>>,
    bytes_sent: AtomicU64,
    reduce_rounds: AtomicU64,
    wire_bytes: AtomicU64,
    wire_msgs: AtomicU64,
    ar_round: AtomicU64,
}

impl ProcComm {
    /// Open this rank's endpoint.  For [`TransportKind::Socket`] this
    /// binds the rank's listener, so it must run BEFORE the
    /// control-plane hello (peers may connect as soon as the parent has
    /// collected every hello).
    pub fn connect(
        rank: usize,
        nranks: usize,
        dir: &Path,
        kind: TransportKind,
        timeout: Duration,
    ) -> Result<Self> {
        let mesh = match kind {
            TransportKind::Shm => Mesh::Shm {
                dir: dir.to_path_buf(),
                writers: (0..nranks).map(|_| None).collect(),
                readers: (0..nranks).map(|_| None).collect(),
            },
            TransportKind::Socket => Mesh::Socket(socket::SocketMesh::bind(rank, nranks, dir)?),
        };
        Ok(ProcComm {
            rank,
            nranks,
            timeout,
            peer_streams: Mutex::new(mesh),
            wait_hist: Mutex::new(Vec::new()),
            bytes_sent: AtomicU64::new(0),
            reduce_rounds: AtomicU64::new(0),
            wire_bytes: AtomicU64::new(0),
            wire_msgs: AtomicU64::new(0),
            ar_round: AtomicU64::new(0),
        })
    }

    fn record_wait(&self, waited_us: u64) {
        if waited_us > 0 {
            lock_recover(&self.wait_hist).push(waited_us);
        }
    }

    fn raw_send(&self, to: usize, tag: u64, data: &[f64]) -> Result<()> {
        let frame = wire::encode_data_frame(tag, data);
        let deadline = Instant::now() + self.timeout;
        let waited = {
            let mut mesh = lock_recover(&self.peer_streams);
            mesh.send_bytes(self.rank, to, &frame, deadline)?
        };
        self.wire_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.wire_msgs.fetch_add(1, Ordering::Relaxed);
        self.record_wait(waited);
        Ok(())
    }

    fn raw_recv(&self, from: usize, tag: u64) -> Result<Vec<f64>> {
        let deadline = Instant::now() + self.timeout;
        let (payload, waited) = {
            let mut mesh = lock_recover(&self.peer_streams);
            let mut hdr = [0u8; 16];
            let mut waited = mesh.recv_bytes(self.rank, from, &mut hdr, deadline)?;
            let (tag_b, rest) = hdr
                .split_first_chunk::<8>()
                .ok_or_else(|| Error::Distributed("short frame header".into()))?;
            let (len_b, _) = rest
                .split_first_chunk::<8>()
                .ok_or_else(|| Error::Distributed("short frame header".into()))?;
            let got_tag = u64::from_le_bytes(*tag_b);
            if got_tag != tag {
                return Err(Error::Distributed(format!(
                    "rank {}: tag mismatch from {from}: got {got_tag:#x}, want {tag:#x} \
                     (protocol desync)",
                    self.rank
                )));
            }
            let len = u64::from_le_bytes(*len_b) as usize;
            if len > (1 << 28) {
                return Err(Error::Distributed(format!("implausible frame: {len} f64s")));
            }
            let mut payload = vec![0u8; len * 8];
            waited += mesh.recv_bytes(self.rank, from, &mut payload, deadline)?;
            (payload, waited)
        };
        self.record_wait(waited);
        wire::decode_payload(&payload)
    }

    /// A transport failure inside a collective is unrecoverable for
    /// this worker: terminate so the parent's liveness monitor converts
    /// it into a typed [`Error::RankDead`] for the caller.
    fn die(&self, what: &str, e: Error) -> ! {
        eprintln!("rsla worker rank {}: {what} failed: {e}", self.rank);
        std::process::exit(102)
    }

    fn all_reduce_inner(&self, xs: &mut [f64], tag: u64) -> Result<()> {
        if self.rank == 0 {
            // fold in RANK-ASCENDING order: own contribution is c0,
            // then += c1, c2, ... — same association as LocalComm
            for r in 1..self.nranks {
                let c = self.raw_recv(r, tag)?;
                if c.len() != xs.len() {
                    return Err(Error::Distributed(format!(
                        "all_reduce width mismatch: rank {r} sent {}, want {}",
                        c.len(),
                        xs.len()
                    )));
                }
                for (acc, v) in xs.iter_mut().zip(c.iter()) {
                    *acc += *v;
                }
            }
            for r in 1..self.nranks {
                self.raw_send(r, tag, xs)?;
            }
        } else {
            self.raw_send(0, tag, xs)?;
            let res = self.raw_recv(0, tag)?;
            if res.len() != xs.len() {
                return Err(Error::Distributed("all_reduce result width mismatch".into()));
            }
            xs.copy_from_slice(&res);
        }
        Ok(())
    }
}

impl Communicator for ProcComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.nranks
    }

    fn all_reduce(&self, xs: &mut [f64]) {
        if self.nranks > 1 {
            let tag = AR_TAG_BASE + self.ar_round.fetch_add(1, Ordering::Relaxed);
            if let Err(e) = self.all_reduce_inner(xs, tag) {
                self.die("all_reduce", e);
            }
        }
        // one round regardless of width or rank — identical accounting
        // to LocalComm (reduction traffic is latency, not bandwidth)
        self.reduce_rounds.fetch_add(1, Ordering::Relaxed);
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent.load(Ordering::Relaxed)
    }

    fn reduce_rounds(&self) -> u64 {
        self.reduce_rounds.load(Ordering::Relaxed)
    }
}

impl Transport for ProcComm {
    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        // algorithmic accounting identical to LocalComm: payload bytes
        self.bytes_sent
            .fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        if let Err(e) = self.raw_send(to, tag, &data) {
            self.die("send", e);
        }
    }

    fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        match self.raw_recv(from, tag) {
            Ok(v) => v,
            Err(e) => self.die("recv", e),
        }
    }

    fn transport_stats(&self) -> TransportStats {
        let mut hist = lock_recover(&self.wait_hist).clone();
        hist.sort_unstable();
        let pick = |q: f64| -> f64 {
            if hist.is_empty() {
                return 0.0;
            }
            let idx = ((hist.len() - 1) as f64 * q).round() as usize;
            hist.get(idx).copied().unwrap_or(0) as f64
        };
        TransportStats {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            wire_msgs: self.wire_msgs.load(Ordering::Relaxed),
            doorbell_waits: hist.len() as u64,
            doorbell_p50_us: pick(0.50),
            doorbell_p99_us: pick(0.99),
            doorbell_max_us: hist.last().copied().unwrap_or(0) as f64,
        }
    }
}

// ---- control plane helpers ------------------------------------------

fn write_blob(s: &mut UnixStream, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    s.write_all(&(bytes.len() as u64).to_le_bytes())?;
    s.write_all(bytes)?;
    Ok(())
}

/// Exact read on a control stream whose read timeout is [`CTL_TICK`];
/// `liveness` runs on every tick so a dead peer is noticed while the
/// stream is silent.
fn read_ctl_exact(
    s: &mut UnixStream,
    buf: &mut [u8],
    deadline: Instant,
    liveness: &mut dyn FnMut() -> Result<()>,
) -> Result<()> {
    use std::io::Read;
    let mut rest: &mut [u8] = buf;
    while !rest.is_empty() {
        match s.read(rest) {
            Ok(0) => {
                return Err(Error::Distributed(
                    "control stream closed mid-message".into(),
                ))
            }
            Ok(n) => {
                let n = n.min(rest.len());
                let (_, next) = std::mem::take(&mut rest).split_at_mut(n);
                rest = next;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                liveness()?;
                if Instant::now() >= deadline {
                    return Err(Error::Distributed(
                        "control plane: deadline exceeded awaiting message".into(),
                    ));
                }
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(())
}

fn read_blob(
    s: &mut UnixStream,
    deadline: Instant,
    liveness: &mut dyn FnMut() -> Result<()>,
) -> Result<Vec<u8>> {
    let mut len_b = [0u8; 8];
    read_ctl_exact(s, &mut len_b, deadline, liveness)?;
    let len = u64::from_le_bytes(len_b) as usize;
    if len > (1 << 32) {
        return Err(Error::Distributed(format!("implausible blob: {len} B")));
    }
    let mut buf = vec![0u8; len];
    read_ctl_exact(s, &mut buf, deadline, liveness)?;
    Ok(buf)
}

// ---- parent side: team lifecycle ------------------------------------

struct Worker {
    rank: usize,
    child: Child,
    done: bool,
}

/// Owns the spawned workers and the session directory; `Drop` kills
/// every still-running worker, reaps all of them, and removes the
/// directory — so every exit path (including `?`) cleans up the team.
struct TeamGuard {
    dir: PathBuf,
    workers: Vec<Worker>,
}

impl TeamGuard {
    /// Poll worker exit status.  A worker that exited NONZERO before
    /// being marked done is a dead rank (exit 0 is a worker that
    /// finished reporting and left — legal while the parent is still
    /// reading slower ranks' results).
    fn liveness(&mut self) -> Result<()> {
        for w in &mut self.workers {
            if w.done {
                continue;
            }
            match w.child.try_wait() {
                Ok(Some(status)) => {
                    w.done = true;
                    if !status.success() {
                        Registry::global().incr(mn::COMM_TRANSPORT_DEAD_RANKS, 1);
                        return Err(Error::RankDead {
                            rank: w.rank,
                            detail: status.to_string(),
                        });
                    }
                }
                Ok(None) => {}
                Err(e) => {
                    w.done = true;
                    return Err(Error::Io(e));
                }
            }
        }
        Ok(())
    }

    fn join_all(&mut self, deadline: Instant) -> Result<()> {
        loop {
            self.liveness()?;
            if self.workers.iter().all(|w| w.done) {
                return Ok(());
            }
            if Instant::now() >= deadline {
                // stragglers are killed by Drop
                return Err(Error::Distributed(
                    "worker did not exit after reporting".into(),
                ));
            }
            std::thread::sleep(ACCEPT_POLL);
        }
    }
}

impl Drop for TeamGuard {
    fn drop(&mut self) {
        for w in &mut self.workers {
            if !w.done {
                let _ = w.child.kill();
            }
            let _ = w.child.wait();
        }
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Solve one distributed system on a freshly spawned process rank team
/// and return the per-rank reports (rank order).  The team is always
/// reaped before returning, success or failure.
pub fn proc_solve(
    shares: &[DistCsr],
    bs: &[Vec<f64>],
    spd: bool,
    restart: usize,
    opts: &DistIterOpts,
    popts: &ProcOpts,
) -> Result<Vec<DistSolveReport>> {
    let n = shares.len();
    if n == 0 || bs.len() != n {
        return Err(Error::InvalidProblem(format!(
            "proc_solve: {n} shares vs {} right-hand sides",
            bs.len()
        )));
    }
    let _sp = crate::trace::span_arg(tn::COMM_TEAM, n as u64);
    let deadline = Instant::now() + Duration::from_millis(popts.timeout_ms);

    let dir = session_dir();
    std::fs::create_dir_all(&dir)?;
    let mut guard = TeamGuard {
        dir: dir.clone(),
        workers: Vec::with_capacity(n),
    };

    if popts.kind == TransportKind::Shm {
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    shm::create_ring(&ring_path(&dir, i, j), popts.ring_cap)?;
                }
            }
        }
    }

    let ctl = ctl_path(&dir);
    let listener = UnixListener::bind(&ctl)
        .map_err(|e| Error::Distributed(format!("bind {}: {e}", ctl.display())))?;
    listener.set_nonblocking(true)?;

    let exe = std::env::current_exe()?;
    let kind_s = match popts.kind {
        TransportKind::Shm => "shm",
        TransportKind::Socket => "socket",
    };
    for rank in 0..n {
        let mut cmd = Command::new(&exe);
        cmd.args(&popts.worker_args)
            .env(ENV_RANK, rank.to_string())
            .env(ENV_SIZE, n.to_string())
            .env(ENV_DIR, &dir)
            .env(ENV_TRANSPORT, kind_s)
            .env(ENV_TIMEOUT_MS, popts.timeout_ms.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        if popts.fail_rank == Some(rank) {
            cmd.env(ENV_FAIL, "1");
        }
        let child = cmd
            .spawn()
            .map_err(|e| Error::Distributed(format!("spawn worker rank {rank}: {e}")))?;
        guard.workers.push(Worker {
            rank,
            child,
            done: false,
        });
    }
    Registry::global().incr(mn::COMM_TRANSPORT_TEAMS, 1);

    // collect hellos (any arrival order), identifying each stream
    let mut streams: Vec<Option<UnixStream>> = (0..n).map(|_| None).collect();
    let mut missing = n;
    while missing > 0 {
        match listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(CTL_TICK))?;
                let mut hello = [0u8; 8];
                read_ctl_exact(&mut s, &mut hello, deadline, &mut || guard.liveness())?;
                let r = u64::from_le_bytes(hello) as usize;
                let slot = streams
                    .get_mut(r)
                    .ok_or_else(|| Error::Distributed(format!("hello from unknown rank {r}")))?;
                if slot.is_some() {
                    return Err(Error::Distributed(format!("duplicate hello from rank {r}")));
                }
                *slot = Some(s);
                missing -= 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                guard.liveness()?;
                if Instant::now() >= deadline {
                    return Err(Error::Distributed(
                        "deadline exceeded awaiting worker hellos".into(),
                    ));
                }
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }

    // ship jobs
    for (rank, (share, b)) in shares.iter().zip(bs).enumerate() {
        let blob = wire::encode_job(share, b, spd, restart, opts);
        let s = streams
            .get_mut(rank)
            .and_then(|o| o.as_mut())
            .ok_or_else(|| Error::Distributed(format!("lost control stream {rank}")))?;
        write_blob(s, &blob)?;
    }

    // collect results; liveness runs on every poll tick, so a rank
    // dying while we wait on ANY stream is noticed promptly
    let mut reports = Vec::with_capacity(n);
    for rank in 0..n {
        let s = streams
            .get_mut(rank)
            .and_then(|o| o.as_mut())
            .ok_or_else(|| Error::Distributed(format!("lost control stream {rank}")))?;
        let mut status = [0u8; 1];
        read_ctl_exact(s, &mut status, deadline, &mut || guard.liveness())?;
        let blob = read_blob(s, deadline, &mut || guard.liveness())?;
        if status != [0u8] {
            return Err(Error::Distributed(format!(
                "worker rank {rank} reported failure: {}",
                String::from_utf8_lossy(&blob)
            )));
        }
        reports.push(wire::decode_report(&blob)?);
    }

    guard.join_all(deadline)?;

    // fold the team's wire-level activity into the process-wide
    // counters feeding `rsla dist` / `rsla stats`
    let reg = Registry::global();
    reg.incr(
        mn::COMM_TRANSPORT_ROUNDS,
        reports.first().map(|r| r.reduce_rounds).unwrap_or(0),
    );
    reg.incr(
        mn::COMM_TRANSPORT_WIRE_BYTES,
        reports.iter().map(|r| r.transport.wire_bytes).sum(),
    );
    reg.incr(
        mn::COMM_TRANSPORT_DOORBELL_WAITS,
        reports.iter().map(|r| r.transport.doorbell_waits).sum(),
    );
    Ok(reports)
}

// ---- worker side -----------------------------------------------------

/// Worker-side kernel routing: the exact mirror of the SPD dispatch in
/// `DSparseTensor::solve`, so a ProcComm solve runs the same kernel the
/// LocalComm path would.
fn run_job(blob: &[u8], comm: &ProcComm) -> Result<Vec<u8>> {
    let job = wire::decode_job(blob)?;
    let rep = if !job.spd {
        dist_gmres(&job.share, &job.b_own, job.restart, comm, &job.opts)
    } else {
        match &job.opts.method {
            DistMethod::Auto | DistMethod::Cg => dist_cg(&job.share, &job.b_own, comm, &job.opts),
            DistMethod::CgPipelined => dist_cg_pipelined(&job.share, &job.b_own, comm, &job.opts),
            DistMethod::CaCg { s } => {
                let mut ca = crate::krylov::CaCgOpts::default();
                if *s > 0 {
                    ca.s = *s;
                }
                dist_cg_ca(&job.share, &job.b_own, comm, &job.opts, &ca)
            }
        }
    };
    Ok(wire::encode_report(&rep))
}

fn worker_main() -> Result<()> {
    let getenv = |k: &str| -> Result<String> {
        std::env::var(k).map_err(|_| Error::Distributed(format!("worker env {k} missing")))
    };
    let rank: usize = getenv(ENV_RANK)?
        .parse()
        .map_err(|e| Error::Distributed(format!("bad {ENV_RANK}: {e}")))?;
    let size: usize = getenv(ENV_SIZE)?
        .parse()
        .map_err(|e| Error::Distributed(format!("bad {ENV_SIZE}: {e}")))?;
    let dir = PathBuf::from(getenv(ENV_DIR)?);
    let kind = match getenv(ENV_TRANSPORT)?.as_str() {
        "socket" => TransportKind::Socket,
        _ => TransportKind::Shm,
    };
    let timeout_ms: u64 = std::env::var(ENV_TIMEOUT_MS)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120_000);
    let timeout = Duration::from_millis(timeout_ms);
    let deadline = Instant::now() + timeout;

    // data plane first (socket listeners must exist before any peer can
    // have received its job), then the hello
    let comm = ProcComm::connect(rank, size, &dir, kind, timeout)?;

    let ctl = ctl_path(&dir);
    let mut stream = loop {
        match UnixStream::connect(&ctl) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => std::thread::sleep(ACCEPT_POLL),
            Err(e) => {
                return Err(Error::Distributed(format!(
                    "worker rank {rank}: connect {}: {e}",
                    ctl.display()
                )))
            }
        }
    };
    stream.set_read_timeout(Some(CTL_TICK))?;
    {
        use std::io::Write;
        stream.write_all(&(rank as u64).to_le_bytes())?;
    }
    let blob = read_blob(&mut stream, deadline, &mut || Ok(()))?;
    if std::env::var_os(ENV_FAIL).is_some() {
        // dead-rank injection: die after taking the job, before solving
        std::process::exit(101);
    }
    match run_job(&blob, &comm) {
        Ok(payload) => {
            use std::io::Write;
            stream.write_all(&[0u8])?;
            write_blob(&mut stream, &payload)?;
            Ok(())
        }
        Err(e) => {
            use std::io::Write;
            let msg = e.to_string();
            let _ = stream.write_all(&[1u8]);
            let _ = write_blob(&mut stream, msg.as_bytes());
            Err(e)
        }
    }
}

/// Process-team worker entry point.  Every binary that may serve as a
/// re-exec target calls this FIRST (`main.rs`, bench mains, and a
/// `proc_worker_entry` `#[test]` in each integration-test binary that
/// spawns teams): if the `RSLA_PROC_*` environment identifies this
/// process as a worker, it runs the worker protocol and EXITS —
/// otherwise returns `false` and the caller proceeds normally.
pub fn maybe_run_worker() -> bool {
    if std::env::var_os(ENV_RANK).is_none() {
        return false;
    }
    match worker_main() {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            eprintln!("rsla worker: {e}");
            std::process::exit(103)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;

    fn team_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rsla-proc-unit-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// In-process ProcComm endpoints (threads, not processes): the
    /// transport does not care what's on each end of the rings/sockets,
    /// which lets this test pin the hub fold order against LocalComm
    /// bitwise without spawning.
    fn proc_team(n: usize, kind: TransportKind, dir: &Path) -> Vec<ProcComm> {
        if kind == TransportKind::Shm {
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        shm::create_ring(&ring_path(dir, i, j), 1 << 16).unwrap();
                    }
                }
            }
        }
        (0..n)
            .map(|r| ProcComm::connect(r, n, dir, kind, Duration::from_secs(30)).unwrap())
            .collect()
    }

    #[test]
    fn proc_all_reduce_matches_local_comm_bitwise_on_both_transports() {
        // magnitudes chosen so the fold order changes the result:
        // only the canonical rank-ascending association may appear
        let contrib = |r: usize| match r {
            0 => [1e16, 0.125],
            1 => [1.0, 3.5],
            2 => [-1e16, -0.25],
            _ => [1.0, 1.75],
        };
        let n = 4;
        let expect: Vec<Vec<f64>> = run_ranks(n, move |c| {
            let mut xs = contrib(c.rank());
            c.all_reduce(&mut xs);
            xs.to_vec()
        });
        for kind in [TransportKind::Shm, TransportKind::Socket] {
            let dir = team_dir(&format!("ar-{kind:?}"));
            let comms = proc_team(n, kind, &dir);
            let got: Vec<Vec<f64>> = std::thread::scope(|scope| {
                let handles: Vec<_> = comms
                    .iter()
                    .map(|c| {
                        scope.spawn(move || {
                            let mut xs = contrib(c.rank());
                            c.all_reduce(&mut xs);
                            xs.to_vec()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (r, (g, e)) in got.iter().zip(&expect).enumerate() {
                for (a, b) in g.iter().zip(e.iter()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "rank {r} over {kind:?} diverged from LocalComm"
                    );
                }
            }
            // every endpoint counts exactly one round, zero algorithmic
            // bytes — identical accounting to LocalComm
            for c in &comms {
                assert_eq!(c.reduce_rounds(), 1);
                assert_eq!(Communicator::bytes_sent(c), 0);
                let ts = c.transport_stats();
                assert!(ts.wire_msgs > 0 || c.rank() > 0 || n == 1);
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn tagged_send_recv_roundtrip_and_stats() {
        let dir = team_dir("p2p");
        let comms = proc_team(2, TransportKind::Shm, &dir);
        let (left, right) = (&comms[0], &comms[1]);
        std::thread::scope(|scope| {
            scope.spawn(move || {
                Transport::send(left, 1, 7, vec![1.0, 2.0, 3.0]);
                let back = Transport::recv(left, 1, 8);
                assert_eq!(back, vec![6.0]);
            });
            let got = Transport::recv(right, 0, 7);
            assert_eq!(got, vec![1.0, 2.0, 3.0]);
            Transport::send(right, 0, 8, vec![got.iter().sum()]);
        });
        // algorithmic bytes: 3 f64 one way, 1 f64 the other
        assert_eq!(Communicator::bytes_sent(&comms[0]), 24);
        assert_eq!(Communicator::bytes_sent(&comms[1]), 8);
        let ts = comms[0].transport_stats();
        assert_eq!(ts.wire_msgs, 1);
        assert_eq!(ts.wire_bytes, 16 + 24);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
