//! Shared-memory ring transport: one single-producer/single-consumer
//! byte ring per ordered rank pair, backed by a file under `/dev/shm`
//! (kernel page cache = the shared memory; cross-process coherence is
//! the kernel's, not ours).
//!
//! Ring layout: `[head u64 LE][tail u64 LE][payload; cap bytes]`.
//! `head` (bytes consumed) is reader-owned, `tail` (bytes produced) is
//! writer-owned; both grow monotonically, so `tail - head` is the
//! readable byte count and `cap - (tail - head)` the free space — no
//! modulo ambiguity at full/empty.  Each side caches the peer-owned
//! counter and refreshes it only when blocked ("doorbell" polling:
//! yield-spin first, then sleep), recording the blocked time so the
//! endpoint can report doorbell-wait percentiles.
//!
//! 8-byte counter updates go through aligned `pwrite`s, which the
//! kernel serves atomically through the shared page cache; the payload
//! write always precedes the `tail` publish, so a reader never observes
//! a frame before its bytes.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

const HDR: u64 = 16;
const SPIN_ROUNDS: u32 = 64;
const POLL_SLEEP: Duration = Duration::from_micros(50);

/// Yield-then-sleep poll loop shared by both ring sides.
struct Backoff {
    spins: u32,
}

impl Backoff {
    fn new() -> Self {
        Backoff { spins: 0 }
    }

    fn wait(&mut self) {
        if self.spins < SPIN_ROUNDS {
            std::thread::yield_now();
        } else {
            std::thread::sleep(POLL_SLEEP);
        }
        self.spins = self.spins.saturating_add(1);
    }
}

fn read_counter(f: &File, off: u64) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact_at(&mut b, off)?;
    Ok(u64::from_le_bytes(b))
}

fn write_counter(f: &File, off: u64, v: u64) -> Result<()> {
    f.write_all_at(&v.to_le_bytes(), off)?;
    Ok(())
}

fn timeout_err(what: &str, path: &Path) -> Error {
    Error::Distributed(format!(
        "shm ring {}: peer silent past deadline while {what}",
        path.display()
    ))
}

/// Create (and zero) a ring file with `cap` payload bytes.  The parent
/// does this for every ordered rank pair before spawning workers, so
/// endpoints only ever open existing files.
pub fn create_ring(path: &Path, cap: u64) -> Result<()> {
    let f = File::create(path)?;
    f.set_len(HDR + cap)?;
    Ok(())
}

fn open_ring(path: &Path) -> Result<(File, u64)> {
    let f = OpenOptions::new().read(true).write(true).open(path)?;
    let len = f.metadata()?.len();
    if len <= HDR {
        return Err(Error::Distributed(format!(
            "shm ring {}: file too small ({len} B)",
            path.display()
        )));
    }
    // capacity comes from the file itself, so writer and reader can
    // never disagree on it
    Ok((f, len - HDR))
}

/// Producer endpoint of one ordered rank pair's ring.
pub struct RingWriter {
    file: File,
    path: std::path::PathBuf,
    cap: u64,
    tail: u64,
    head_cache: u64,
}

impl RingWriter {
    pub fn open(path: &Path) -> Result<Self> {
        let (file, cap) = open_ring(path)?;
        let tail = read_counter(&file, 8)?;
        let head_cache = read_counter(&file, 0)?;
        Ok(RingWriter {
            file,
            path: path.to_path_buf(),
            cap,
            tail,
            head_cache,
        })
    }

    /// Append `bytes` to the ring, blocking (poll + backoff) on
    /// backpressure.  Returns the microseconds spent blocked waiting
    /// for the reader to free space.
    pub fn write_all(&mut self, bytes: &[u8], deadline: Instant) -> Result<u64> {
        if bytes.len() as u64 > self.cap {
            return Err(Error::Distributed(format!(
                "shm ring {}: frame of {} B exceeds ring capacity {} B",
                self.path.display(),
                bytes.len(),
                self.cap
            )));
        }
        let mut rest = bytes;
        let mut waited_us = 0u64;
        let mut backoff = Backoff::new();
        while !rest.is_empty() {
            let free = self.cap - (self.tail - self.head_cache);
            if free == 0 {
                let t0 = Instant::now();
                self.head_cache = read_counter(&self.file, 0)?;
                if self.cap - (self.tail - self.head_cache) == 0 {
                    if Instant::now() >= deadline {
                        return Err(timeout_err("awaiting ring space", &self.path));
                    }
                    backoff.wait();
                }
                waited_us += t0.elapsed().as_micros() as u64;
                continue;
            }
            let off = self.tail % self.cap;
            let contig = (self.cap - off).min(free);
            let n = (contig as usize).min(rest.len());
            let (chunk, next) = rest.split_at(n);
            self.file.write_all_at(chunk, HDR + off)?;
            self.tail += n as u64;
            // publish AFTER the payload bytes land
            write_counter(&self.file, 8, self.tail)?;
            rest = next;
        }
        Ok(waited_us)
    }
}

/// Consumer endpoint of one ordered rank pair's ring.
pub struct RingReader {
    file: File,
    path: std::path::PathBuf,
    cap: u64,
    head: u64,
    tail_cache: u64,
}

impl RingReader {
    pub fn open(path: &Path) -> Result<Self> {
        let (file, cap) = open_ring(path)?;
        let head = read_counter(&file, 0)?;
        let tail_cache = read_counter(&file, 8)?;
        Ok(RingReader {
            file,
            path: path.to_path_buf(),
            cap,
            head,
            tail_cache,
        })
    }

    /// Fill `buf` from the ring, blocking (poll + backoff) until enough
    /// bytes arrive.  Returns the microseconds spent blocked on the
    /// doorbell (writer had published nothing new).
    pub fn read_exact(&mut self, buf: &mut [u8], deadline: Instant) -> Result<u64> {
        let mut rest: &mut [u8] = buf;
        let mut waited_us = 0u64;
        let mut backoff = Backoff::new();
        while !rest.is_empty() {
            let avail = self.tail_cache - self.head;
            if avail == 0 {
                let t0 = Instant::now();
                self.tail_cache = read_counter(&self.file, 8)?;
                if self.tail_cache == self.head {
                    if Instant::now() >= deadline {
                        return Err(timeout_err("awaiting ring data", &self.path));
                    }
                    backoff.wait();
                }
                waited_us += t0.elapsed().as_micros() as u64;
                continue;
            }
            let off = self.head % self.cap;
            let contig = (self.cap - off).min(avail);
            let n = (contig as usize).min(rest.len());
            let (chunk, next) = std::mem::take(&mut rest).split_at_mut(n);
            self.file.read_exact_at(chunk, HDR + off)?;
            self.head += n as u64;
            // free the space AFTER the bytes are out
            write_counter(&self.file, 0, self.head)?;
            rest = next;
        }
        Ok(waited_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_ring(cap: u64, tag: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "rsla-ring-test-{}-{tag}.dat",
            std::process::id()
        ));
        create_ring(&p, cap).unwrap();
        p
    }

    fn far() -> Instant {
        Instant::now() + Duration::from_secs(30)
    }

    #[test]
    fn roundtrip_with_wraparound() {
        let p = tmp_ring(64, "wrap");
        let mut w = RingWriter::open(&p).unwrap();
        let mut r = RingReader::open(&p).unwrap();
        // 10 messages of 40 bytes through a 64-byte ring forces many
        // wraparounds and exercises the chunked copy path
        for round in 0u8..10 {
            let msg: Vec<u8> = (0..40u8).map(|i| i.wrapping_mul(3).wrapping_add(round)).collect();
            w.write_all(&msg, far()).unwrap();
            let mut back = vec![0u8; 40];
            r.read_exact(&mut back, far()).unwrap();
            assert_eq!(back, msg, "round {round}");
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn concurrent_producer_consumer_is_lossless() {
        let p = tmp_ring(256, "conc");
        let mut w = RingWriter::open(&p).unwrap();
        let mut r = RingReader::open(&p).unwrap();
        let total: usize = 64 * 1024;
        let producer = std::thread::spawn(move || {
            let chunk: Vec<u8> = (0..251u8).collect();
            let mut sent = 0usize;
            while sent < total {
                let n = chunk.len().min(total - sent);
                w.write_all(&chunk[..n], far()).unwrap();
                sent += n;
            }
        });
        let mut got = vec![0u8; total];
        r.read_exact(&mut got, far()).unwrap();
        producer.join().unwrap();
        for (i, b) in got.iter().enumerate() {
            assert_eq!(*b as usize, i % 251, "byte {i}");
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn oversized_frame_and_timeout_are_typed_errors() {
        let p = tmp_ring(32, "err");
        let mut w = RingWriter::open(&p).unwrap();
        let mut r = RingReader::open(&p).unwrap();
        assert!(w.write_all(&[0u8; 33], far()).is_err());
        // nothing written: a short deadline must surface as an error,
        // not a hang
        let soon = Instant::now() + Duration::from_millis(50);
        let mut buf = [0u8; 8];
        assert!(r.read_exact(&mut buf, soon).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
