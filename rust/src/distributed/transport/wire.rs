//! Wire codec for the process-separated rank teams: fixed-layout
//! little-endian framing with length prefixes, no external
//! serialization crates (offline build).
//!
//! Layout conventions, shared by the control plane and both data
//! transports:
//!
//! * integers are `u64` little-endian; floats are `f64::to_bits`
//!   little-endian (BITWISE exact round-trip — the transport must not
//!   perturb the FP trajectory it carries);
//! * every variable-length section is `[count u64][items...]`;
//! * decode failures surface as [`Error::Distributed`] — a malformed
//!   frame is a protocol bug, never a panic (this module is under the
//!   lint's strict-index coverage).

use crate::distributed::comm::TransportStats;
use crate::distributed::dist_solver::{DistIterOpts, DistMethod, DistPrecondKind, DistSolveReport};
use crate::distributed::halo::{DistCsr, HaloPlan};
use crate::error::{Error, Result};
use crate::sparse::Csr;

fn proto_err(what: &str) -> Error {
    Error::Distributed(format!("wire protocol: {what}"))
}

// ---- primitive writers ----------------------------------------------

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

pub fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_usize(out, xs.len());
    for x in xs {
        put_f64(out, *x);
    }
}

pub fn put_usizes(out: &mut Vec<u8>, xs: &[usize]) {
    put_usize(out, xs.len());
    for x in xs {
        put_usize(out, *x);
    }
}

pub fn put_bytes(out: &mut Vec<u8>, xs: &[u8]) {
    put_usize(out, xs.len());
    out.extend_from_slice(xs);
}

// ---- cursor reader --------------------------------------------------

/// Forward-only cursor over a received frame; every read is
/// bounds-checked and truncation is a typed error.
pub struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf }
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() < n {
            return Err(proto_err("truncated frame"));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let arr: [u8; 8] = b.try_into().map_err(|_| proto_err("u64 width"))?;
        Ok(u64::from_le_bytes(arr))
    }

    pub fn byte(&mut self) -> Result<u8> {
        let b = self.take(1)?;
        b.first().copied().ok_or_else(|| proto_err("u8 width"))
    }

    pub fn usz(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Bounded count read: rejects counts a hostile/corrupt frame could
    /// use to force an absurd allocation.
    fn count(&mut self) -> Result<usize> {
        let n = self.usz()?;
        if n > (1usize << 32) {
            return Err(proto_err("implausible element count"));
        }
        Ok(n)
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.usz()?);
        }
        Ok(out)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count()?;
        Ok(self.take(n)?.to_vec())
    }
}

// ---- domain encodings -----------------------------------------------

fn put_csr(out: &mut Vec<u8>, a: &Csr) {
    put_usize(out, a.nrows);
    put_usize(out, a.ncols);
    put_usizes(out, &a.indptr);
    put_usizes(out, &a.indices);
    put_f64s(out, &a.vals);
}

fn get_csr(r: &mut Reader) -> Result<Csr> {
    let a = Csr {
        nrows: r.usz()?,
        ncols: r.usz()?,
        indptr: r.usizes()?,
        indices: r.usizes()?,
        vals: r.f64s()?,
    };
    a.validate()
        .map_err(|e| proto_err(&format!("invalid CSR share: {e}")))?;
    Ok(a)
}

fn put_plan(out: &mut Vec<u8>, p: &HaloPlan) {
    put_usize(out, p.rank);
    put_usize(out, p.n_own);
    put_usizes(out, &p.halo_globals);
    for list in [&p.send, &p.recv] {
        put_usize(out, list.len());
        for (peer, idx) in list.iter() {
            put_usize(out, *peer);
            put_usizes(out, idx);
        }
    }
}

fn get_plan(r: &mut Reader) -> Result<HaloPlan> {
    let rank = r.usz()?;
    let n_own = r.usz()?;
    let halo_globals = r.usizes()?;
    let mut lists = Vec::with_capacity(2);
    for _ in 0..2 {
        let n = r.usz()?;
        if n > (1usize << 24) {
            return Err(proto_err("implausible neighbor count"));
        }
        let mut list = Vec::with_capacity(n);
        for _ in 0..n {
            let peer = r.usz()?;
            list.push((peer, r.usizes()?));
        }
        lists.push(list);
    }
    let recv = lists.pop().ok_or_else(|| proto_err("plan lists"))?;
    let send = lists.pop().ok_or_else(|| proto_err("plan lists"))?;
    Ok(HaloPlan {
        rank,
        n_own,
        halo_globals,
        send,
        recv,
    })
}

fn precond_code(k: &DistPrecondKind) -> u8 {
    match k {
        DistPrecondKind::Jacobi => 0,
        DistPrecondKind::BlockAmg => 1,
        DistPrecondKind::BlockLu => 2,
    }
}

fn precond_from(code: u8) -> Result<DistPrecondKind> {
    match code {
        0 => Ok(DistPrecondKind::Jacobi),
        1 => Ok(DistPrecondKind::BlockAmg),
        2 => Ok(DistPrecondKind::BlockLu),
        _ => Err(proto_err("unknown precond code")),
    }
}

fn method_code(m: &DistMethod) -> (u8, u64) {
    match m {
        DistMethod::Auto => (0, 0),
        DistMethod::Cg => (1, 0),
        DistMethod::CgPipelined => (2, 0),
        DistMethod::CaCg { s } => (3, *s as u64),
    }
}

fn method_from(code: u8, s: u64) -> Result<DistMethod> {
    match code {
        0 => Ok(DistMethod::Auto),
        1 => Ok(DistMethod::Cg),
        2 => Ok(DistMethod::CgPipelined),
        3 => Ok(DistMethod::CaCg { s: s as usize }),
        _ => Err(proto_err("unknown method code")),
    }
}

/// Kernel names cross the wire as bytes; map back to the `'static`
/// vocabulary [`DistSolveReport::method`] promises.
fn method_name_from(bytes: &[u8]) -> &'static str {
    match bytes {
        b"cg" => "cg",
        b"cg-pipelined" => "cg-pipelined",
        b"ca-cg" => "ca-cg",
        b"ca-cg+fallback" => "ca-cg+fallback",
        b"gmres" => "gmres",
        b"bicgstab" => "bicgstab",
        b"minres" => "minres",
        _ => "unknown",
    }
}

/// One rank's job: its share, RHS slice, and the solve routing.
pub struct WireJob {
    pub share: DistCsr,
    pub b_own: Vec<f64>,
    pub spd: bool,
    pub restart: usize,
    pub opts: DistIterOpts,
}

pub fn encode_job(
    share: &DistCsr,
    b_own: &[f64],
    spd: bool,
    restart: usize,
    opts: &DistIterOpts,
) -> Vec<u8> {
    let mut out = Vec::new();
    put_csr(&mut out, &share.local);
    put_plan(&mut out, &share.plan);
    put_f64s(&mut out, b_own);
    out.push(u8::from(spd));
    put_usize(&mut out, restart);
    put_f64(&mut out, opts.tol);
    put_usize(&mut out, opts.max_iters);
    out.push(precond_code(&opts.precond));
    let (mc, ms) = method_code(&opts.method);
    out.push(mc);
    put_u64(&mut out, ms);
    out
}

pub fn decode_job(buf: &[u8]) -> Result<WireJob> {
    let mut r = Reader::new(buf);
    let local = get_csr(&mut r)?;
    let plan = get_plan(&mut r)?;
    if local.nrows != plan.n_own || local.ncols != plan.n_own + plan.n_halo() {
        return Err(proto_err("share/plan shape mismatch"));
    }
    let b_own = r.f64s()?;
    if b_own.len() != plan.n_own {
        return Err(proto_err("rhs length mismatch"));
    }
    let spd = r.byte()? != 0;
    let restart = r.usz()?;
    let tol = r.f64()?;
    let max_iters = r.usz()?;
    let precond = precond_from(r.byte()?)?;
    let mc = r.byte()?;
    let ms = r.u64()?;
    let method = method_from(mc, ms)?;
    Ok(WireJob {
        share: DistCsr::new(local, plan),
        b_own,
        spd,
        restart,
        opts: DistIterOpts {
            tol,
            max_iters,
            precond,
            method,
            // the worker calls the dist_* kernels directly; the backend
            // field is only read by DSparseTensor::solve on the parent
            backend: super::CommBackend::Local,
        },
    })
}

pub fn encode_report(rep: &DistSolveReport) -> Vec<u8> {
    let mut out = Vec::new();
    put_f64s(&mut out, &rep.x_own);
    put_bytes(&mut out, rep.method.as_bytes());
    put_usize(&mut out, rep.iters);
    put_f64(&mut out, rep.residual);
    out.push(u8::from(rep.converged));
    put_u64(&mut out, rep.bytes_sent);
    put_u64(&mut out, rep.reduce_rounds);
    put_u64(&mut out, rep.peak_bytes);
    let t = &rep.transport;
    put_u64(&mut out, t.wire_bytes);
    put_u64(&mut out, t.wire_msgs);
    put_u64(&mut out, t.doorbell_waits);
    put_f64(&mut out, t.doorbell_p50_us);
    put_f64(&mut out, t.doorbell_p99_us);
    put_f64(&mut out, t.doorbell_max_us);
    out
}

pub fn decode_report(buf: &[u8]) -> Result<DistSolveReport> {
    let mut r = Reader::new(buf);
    let x_own = r.f64s()?;
    let method_bytes = r.bytes()?;
    let method = method_name_from(&method_bytes);
    let iters = r.usz()?;
    let residual = r.f64()?;
    let converged = r.byte()? != 0;
    let bytes_sent = r.u64()?;
    let reduce_rounds = r.u64()?;
    let peak_bytes = r.u64()?;
    let transport = TransportStats {
        wire_bytes: r.u64()?,
        wire_msgs: r.u64()?,
        doorbell_waits: r.u64()?,
        doorbell_p50_us: r.f64()?,
        doorbell_p99_us: r.f64()?,
        doorbell_max_us: r.f64()?,
    };
    Ok(DistSolveReport {
        x_own,
        method,
        iters,
        residual,
        converged,
        bytes_sent,
        reduce_rounds,
        peak_bytes,
        transport,
    })
}

/// Tagged data frame for the point-to-point transports:
/// `[tag u64][len u64][payload f64 bits...]`.
pub fn encode_data_frame(tag: u64, data: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + data.len() * 8);
    put_u64(&mut out, tag);
    put_usize(&mut out, data.len());
    for x in data {
        put_u64(&mut out, x.to_bits());
    }
    out
}

/// Decode a data-frame payload (everything after the 16-byte header).
pub fn decode_payload(bytes: &[u8]) -> Result<Vec<f64>> {
    if bytes.len() % 8 != 0 {
        return Err(proto_err("payload not f64-aligned"));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| {
            let arr: [u8; 8] = c.try_into().unwrap_or([0; 8]); // rsla-lint: allow(L1, chunks_exact(8) yields exactly 8 bytes)
            f64::from_bits(u64::from_le_bytes(arr))
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::sparse::poisson::poisson2d;
    use crate::util::Prng;

    #[test]
    fn job_roundtrip_is_bitwise() {
        let sys = poisson2d(8, None);
        let part = partition(&sys.matrix, None, 3, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let shares = crate::distributed::halo::distribute(&a_perm, &part);
        let mut rng = Prng::new(1);
        for (p, share) in shares.iter().enumerate() {
            let b: Vec<f64> = rng.normal_vec(share.plan.n_own);
            let opts = DistIterOpts {
                tol: 3.5e-9,
                max_iters: 1234,
                precond: DistPrecondKind::BlockLu,
                method: DistMethod::CaCg { s: 4 },
                ..Default::default()
            };
            let blob = encode_job(share, &b, true, 77, &opts);
            let job = decode_job(&blob).unwrap();
            assert_eq!(job.share.plan.rank, p);
            assert_eq!(job.share.local.vals, share.local.vals);
            assert_eq!(job.share.local.indptr, share.local.indptr);
            assert_eq!(job.share.plan.halo_globals, share.plan.halo_globals);
            for (x, y) in job.b_own.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert!(job.spd);
            assert_eq!(job.restart, 77);
            assert_eq!(job.opts.tol.to_bits(), 3.5e-9f64.to_bits());
            assert_eq!(job.opts.max_iters, 1234);
            assert_eq!(job.opts.precond, DistPrecondKind::BlockLu);
            assert_eq!(job.opts.method, DistMethod::CaCg { s: 4 });
        }
    }

    #[test]
    fn report_roundtrip_is_bitwise() {
        let rep = DistSolveReport {
            x_own: vec![1.5, -2.25e-300, f64::MIN_POSITIVE],
            method: "ca-cg+fallback",
            iters: 42,
            residual: 7.125e-11,
            converged: true,
            bytes_sent: 9001,
            reduce_rounds: 17,
            peak_bytes: 1 << 20,
            transport: TransportStats {
                wire_bytes: 12345,
                wire_msgs: 67,
                doorbell_waits: 8,
                doorbell_p50_us: 1.5,
                doorbell_p99_us: 220.0,
                doorbell_max_us: 400.25,
            },
        };
        let back = decode_report(&encode_report(&rep)).unwrap();
        assert_eq!(back.method, rep.method);
        assert_eq!(back.iters, rep.iters);
        assert_eq!(back.residual.to_bits(), rep.residual.to_bits());
        assert_eq!(back.converged, rep.converged);
        assert_eq!(back.bytes_sent, rep.bytes_sent);
        assert_eq!(back.reduce_rounds, rep.reduce_rounds);
        assert_eq!(back.peak_bytes, rep.peak_bytes);
        assert_eq!(back.transport, rep.transport);
        for (x, y) in back.x_own.iter().zip(&rep.x_own) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn truncated_frames_are_typed_errors_not_panics() {
        let rep = DistSolveReport {
            x_own: vec![1.0; 10],
            method: "cg",
            iters: 1,
            residual: 0.0,
            converged: true,
            bytes_sent: 0,
            reduce_rounds: 0,
            peak_bytes: 0,
            transport: TransportStats::default(),
        };
        let blob = encode_report(&rep);
        for cut in [0usize, 1, 7, 8, blob.len() - 1] {
            let r = decode_report(&blob[..cut]);
            assert!(r.is_err(), "cut={cut} must fail");
        }
        assert!(decode_job(&[0u8; 4]).is_err());
    }
}
