//! Localhost-socket transport: a full mesh of Unix-domain streams,
//! the fallback for hosts where `/dev/shm` rings are unavailable (or
//! for debugging the shm path against an independent implementation).
//!
//! Every rank binds `rank<r>.sock` in the session directory BEFORE the
//! control-plane hello, so by the time any solve traffic flows all
//! listeners exist; outgoing streams are then connected lazily (with a
//! retry loop as a second line of defense).  The first 8 bytes on any
//! accepted stream are the sender's rank (little-endian), after which
//! the stream carries tagged data frames.  Streams are per ordered
//! pair, so per-peer FIFO holds and no demultiplexing is needed beyond
//! the hello.

use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

const ACCEPT_POLL: Duration = Duration::from_millis(2);
const CONNECT_RETRY: Duration = Duration::from_millis(5);
const READ_TICK: Duration = Duration::from_millis(100);

pub fn sock_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("rank{rank}.sock"))
}

fn timeout_err(what: &str, peer: usize) -> Error {
    Error::Distributed(format!(
        "socket transport: deadline exceeded while {what} (peer rank {peer})"
    ))
}

/// Blocking-with-deadline exact read; returns microseconds spent
/// blocked (the socket analogue of a doorbell wait).  The stream must
/// have a finite read timeout so each blocked `read` wakes up to check
/// the deadline.
fn read_exact_deadline(
    s: &mut UnixStream,
    buf: &mut [u8],
    deadline: Instant,
    peer: usize,
) -> Result<u64> {
    let mut rest: &mut [u8] = buf;
    let mut waited_us = 0u64;
    while !rest.is_empty() {
        let t0 = Instant::now();
        match s.read(rest) {
            Ok(0) => {
                return Err(Error::Distributed(format!(
                    "socket transport: peer rank {peer} closed the stream mid-frame"
                )))
            }
            Ok(n) => {
                let n = n.min(rest.len());
                let (_, next) = std::mem::take(&mut rest).split_at_mut(n);
                rest = next;
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                waited_us += t0.elapsed().as_micros() as u64;
                if Instant::now() >= deadline {
                    return Err(timeout_err("awaiting frame bytes", peer));
                }
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(waited_us)
}

/// One rank's endpoint of the socket mesh.
pub struct SocketMesh {
    rank: usize,
    listener: UnixListener,
    dir: PathBuf,
    /// Outgoing streams, indexed by destination rank (lazy connect).
    out: Vec<Option<UnixStream>>,
    /// Incoming streams, indexed by source rank (filled by accept).
    inc: Vec<Option<UnixStream>>,
}

impl SocketMesh {
    /// Bind this rank's listener.  MUST happen before the control-plane
    /// hello so peers never race the bind.
    pub fn bind(rank: usize, nranks: usize, dir: &Path) -> Result<Self> {
        let path = sock_path(dir, rank);
        let listener = UnixListener::bind(&path).map_err(|e| {
            Error::Distributed(format!("socket transport: bind {}: {e}", path.display()))
        })?;
        listener.set_nonblocking(true)?;
        Ok(SocketMesh {
            rank,
            listener,
            dir: dir.to_path_buf(),
            out: (0..nranks).map(|_| None).collect(),
            inc: (0..nranks).map(|_| None).collect(),
        })
    }

    fn connect(&mut self, to: usize, deadline: Instant) -> Result<&mut UnixStream> {
        let rank = self.rank;
        let path = sock_path(&self.dir, to);
        let slot = self
            .out
            .get_mut(to)
            .ok_or_else(|| Error::Distributed(format!("socket transport: no rank {to}")))?;
        while slot.is_none() {
            match UnixStream::connect(&path) {
                Ok(mut s) => {
                    s.write_all(&(rank as u64).to_le_bytes())?;
                    *slot = Some(s);
                }
                Err(_) if Instant::now() < deadline => std::thread::sleep(CONNECT_RETRY),
                Err(e) => {
                    return Err(Error::Distributed(format!(
                        "socket transport: connect {}: {e}",
                        path.display()
                    )))
                }
            }
        }
        slot.as_mut()
            .ok_or_else(|| Error::Distributed("socket transport: lost stream".into()))
    }

    /// Send one pre-encoded frame to `to`.
    pub fn send_bytes(&mut self, to: usize, frame: &[u8], deadline: Instant) -> Result<u64> {
        let s = self.connect(to, deadline)?;
        // blocking write: a dead peer surfaces as EPIPE (Rust ignores
        // SIGPIPE), which the worker converts into its own death and
        // the parent into RankDead
        s.write_all(frame)?;
        Ok(0)
    }

    /// Accept pending connections until a stream from `from` exists.
    fn ensure_incoming(&mut self, from: usize, deadline: Instant) -> Result<u64> {
        let mut waited_us = 0u64;
        loop {
            let have = self
                .inc
                .get(from)
                .ok_or_else(|| Error::Distributed(format!("socket transport: no rank {from}")))?
                .is_some();
            if have {
                return Ok(waited_us);
            }
            match self.listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    s.set_read_timeout(Some(READ_TICK))?;
                    let mut hello = [0u8; 8];
                    read_exact_deadline(&mut s, &mut hello, deadline, usize::MAX)?;
                    let peer = u64::from_le_bytes(hello) as usize;
                    let slot = self.inc.get_mut(peer).ok_or_else(|| {
                        Error::Distributed(format!(
                            "socket transport: hello from unknown rank {peer}"
                        ))
                    })?;
                    *slot = Some(s);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(timeout_err("awaiting connection", from));
                    }
                    waited_us += ACCEPT_POLL.as_micros() as u64;
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
    }

    /// Exact read from the stream owned by `from`; accepts pending
    /// connections as needed.  Returns microseconds spent blocked.
    pub fn recv_bytes(&mut self, from: usize, buf: &mut [u8], deadline: Instant) -> Result<u64> {
        let mut waited_us = self.ensure_incoming(from, deadline)?;
        let s = self
            .inc
            .get_mut(from)
            .and_then(|o| o.as_mut())
            .ok_or_else(|| Error::Distributed(format!("socket transport: no stream {from}")))?;
        waited_us += read_exact_deadline(s, buf, deadline, from)?;
        Ok(waited_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rsla-sock-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn far() -> Instant {
        Instant::now() + Duration::from_secs(30)
    }

    #[test]
    fn two_endpoint_roundtrip_both_directions() {
        let dir = tmp_dir("pair");
        let mut a = SocketMesh::bind(0, 2, &dir).unwrap();
        let d2 = dir.clone();
        let t = std::thread::spawn(move || {
            let mut b = SocketMesh::bind(1, 2, &d2).unwrap();
            let mut buf = [0u8; 24];
            b.recv_bytes(0, &mut buf, far()).unwrap();
            // echo back reversed
            let rev: Vec<u8> = buf.iter().rev().copied().collect();
            b.send_bytes(0, &rev, far()).unwrap();
        });
        let msg: Vec<u8> = (0..24u8).collect();
        a.send_bytes(1, &msg, far()).unwrap();
        let mut back = [0u8; 24];
        a.recv_bytes(1, &mut back, far()).unwrap();
        t.join().unwrap();
        let want: Vec<u8> = (0..24u8).rev().collect();
        assert_eq!(back.to_vec(), want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recv_deadline_is_typed_error_not_hang() {
        let dir = tmp_dir("dead");
        let mut a = SocketMesh::bind(0, 2, &dir).unwrap();
        let mut buf = [0u8; 8];
        let soon = Instant::now() + Duration::from_millis(60);
        let t0 = Instant::now();
        assert!(a.recv_bytes(1, &mut buf, soon).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
