//! `DSparseTensor` / `DSparseTensorList`: the distributed typed API
//! (paper §3.1's bottom row).  In this testbed the ranks are in-process
//! threads, so the tensor owns all partitions and `solve`/`matvec`/
//! `eigsh` spawn the rank team internally; `gather_global` is the
//! paper's utility of the same name.

use std::sync::Arc;

use super::comm::run_ranks;
use super::dist_solver::{
    auto_restart, dist_cg, dist_cg_ca, dist_cg_pipelined, dist_gmres, dist_lobpcg,
    dist_solve_adjoint, DistIterOpts, DistMethod, DistSolveReport,
};
use super::halo::{dist_spmv, distribute, DistCsr};
use super::partition::{partition, Partition, PartitionStrategy};
use super::transport::{proc_solve, CommBackend};
use crate::error::{Error, Result};
use crate::sparse::Csr;

/// A matrix partitioned across P (simulated) ranks.
#[derive(Clone)]
pub struct DSparseTensor {
    part: Arc<Partition>,
    shares: Arc<Vec<DistCsr>>,
    /// whether the (global) matrix is SPD-like, decided at build time.
    spd: bool,
    n: usize,
}

impl DSparseTensor {
    /// Partition a global matrix (paper: `DSparseTensor.from_global`).
    pub fn from_global(
        a: &Csr,
        coords: Option<&[(f64, f64)]>,
        nparts: usize,
        strategy: PartitionStrategy,
    ) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("needs square matrix".into()));
        }
        if nparts == 0 || nparts > a.nrows {
            return Err(Error::InvalidProblem(format!(
                "bad partition count {nparts} for n={}",
                a.nrows
            )));
        }
        let part = partition(a, coords, nparts, strategy);
        let a_perm = a.permute_sym(&part.perm);
        let shares = distribute(&a_perm, &part);
        Ok(DSparseTensor {
            spd: a.looks_spd(),
            n: a.nrows,
            part: Arc::new(part),
            shares: Arc::new(shares),
        })
    }

    pub fn nparts(&self) -> usize {
        self.part.nparts
    }

    pub fn nrows(&self) -> usize {
        self.n
    }

    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Per-rank matrix bytes (the "Mem./GPU" column of Table 4).
    pub fn bytes_per_rank(&self) -> Vec<u64> {
        self.shares.iter().map(|s| s.bytes()).collect()
    }

    /// Scatter a global vector into per-rank slices (permuted space).
    pub fn scatter(&self, x: &[f64]) -> Vec<Vec<f64>> {
        (0..self.nparts())
            .map(|p| {
                self.part
                    .rank_range(p)
                    .map(|new| x[self.part.perm[new]])
                    .collect()
            })
            .collect()
    }

    /// Gather per-rank slices back into a global vector (paper:
    /// `gather_global`).
    pub fn gather_global(&self, slices: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        for (p, slice) in slices.iter().enumerate() {
            for (i, new) in self.part.rank_range(p).enumerate() {
                out[self.part.perm[new]] = slice[i];
            }
        }
        out
    }

    /// Distributed matvec on a global vector (spawns the rank team).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let xs = self.scatter(x);
        let shares = self.shares.clone();
        let xs = Arc::new(xs);
        let results = run_ranks(self.nparts(), move |c| {
            let p = c.rank();
            let share = &shares[p];
            let mut x_ext = vec![0.0; share.plan.n_own + share.plan.n_halo()];
            x_ext[..share.plan.n_own].copy_from_slice(&xs[p]);
            let mut y = vec![0.0; share.plan.n_own];
            dist_spmv(share, &mut x_ext, &mut y, &c, 1);
            y
        });
        self.gather_global(&results)
    }

    /// Distributed solve with a global RHS; returns the global solution
    /// and the per-rank reports (iters/residual/bytes identical across
    /// ranks except for communication volume).
    pub fn solve(&self, b: &[f64], opts: &DistIterOpts) -> Result<(Vec<f64>, Vec<DistSolveReport>)> {
        if b.len() != self.n {
            return Err(Error::InvalidProblem("rhs length mismatch".into()));
        }
        let spd = self.spd;
        // SPD systems run CG (standard, pipelined, or s-step CA-CG,
        // per `opts.method`); everything else (nonsymmetric OR
        // symmetric-indefinite) routes to restarted GMRES with an
        // automatically selected restart length — the workhorse that
        // handles both, instead of hoping BiCGStab's recurrence holds.
        // `opts.backend` picks the rank team: in-process threads over
        // LocalComm, or spawned worker processes over ProcComm — the
        // canonical reduction order makes the two bitwise identical.
        let restart = auto_restart(self.n);
        let reports = match &opts.backend {
            CommBackend::Proc(popts) => {
                proc_solve(&self.shares, &self.scatter(b), spd, restart, opts, popts)?
            }
            CommBackend::Local => {
                let bs = Arc::new(self.scatter(b));
                let shares = self.shares.clone();
                let opts = opts.clone();
                run_ranks(self.nparts(), move |c| {
                    let p = c.rank();
                    if !spd {
                        return dist_gmres(&shares[p], &bs[p], restart, &c, &opts);
                    }
                    match &opts.method {
                        DistMethod::Auto | DistMethod::Cg => {
                            dist_cg(&shares[p], &bs[p], &c, &opts)
                        }
                        DistMethod::CgPipelined => {
                            dist_cg_pipelined(&shares[p], &bs[p], &c, &opts)
                        }
                        DistMethod::CaCg { s } => {
                            let mut ca = crate::krylov::CaCgOpts::default();
                            if *s > 0 {
                                ca.s = *s;
                            }
                            dist_cg_ca(&shares[p], &bs[p], &c, &opts, &ca)
                        }
                    }
                })
            }
        };
        let x = self.gather_global(
            &reports
                .iter()
                .map(|r| r.x_own.clone())
                .collect::<Vec<_>>(),
        );
        Ok((x, reports))
    }

    /// Distributed differentiable solve: forward + adjoint + matrix
    /// gradient in one rank-team launch (paper §3.3 composition).
    /// Returns (x, dL/db, dL/dA as global COO triplets).
    #[allow(clippy::type_complexity)]
    pub fn solve_adjoint(
        &self,
        b: &[f64],
        gy: &[f64],
        opts: &DistIterOpts,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<(usize, usize, f64)>)> {
        if !self.spd {
            return Err(Error::InvalidProblem(
                "distributed adjoint path currently requires SPD".into(),
            ));
        }
        let bs = Arc::new(self.scatter(b));
        let gs = Arc::new(self.scatter(gy));
        let shares = self.shares.clone();
        let opts = opts.clone();
        let results = run_ranks(self.nparts(), move |c| {
            let p = c.rank();
            dist_solve_adjoint(&shares[p], &bs[p], &gs[p], &c, &opts)
        });
        let x = self.gather_global(&results.iter().map(|r| r.x_own.clone()).collect::<Vec<_>>());
        let db = self.gather_global(&results.iter().map(|r| r.db_own.clone()).collect::<Vec<_>>());
        // assemble global (old-space) matrix-gradient triplets
        let mut triplets = Vec::new();
        for (p, res) in results.iter().enumerate() {
            let share = &self.shares[p];
            let range = self.part.rank_range(p);
            for r_local in 0..share.plan.n_own {
                let r_new = range.start + r_local;
                for kk in share.local.indptr[r_local]..share.local.indptr[r_local + 1] {
                    let lc = share.local.indices[kk];
                    let c_new = if lc < share.plan.n_own {
                        range.start + lc
                    } else {
                        share.plan.halo_globals[lc - share.plan.n_own]
                    };
                    triplets.push((
                        self.part.perm[r_new],
                        self.part.perm[c_new],
                        res.dvals_own[kk],
                    ));
                }
            }
        }
        Ok((x, db, triplets))
    }

    /// Distributed k smallest eigenvalues (dist-LOBPCG).
    pub fn eigsh(&self, k: usize, tol: f64, max_iters: usize) -> Result<Vec<f64>> {
        if !self.spd {
            return Err(Error::InvalidProblem("eigsh needs symmetric".into()));
        }
        let shares = self.shares.clone();
        let vals = run_ranks(self.nparts(), move |c| {
            let p = c.rank();
            let (values, _, _) = dist_lobpcg(&shares[p], k, &c, tol, max_iters, 11);
            values
        });
        Ok(vals[0].clone())
    }

    /// `det` does not distribute (paper §3.3 "Scope of distributed
    /// gradients"): gather everything onto rank 0 and warn.
    pub fn det_gathered(&self, global: &Csr) -> Result<f64> {
        log::warn!(
            "DSparseTensor::det gathers all partitions onto one rank; this does not scale (see paper §3.3)"
        );
        let f = crate::direct::SparseLu::factor(global)?;
        let (sign, logabs) = f.slogdet();
        Ok(sign * logabs.exp())
    }
}

/// Distributed batch over distinct patterns: each element is its own
/// DSparseTensor (solved sequentially; each spawns its own rank team).
pub struct DSparseTensorList {
    pub items: Vec<DSparseTensor>,
}

impl DSparseTensorList {
    pub fn from_globals(
        mats: &[Csr],
        nparts: usize,
        strategy: PartitionStrategy,
    ) -> Result<Self> {
        Ok(DSparseTensorList {
            items: mats
                .iter()
                .map(|m| DSparseTensor::from_global(m, None, nparts, strategy))
                .collect::<Result<Vec<_>>>()?,
        })
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn solve(&self, bs: &[Vec<f64>], opts: &DistIterOpts) -> Result<Vec<Vec<f64>>> {
        if bs.len() != self.items.len() {
            return Err(Error::InvalidProblem("rhs count mismatch".into()));
        }
        self.items
            .iter()
            .zip(bs)
            .map(|(t, b)| Ok(t.solve(b, opts)?.0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn from_global_solve_gather() {
        let g = 14;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let t = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            4,
            PartitionStrategy::Rcb,
        )
        .unwrap();
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let (x, reports) = t.solve(&b, &DistIterOpts::default()).unwrap();
        assert!(reports.iter().all(|r| r.converged));
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-8);
    }

    #[test]
    fn matvec_matches_serial() {
        let g = 10;
        let sys = poisson2d(g, None);
        let t =
            DSparseTensor::from_global(&sys.matrix, None, 3, PartitionStrategy::Contiguous)
                .unwrap();
        let mut rng = Prng::new(1);
        let x = rng.normal_vec(g * g);
        let y = t.matvec(&x);
        assert!(util::max_abs_diff(&y, &sys.matrix.matvec(&x)) < 1e-12);
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let g = 8;
        let sys = poisson2d(g, None);
        let t = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            3,
            PartitionStrategy::Rcb,
        )
        .unwrap();
        let mut rng = Prng::new(2);
        let x = rng.normal_vec(g * g);
        let back = t.gather_global(&t.scatter(&x));
        assert_eq!(back, x);
    }

    #[test]
    fn adjoint_gradients_match_serial() {
        let g = 8;
        let n = g * g;
        let sys = poisson2d(g, None);
        let t =
            DSparseTensor::from_global(&sys.matrix, None, 3, PartitionStrategy::Contiguous)
                .unwrap();
        let mut rng = Prng::new(3);
        let b = rng.normal_vec(n);
        let gy = rng.normal_vec(n);
        let (x, db, dvals) = t
            .solve_adjoint(
                &b,
                &gy,
                &DistIterOpts {
                    tol: 1e-12,
                    max_iters: 20_000,
                ..Default::default()
            },
            )
            .unwrap();
        // serial reference via the tape adjoint
        let x_ref = crate::direct::direct_solve(&sys.matrix, &b).unwrap();
        let lam_ref = crate::direct::direct_solve(&sys.matrix, &gy).unwrap();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6);
        assert!(util::rel_l2(&db, &lam_ref) < 1e-6);
        for &(r, c, v) in dvals.iter().take(50) {
            let want = -lam_ref[r] * x_ref[c];
            assert!((v - want).abs() < 1e-5 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn dist_eigsh() {
        let g = 10;
        let sys = poisson2d(g, None);
        let t =
            DSparseTensor::from_global(&sys.matrix, None, 3, PartitionStrategy::Contiguous)
                .unwrap();
        let vals = t.eigsh(2, 1e-9, 300).unwrap();
        let serial = crate::eigen::lanczos(
            &sys.matrix,
            2,
            crate::eigen::lanczos::Which::Smallest,
            80,
            0,
        );
        for (a, b) in vals.iter().zip(&serial.values) {
            assert!((a - b).abs() < 1e-5 * b);
        }
    }

    #[test]
    fn nonsymmetric_solve_routes_to_gmres_and_matches_serial() {
        // Satellite: the nonsymmetric path must run restarted GMRES
        // (auto restart), not fall back, and a 2-rank solve must match
        // the serial direct solution.
        use crate::sparse::graphs::random_nonsymmetric;
        let mut rng = Prng::new(11);
        let a = random_nonsymmetric(&mut rng, 24, 3);
        assert!(!a.looks_spd());
        let t = DSparseTensor::from_global(&a, None, 2, PartitionStrategy::Contiguous).unwrap();
        let b = rng.normal_vec(24);
        let (x, reports) = t
            .solve(
                &b,
                &DistIterOpts {
                    tol: 1e-10,
                    max_iters: 5_000,
                    ..Default::default()
                },
            )
            .unwrap();
        assert!(
            reports.iter().all(|r| r.method == "gmres"),
            "nonsymmetric solve must route to dist_gmres"
        );
        assert!(reports.iter().all(|r| r.converged));
        let x_ref = crate::direct::direct_solve(&a, &b).unwrap();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6);
        // SPD systems still take CG
        let sys = poisson2d(8, None);
        let t = DSparseTensor::from_global(&sys.matrix, None, 2, PartitionStrategy::Contiguous)
            .unwrap();
        let (_, reports) = t.solve(&vec![1.0; 64], &DistIterOpts::default()).unwrap();
        assert!(reports.iter().all(|r| r.method == "cg"));
    }

    #[test]
    fn list_of_distinct_patterns() {
        let mut rng = Prng::new(4);
        let mats = vec![
            crate::sparse::graphs::random_graph_laplacian(&mut rng, 40, 4, 0.3),
            crate::sparse::graphs::random_graph_laplacian(&mut rng, 60, 3, 0.2),
        ];
        let list = DSparseTensorList::from_globals(&mats, 2, PartitionStrategy::GreedyBfs).unwrap();
        let bs: Vec<Vec<f64>> = mats.iter().map(|m| rng.normal_vec(m.nrows)).collect();
        let xs = list.solve(&bs, &DistIterOpts::default()).unwrap();
        for ((x, b), m) in xs.iter().zip(&bs).zip(&mats) {
            assert!(util::rel_l2(&m.matvec(x), b) < 1e-7);
        }
    }

    #[test]
    fn validates_inputs() {
        let sys = poisson2d(6, None);
        assert!(DSparseTensor::from_global(&sys.matrix, None, 0, PartitionStrategy::Contiguous)
            .is_err());
        let t =
            DSparseTensor::from_global(&sys.matrix, None, 2, PartitionStrategy::Contiguous)
                .unwrap();
        assert!(t.solve(&[1.0; 7], &DistIterOpts::default()).is_err());
    }
}
