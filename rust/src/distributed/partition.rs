//! Partitioners: contiguous row blocks, recursive coordinate bisection
//! (Berger & Bokhari 1987), and a greedy BFS edge-cut reducer (the
//! METIS-lite stand-in).  All three reduce to "permute, then cut into
//! contiguous blocks", which is exactly the row-block ownership the
//! halo plan consumes.

use crate::sparse::Csr;

#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Rows in natural order, split into P equal blocks.
    Contiguous,
    /// Recursive coordinate bisection (needs node coordinates).
    Rcb,
    /// BFS ordering then equal blocks (graph locality without coords).
    GreedyBfs,
}

/// A P-way row partition expressed as a permutation + block offsets:
/// new index i holds old row `perm[i]`; rank p owns new indices
/// `[offsets[p], offsets[p+1])`.
#[derive(Clone, Debug)]
pub struct Partition {
    pub nparts: usize,
    /// new -> old.
    pub perm: Vec<usize>,
    /// old -> new.
    pub inv: Vec<usize>,
    pub offsets: Vec<usize>,
}

impl Partition {
    pub fn owner_of_new(&self, new_idx: usize) -> usize {
        match self.offsets.binary_search(&new_idx) {
            Ok(p) => p.min(self.nparts - 1),
            Err(p) => p - 1,
        }
    }

    pub fn rank_range(&self, p: usize) -> std::ops::Range<usize> {
        self.offsets[p]..self.offsets[p + 1]
    }

    pub fn rank_size(&self, p: usize) -> usize {
        self.offsets[p + 1] - self.offsets[p]
    }

    /// Edge cut: # of (new-index) matrix entries crossing rank blocks.
    pub fn edge_cut(&self, a_permuted: &Csr) -> usize {
        let mut cut = 0;
        for r in 0..a_permuted.nrows {
            let pr = self.owner_of_new(r);
            for &c in a_permuted.row(r).0 {
                if self.owner_of_new(c) != pr {
                    cut += 1;
                }
            }
        }
        cut
    }
}

fn blocks(n: usize, nparts: usize, perm: Vec<usize>) -> Partition {
    let mut inv = vec![0usize; n];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    let mut offsets = Vec::with_capacity(nparts + 1);
    for p in 0..=nparts {
        offsets.push(p * n / nparts);
    }
    Partition {
        nparts,
        perm,
        inv,
        offsets,
    }
}

/// Build a partition of `a` (optionally with coordinates for RCB).
pub fn partition(
    a: &Csr,
    coords: Option<&[(f64, f64)]>,
    nparts: usize,
    strategy: PartitionStrategy,
) -> Partition {
    let n = a.nrows;
    assert!(nparts >= 1 && nparts <= n);
    let perm: Vec<usize> = match strategy {
        PartitionStrategy::Contiguous => (0..n).collect(),
        PartitionStrategy::Rcb => match coords {
            Some(coords) => {
                let mut idx: Vec<usize> = (0..n).collect();
                rcb_sort(&mut idx, coords, nparts, true);
                idx
            }
            // no coordinates: degrade to the coordinate-free strategy
            // with the same locality goal rather than failing the solve
            None => {
                log::warn!("RCB requested without coordinates; using BFS ordering");
                crate::direct::ordering::rcm(a)
            }
        },
        PartitionStrategy::GreedyBfs => {
            // BFS from a min-degree vertex gives banded locality
            let order = crate::direct::ordering::rcm(a);
            order
        }
    };
    blocks(n, nparts, perm)
}

/// Recursively order indices by alternating-axis median splits.
fn rcb_sort(idx: &mut [usize], coords: &[(f64, f64)], parts: usize, split_x: bool) {
    if parts <= 1 || idx.len() <= 1 {
        return;
    }
    let mid = idx.len() * (parts / 2) / parts;
    idx.select_nth_unstable_by(mid, |&a, &b| {
        let ka = if split_x { coords[a].0 } else { coords[a].1 };
        let kb = if split_x { coords[b].0 } else { coords[b].1 };
        ka.total_cmp(&kb)
    });
    let (lo, hi) = idx.split_at_mut(mid);
    rcb_sort(lo, coords, parts / 2, !split_x);
    rcb_sort(hi, coords, parts - parts / 2, !split_x);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;
    use crate::util::proptest::check;

    #[test]
    fn contiguous_covers_all_rows_once() {
        let sys = poisson2d(8, None);
        let p = partition(&sys.matrix, None, 4, PartitionStrategy::Contiguous);
        let mut seen = vec![false; 64];
        for rank in 0..4 {
            for i in p.rank_range(rank) {
                assert!(!seen[p.perm[i]]);
                seen[p.perm[i]] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rcb_beats_contiguous_on_grid_cut() {
        // on a column-major-ish workload contiguous is fine; use RCB on
        // the grid and check the cut is within the 2D surface law
        let g = 16;
        let sys = poisson2d(g, None);
        for strat in [PartitionStrategy::Contiguous, PartitionStrategy::Rcb] {
            let p = partition(&sys.matrix, Some(&sys.coords), 4, strat);
            let ap = sys.matrix.permute_sym(&p.perm);
            let cut = p.edge_cut(&ap);
            // surface ~ 3 cuts of g rows, 2 entries per crossing: O(g)
            assert!(cut <= 8 * g, "{strat:?} cut {cut} too large");
        }
    }

    #[test]
    fn owner_of_new_matches_ranges() {
        let sys = poisson2d(6, None);
        let p = partition(&sys.matrix, None, 3, PartitionStrategy::Contiguous);
        for rank in 0..3 {
            for i in p.rank_range(rank) {
                assert_eq!(p.owner_of_new(i), rank);
            }
        }
    }

    #[test]
    fn property_all_strategies_are_permutations() {
        let g = 10;
        let sys = poisson2d(g, None);
        check("partition is a permutation", 9, |rng| {
            let nparts = 1 + rng.below(6);
            let strat = match rng.below(3) {
                0 => PartitionStrategy::Contiguous,
                1 => PartitionStrategy::Rcb,
                _ => PartitionStrategy::GreedyBfs,
            };
            let p = partition(&sys.matrix, Some(&sys.coords), nparts, strat);
            let mut seen = vec![false; g * g];
            for &old in &p.perm {
                if seen[old] {
                    return Err(format!("row {old} owned twice"));
                }
                seen[old] = true;
            }
            if p.offsets[p.nparts] != g * g {
                return Err("offsets do not cover".into());
            }
            // inv is consistent
            for (new, &old) in p.perm.iter().enumerate() {
                if p.inv[old] != new {
                    return Err("inv mismatch".into());
                }
            }
            Ok(())
        });
    }
}
