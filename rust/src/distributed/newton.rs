//! Distributed matrix-free Newton–Krylov: the rank-local
//! [`KrylovResidual`] implementation for residuals of the form
//!
//! ```text
//! F(u)_i = (A u)_i + g(u_i) - f_i
//! ```
//!
//! (sparse linear part + pointwise nonlinearity — the paper's
//! quadratic-Poisson example is `g(u) = u^2`).  The linear part is the
//! halo-exchanged distributed SpMV (Eq. 5); the nonlinearity and the
//! Jacobian's diagonal correction `g'(u)` are purely local, so
//! `newton_krylov` runs the SAME body it runs serially — each Newton
//! step solved by the generic GMRES kernel with all-reduced inner
//! products, the Jacobian applied matrix-free as `J v = A v + g'(u) v`.
//! No Jacobian is ever assembled, distributed or otherwise.

use std::cell::Cell;

use super::comm::Transport;
use super::halo::{dist_spmv, DistCsr};
use crate::nonlinear::KrylovResidual;

/// One rank's share of `F(u) = A u + g(u) - f`.
pub struct DistPointwiseResidual<'a> {
    a: &'a DistCsr,
    comm: &'a dyn Transport,
    tag: Cell<u64>,
    /// this rank's slice of the forcing term `f`.
    f_own: Vec<f64>,
    /// pointwise nonlinearity: `u_i -> (g(u_i), g'(u_i))`.
    g: fn(f64) -> (f64, f64),
}

impl<'a> DistPointwiseResidual<'a> {
    pub fn new(
        a: &'a DistCsr,
        comm: &'a dyn Transport,
        f_own: Vec<f64>,
        g: fn(f64) -> (f64, f64),
        base_tag: u64,
    ) -> Self {
        assert_eq!(f_own.len(), a.plan.n_own);
        DistPointwiseResidual {
            a,
            comm,
            tag: Cell::new(base_tag),
            f_own,
            g,
        }
    }

    fn next_tag(&self) -> u64 {
        let t = self.tag.get();
        self.tag.set(t + 1);
        t
    }
}

impl KrylovResidual for DistPointwiseResidual<'_> {
    fn n_own(&self) -> usize {
        self.a.plan.n_own
    }

    fn n_ext(&self) -> usize {
        self.a.plan.n_own + self.a.plan.n_halo()
    }

    fn eval(&self, u_ext: &mut [f64], out_own: &mut [f64]) {
        dist_spmv(self.a, u_ext, out_own, self.comm, self.next_tag());
        for i in 0..self.n_own() {
            out_own[i] += (self.g)(u_ext[i]).0 - self.f_own[i];
        }
    }

    fn jv(&self, u_ext: &[f64], v_ext: &mut [f64], y_own: &mut [f64]) {
        dist_spmv(self.a, v_ext, y_own, self.comm, self.next_tag());
        for i in 0..self.n_own() {
            y_own[i] += (self.g)(u_ext[i]).1 * v_ext[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;
    use crate::distributed::halo::distribute;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::iterative::IterOpts;
    use crate::nonlinear::{newton, newton_krylov, NewtonOpts, Residual};
    use crate::sparse::poisson::poisson2d;
    use crate::sparse::{Coo, Csr};
    use crate::util::{self, Prng};
    use std::sync::Arc;

    /// Serial reference: the same residual on the permuted global matrix.
    struct QuadPerm {
        a: Csr,
        f: Vec<f64>,
    }

    impl Residual for QuadPerm {
        fn dim(&self) -> usize {
            self.f.len()
        }

        fn eval(&self, u: &[f64], out: &mut [f64]) {
            self.a.spmv(u, out);
            for i in 0..u.len() {
                out[i] += u[i] * u[i] - self.f[i];
            }
        }

        fn jacobian(&self, u: &[f64]) -> Csr {
            let n = self.a.nrows;
            let mut coo = Coo::with_capacity(n, n, self.a.nnz() + n);
            for r in 0..n {
                let (cols, vals) = self.a.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    coo.push(r, *c, *v);
                }
                coo.push(r, r, 2.0 * u[r]);
            }
            coo.to_csr()
        }
    }

    #[test]
    fn distributed_newton_krylov_matches_serial_newton() {
        let g = 10;
        let n = g * g;
        let nparts = 3;
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        let mut rng = Prng::new(11);
        let f_perm: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.5).collect();

        // serial reference: assembled-Jacobian direct Newton
        let reference = newton(
            &QuadPerm {
                a: a_perm.clone(),
                f: f_perm.clone(),
            },
            &vec![0.0; n],
            &NewtonOpts::default(),
        );
        assert!(reference.converged);

        // distributed matrix-free Newton-Krylov, same permuted space
        let part2 = Arc::new(part);
        let fp = Arc::new(f_perm);
        let outs = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = part2.rank_range(p);
            let res = DistPointwiseResidual::new(
                &parts[p],
                &c,
                fp[range.clone()].to_vec(),
                |u| (u * u, 2.0 * u),
                5_000,
            );
            let out = newton_krylov(
                &res,
                &vec![0.0; range.len()],
                &c,
                &NewtonOpts::default(),
                &IterOpts {
                    tol: 1e-11,
                    max_iters: 2_000,
                    record_history: false,
                },
            );
            (out.u, out.converged, out.iters, out.residual_norm)
        });
        assert!(outs.iter().all(|(_, conv, _, _)| *conv));
        // every rank agrees on the (replicated) iteration count
        assert!(outs.iter().all(|(_, _, it, _)| *it == outs[0].2));
        let u: Vec<f64> = outs.iter().flat_map(|(u, _, _, _)| u.clone()).collect();
        assert!(
            util::max_abs_diff(&u, &reference.u) < 1e-7,
            "distributed NK diverged from serial Newton"
        );
    }
}
