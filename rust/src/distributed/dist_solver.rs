//! Distributed Krylov entry points (paper §3.3 + Appendix C Algorithm
//! 1) and the distributed adjoint solve.
//!
//! Every recurrence lives in [`crate::krylov`], written once over
//! `LinearOperator x Communicator`; this module only assembles the
//! distributed instantiation — a [`DistOp`] (halo-exchanged SpMV over
//! the rank's share) paired with the rank team's [`LocalComm`] — builds
//! the rank-local preconditioner, and packages the per-rank report
//! (bytes sent, reduction rounds, peak working set).
//!
//! Communication structure per CG iteration: ONE halo exchange (inside
//! the operator apply) and TWO reduction rounds (`<p,Ap>` plus the
//! fused `<r,z>`/`<r,r>` pair) — exactly the paper's Algorithm 1,
//! pinned by the counter test below.  Pipelined CG costs ONE fused
//! round per iteration; s-step CA-CG ([`dist_cg_ca`]) costs ONE packed
//! round per OUTER step of s iterations, ~1/s rounds per iteration.
//!
//! Every entry point is generic over [`Transport`], so the same code
//! serves in-process [`super::comm::LocalComm`] rank teams and
//! process-separated [`super::transport::ProcComm`] workers; the
//! canonical rank-ascending reduction order makes the two backends
//! bitwise interchangeable.

use std::sync::Arc;

use super::comm::{Transport, TransportStats};
use super::halo::DistCsr;
use super::op::DistOp;
use super::transport::CommBackend;
use crate::direct::CachedFactor;
use crate::factor_cache::FactorCache;
use crate::iterative::{Amg, AmgOpts, IterOpts, IterResult, Jacobi, Precond};
use crate::krylov::{self, LinearOperator};
use crate::metrics::{MemTracker, Registry};
use crate::util::lock_recover;

/// Preconditioner for the distributed Krylov loops.  Application is
/// purely LOCAL (no communication), so every variant composes with the
/// transposed-halo backward pass unchanged.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum DistPrecondKind {
    /// Pointwise Jacobi — the paper's only option (§5), kept as the
    /// parity default.
    #[default]
    Jacobi,
    /// One-level additive Schwarz with an AMG V-cycle on each rank's
    /// owned diagonal block — the §5 "stronger preconditioner (e.g.
    /// algebraic multigrid)" future-work item, implemented.
    BlockAmg,
    /// One-level additive Schwarz with an EXACT direct solve of each
    /// rank's owned diagonal block, served through the process-wide
    /// pattern-keyed factor cache: warm distributed solves (training
    /// loops, repeated adjoints) skip the local refactorization
    /// entirely — one numeric factorization per (rank, pattern,
    /// values), pinned by a counter test.
    BlockLu,
}

/// Which Krylov kernel a distributed SPD solve routes to.  Nonsymmetric
/// systems always use GMRES regardless of this field.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum DistMethod {
    /// Historical routing: SPD -> standard CG, otherwise restarted
    /// GMRES.
    #[default]
    Auto,
    /// Two-reduction standard CG.
    Cg,
    /// Single-reduction (Chronopoulos–Gear) CG.
    CgPipelined,
    /// s-step communication-avoiding CG: ONE packed reduction per s
    /// iterations (see [`crate::krylov::ca_cg`]).  `s == 0` means the
    /// [`crate::krylov::CaCgOpts`] default.
    CaCg { s: usize },
}

#[derive(Clone, Debug)]
pub struct DistIterOpts {
    pub tol: f64,
    pub max_iters: usize,
    /// Rank-local preconditioner for CG / pipelined CG / BiCGStab /
    /// GMRES.  [`dist_minres`] ignores this field (it needs an SPD `M`;
    /// see its docs).
    pub precond: DistPrecondKind,
    /// SPD kernel selection for `DSparseTensor::solve`.
    pub method: DistMethod,
    /// Rank-team execution backend for `DSparseTensor::solve`: thread
    /// ranks in-process (default) or spawned worker processes over the
    /// shared-memory/socket transport.
    pub backend: CommBackend,
}

impl Default for DistIterOpts {
    fn default() -> Self {
        DistIterOpts {
            tol: 1e-10,
            max_iters: 10_000,
            precond: DistPrecondKind::Jacobi,
            method: DistMethod::Auto,
            backend: CommBackend::Local,
        }
    }
}

fn iter_opts(opts: &DistIterOpts) -> IterOpts {
    IterOpts {
        tol: opts.tol,
        max_iters: opts.max_iters,
        record_history: false,
    }
}

fn jacobi_of(block_diag: impl Iterator<Item = f64>) -> Box<dyn Precond> {
    let diag: Vec<f64> = block_diag
        .map(|d| if d != 0.0 { d } else { 1.0 })
        .collect();
    Box::new(Jacobi::from_diag(&diag))
}

/// Exact additive-Schwarz block application `z = A_pp^{-1} r`, the
/// factorization held by (and shared through) the factor cache.  The
/// triangular sweeps run through `solve_into` with a reused scratch
/// buffer, so a warm application performs NO heap allocation — pinned
/// by the `factor_solve_alloc_bytes` metric in the serve bench.
struct BlockDirect {
    factor: Arc<CachedFactor>,
    scratch: std::sync::Mutex<Vec<f64>>,
}

impl Precond for BlockDirect {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let mut scratch = lock_recover(&self.scratch);
        match self.factor.solve_into(r, z, &mut scratch) {
            Ok(()) => {}
            // a breakdown here means the block factor went stale in a
            // way the cache could not see; fall back to identity rather
            // than poisoning the Krylov iterate with garbage — but SAY
            // SO, because a varying M breaks CG's fixed-preconditioner
            // assumption and the solve quality signal must not vanish
            Err(e) => {
                log::warn!("BlockDirect precondition solve failed ({e}); applying identity");
                z.copy_from_slice(r);
            }
        }
    }
}

/// Build the local (per-rank) preconditioner over the owned diagonal
/// block of the share.  Direct block factorizations go through `cache`
/// (the wrappers pass the process-wide one), so repeated solves on the
/// same share — warm training loops, forward+adjoint pairs — reuse ONE
/// numeric factorization per (rank, pattern, values) instead of
/// refactoring per call.
pub(crate) fn build_precond(
    a: &DistCsr,
    kind: &DistPrecondKind,
    cache: &FactorCache,
    reg: Option<&Registry>,
) -> Box<dyn Precond> {
    let n_own = a.plan.n_own;
    match kind {
        DistPrecondKind::Jacobi => jacobi_of((0..n_own).map(|r| a.local.get(r, r))),
        DistPrecondKind::BlockAmg => {
            // the owned diagonal block is extracted once per share and
            // cached on it (warm rebuilds skip the O(nnz) extraction)
            let block = a.owned_diag_block();
            // AMG's coarse-grid factorization flows through the
            // process-wide factor cache inside Amg::new.
            match Amg::new(&block, &AmgOpts::default()) {
                Ok(amg) => Box::new(amg),
                Err(_) => {
                    // degenerate block: fall back to Jacobi
                    jacobi_of((0..n_own).map(|r| block.get(r, r)))
                }
            }
        }
        DistPrecondKind::BlockLu => {
            // generous but FINITE fill budget (mirrors the default host
            // budget): a pathological-fill block trips OutOfMemory and
            // degrades to Jacobi instead of exhausting host memory
            const BLOCK_FACTOR_BUDGET_BYTES: u64 = 8 << 30;
            let block = a.owned_diag_block();
            match cache.factor(&block, BLOCK_FACTOR_BUDGET_BYTES, reg) {
                Ok(factor) => Box::new(BlockDirect {
                    factor,
                    scratch: std::sync::Mutex::new(Vec::new()),
                }),
                Err(_) => jacobi_of((0..n_own).map(|r| block.get(r, r))),
            }
        }
    }
}

/// Per-rank report after a distributed solve.
#[derive(Clone, Debug)]
pub struct DistSolveReport {
    pub x_own: Vec<f64>,
    /// Which Krylov kernel served the solve ("cg", "cg-pipelined",
    /// "bicgstab", "gmres", "minres") — the routing decision of
    /// `DSparseTensor::solve` is observable, not inferred.
    pub method: &'static str,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
    /// Bytes this rank sent during the solve.
    pub bytes_sent: u64,
    /// Reduction ROUNDS (team-wide latency units) this solve consumed:
    /// a fused multi-scalar all_reduce counts one.
    pub reduce_rounds: u64,
    /// Peak per-rank working set (matrix share + solver vectors).
    pub peak_bytes: u64,
    /// Wire-level transport stats at solve completion (endpoint
    /// lifetime, not per-solve deltas: the doorbell percentiles are not
    /// delta-able).  Zeros for in-process backends; for ProcComm
    /// workers a process serves exactly one solve, so lifetime ==
    /// solve.
    pub transport: TransportStats,
}

/// Run one generic kernel over (share, comm) and package the report.
fn run_dist<C: Transport>(
    a: &DistCsr,
    comm: &C,
    method: &'static str,
    kernel: impl FnOnce(&dyn LinearOperator, &MemTracker) -> IterResult,
) -> DistSolveReport {
    let bytes0 = comm.bytes_sent();
    let rounds0 = comm.reduce_rounds();
    let mem = MemTracker::new();
    let op = DistOp::new(a, comm, 100);
    let _sp = crate::trace::span_arg(crate::trace::names::DIST_SOLVE, a.plan.n_own as u64);
    let ct = crate::trace::ConvergenceTrace::new(crate::trace::names::DIST_SOLVE);
    let res = kernel(&op, &mem);
    // snapshot the monotonic counters ONCE: the report and the trace
    // record must agree on what this solve cost
    let bytes_sent = comm.bytes_sent() - bytes0;
    let reduce_rounds = comm.reduce_rounds() - rounds0;
    ct.finish_dist(res.iters, res.residual, res.converged, reduce_rounds, bytes_sent);
    DistSolveReport {
        x_own: res.x,
        method,
        iters: res.iters,
        residual: res.residual,
        converged: res.converged,
        bytes_sent,
        reduce_rounds,
        peak_bytes: a.bytes() + mem.peak(),
        transport: comm.transport_stats(),
    }
}

/// Restart length for [`dist_gmres`] when the caller does not pin one:
/// grows like sqrt(n) (deeper Krylov spaces pay off on larger systems)
/// but stays within [30, 200] so per-iteration orthogonalization cost
/// and basis storage remain bounded; tiny systems use n (full GMRES).
pub fn auto_restart(n_global: usize) -> usize {
    n_global.min(((n_global as f64).sqrt().ceil() as usize).clamp(30, 200))
}

/// Distributed preconditioned CG; runs inside one rank's thread.
/// `b_own` is this rank's slice of the RHS.
pub fn dist_cg<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    let m = build_precond(a, &opts.precond, FactorCache::global(), None);
    run_dist(a, comm, "cg", |op, mem| {
        krylov::cg(op, b_own, &*m, comm, &iter_opts(opts), Some(mem))
    })
}

/// Single-reduction distributed CG (Chronopoulos & Gear 1989; the
/// "pipelined / communication-avoiding CG" roadmap item of Appendix C):
/// algebraically equivalent to [`dist_cg`] with the per-iteration
/// reductions fused into ONE round.
pub fn dist_cg_pipelined<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    let m = build_precond(a, &opts.precond, FactorCache::global(), None);
    run_dist(a, comm, "cg-pipelined", |op, mem| {
        krylov::cg_pipelined(op, b_own, &*m, comm, &iter_opts(opts), Some(mem))
    })
}

/// s-step communication-avoiding distributed CG (Appendix C roadmap,
/// pushed past pipelining): ONE packed reduction per outer step of `s`
/// iterations — the Gram matrix, cross-block couplings, projections,
/// and the residual norm all ride a single `all_reduce`, cutting
/// reduction ROUNDS from ~2/iter (standard CG) toward ~1/s per iter.
/// The residual-replacement guard inside [`krylov::ca_cg`] falls back
/// to standard CG when finite-precision drift is detected, in which
/// case the report's method reads `"ca-cg+fallback"`.
pub fn dist_cg_ca<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
    ca: &krylov::CaCgOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    let m = build_precond(a, &opts.precond, FactorCache::global(), None);
    let detail = std::cell::Cell::new((0usize, false));
    let mut rep = run_dist(a, comm, "ca-cg", |op, mem| {
        let r = krylov::ca_cg(op, b_own, &*m, comm, &iter_opts(opts), ca, Some(mem));
        detail.set((r.replacements, r.fell_back));
        r.iter
    });
    let (replacements, fell_back) = detail.get();
    if replacements > 0 {
        Registry::global().incr(crate::metrics::names::KRYLOV_CA_REPLACEMENTS, replacements as u64);
    }
    if fell_back {
        rep.method = "ca-cg+fallback";
        Registry::global().incr(crate::metrics::names::KRYLOV_CA_FALLBACKS, 1);
    }
    rep
}

/// Distributed BiCGStab for general systems (same halo/reduce template).
pub fn dist_bicgstab<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    let m = build_precond(a, &opts.precond, FactorCache::global(), None);
    run_dist(a, comm, "bicgstab", |op, mem| {
        krylov::bicgstab(op, b_own, &*m, comm, &iter_opts(opts), Some(mem))
    })
}

/// Distributed restarted GMRES(m) — the nonsymmetric/indefinite
/// workhorse at rank-team scale (a scenario family the serial-only
/// wrapper could not serve).
pub fn dist_gmres<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    restart: usize,
    comm: &C,
    opts: &DistIterOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    let m = build_precond(a, &opts.precond, FactorCache::global(), None);
    run_dist(a, comm, "gmres", |op, mem| {
        krylov::gmres(op, b_own, &*m, restart, comm, &iter_opts(opts), Some(mem))
    })
}

/// Distributed MINRES for symmetric (possibly indefinite) systems.
///
/// Always UNPRECONDITIONED: `opts.precond` is deliberately ignored —
/// MINRES requires an SPD `M`, and none of the [`DistPrecondKind`]
/// variants guarantee that on an indefinite operator (Jacobi's diagonal
/// and the exact/AMG block inverses inherit the operator's
/// indefiniteness).
pub fn dist_minres<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
) -> DistSolveReport {
    assert_eq!(b_own.len(), a.plan.n_own);
    run_dist(a, comm, "minres", |op, mem| {
        krylov::minres(
            op,
            b_own,
            &crate::iterative::Identity,
            comm,
            &iter_opts(opts),
            Some(mem),
        )
    })
}

/// Distributed LOBPCG for the k smallest eigenpairs (Jacobi
/// preconditioned).  Returns (values, per-rank vector slices, iters).
pub fn dist_lobpcg<C: Transport>(
    a: &DistCsr,
    k: usize,
    comm: &C,
    tol: f64,
    max_iters: usize,
    seed: u64,
) -> (Vec<f64>, Vec<Vec<f64>>, usize) {
    let n_own = a.plan.n_own;
    let m = jacobi_of((0..n_own).map(|r| a.local.get(r, r)));
    let op = DistOp::new(a, comm, 1_000_000);
    let result = krylov::lobpcg(
        &op,
        &*m,
        k,
        comm,
        &crate::eigen::LobpcgOpts {
            tol,
            max_iters,
            seed,
        },
    );
    (result.values, result.vectors, result.iters)
}

/// Distributed adjoint linear solve (paper §3.3 "Autograd composition"):
/// forward dist CG for x, backward dist CG for lambda (A = A^T here),
/// local O(nnz_own) matrix-gradient assembly using one extra halo
/// exchange to refresh x's halo values.  No other communication.
pub struct DistAdjointResult {
    pub x_own: Vec<f64>,
    pub lambda_own: Vec<f64>,
    /// dL/db restricted to owned entries ( = lambda).
    pub db_own: Vec<f64>,
    /// dL/dA on this rank's owned non-zeros (local CSR layout).
    pub dvals_own: Vec<f64>,
    pub forward: DistSolveReport,
    pub backward: DistSolveReport,
}

pub fn dist_solve_adjoint<C: Transport>(
    a: &DistCsr,
    b_own: &[f64],
    gy_own: &[f64],
    comm: &C,
    opts: &DistIterOpts,
) -> DistAdjointResult {
    let forward = dist_cg(a, b_own, comm, opts);
    let backward = dist_cg(a, gy_own, comm, opts); // A^T = A (SPD)
    let n_ext = a.plan.n_own + a.plan.n_halo();
    // refresh halo copies of x for the outer product
    let mut x_ext = vec![0.0; n_ext];
    x_ext[..a.plan.n_own].copy_from_slice(&forward.x_own);
    super::halo::halo_exchange(&a.plan, &mut x_ext, comm, 424_242);
    // dA_ij = -lambda_i x_j on owned rows (local indices)
    let mut dvals = vec![0.0; a.local.nnz()];
    for r in 0..a.plan.n_own {
        let lam_r = backward.x_own[r];
        let lo = a.local.indptr[r];
        let hi = a.local.indptr[r + 1];
        for kk in lo..hi {
            dvals[kk] = -lam_r * x_ext[a.local.indices[kk]];
        }
    }
    DistAdjointResult {
        x_own: forward.x_own.clone(),
        lambda_own: backward.x_own.clone(),
        db_own: backward.x_own.clone(),
        dvals_own: dvals,
        forward,
        backward,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;
    use crate::distributed::halo::distribute;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};
    use std::sync::Arc;

    fn dist_setup(g: usize, nparts: usize) -> (crate::sparse::Csr, super::super::Partition, Arc<Vec<DistCsr>>) {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        (a_perm, part, parts)
    }

    #[test]
    fn dist_cg_matches_serial_solution() {
        let g = 16;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(0);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);
        let bc = b.clone();
        let p2 = part2.clone();
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_cg(&parts[p], &bc[range], &c, &DistIterOpts::default())
        });
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(reports.iter().all(|r| r.converged));
        assert!(util::rel_l2(&a_perm.matvec(&x), &b) < 1e-8);
        // communication happened and was accounted
        assert!(reports.iter().any(|r| r.bytes_sent > 0));
        assert!(reports.iter().all(|r| r.reduce_rounds > 0));
    }

    #[test]
    fn pipelined_cg_matches_standard_cg_with_half_the_reductions() {
        let g = 20;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(3);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);

        // standard two-reduction CG
        let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
        let std_out = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let rep = dist_cg(&ps[p], &bc[range], &c, &DistIterOpts::default());
            (rep, c.reduce_rounds())
        });
        // single-reduction (pipelined) CG
        let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
        let pip_out = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let rep = dist_cg_pipelined(&ps[p], &bc[range], &c, &DistIterOpts::default());
            (rep, c.reduce_rounds())
        });

        let x_std: Vec<f64> = std_out.iter().flat_map(|(r, _)| r.x_own.clone()).collect();
        let x_pip: Vec<f64> = pip_out.iter().flat_map(|(r, _)| r.x_own.clone()).collect();
        assert!(std_out.iter().all(|(r, _)| r.converged));
        assert!(pip_out.iter().all(|(r, _)| r.converged));
        assert!(util::rel_l2(&a_perm.matvec(&x_std), &b) < 1e-8);
        assert!(util::rel_l2(&a_perm.matvec(&x_pip), &b) < 1e-8);
        assert!(util::rel_l2(&x_pip, &x_std) < 1e-6);

        // iteration counts agree to within a couple (same Krylov space)
        let it_std = std_out[0].0.iters;
        let it_pip = pip_out[0].0.iters;
        assert!(
            (it_std as i64 - it_pip as i64).abs() <= 3,
            "iters diverged: std {it_std} vs pipelined {it_pip}"
        );

        // the headline: reduction ROUNDS per iteration drop from 2
        // (<p,Ap>; fused <r,z>+<r,r>) to 1 (everything fused)
        let rounds_std = std_out[0].1 as f64 / it_std as f64;
        let rounds_pip = pip_out[0].1 as f64 / it_pip as f64;
        assert!(
            rounds_std > 1.9 && rounds_std < 2.2,
            "standard CG should cost ~2 reduction rounds/iter, got {rounds_std:.2}"
        );
        assert!(
            rounds_pip < 1.2,
            "pipelined CG should cost ~1 reduction round/iter, got {rounds_pip:.2}"
        );
        // the per-solve report carries the same pinned structure
        assert_eq!(std_out[0].0.reduce_rounds, std_out[0].1);
        assert_eq!(pip_out[0].0.reduce_rounds, pip_out[0].1);
    }

    #[test]
    fn block_amg_precond_converges_much_faster_than_jacobi() {
        // The §5 future-work item: at fixed iteration budget the AMG
        // additive-Schwarz residual must be orders of magnitude below
        // Jacobi's (and it must still match the serial solution).
        let g = 32;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(5);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);

        let run = |kind: DistPrecondKind| {
            let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
            run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                dist_cg(
                    &ps[p],
                    &bc[range],
                    &c,
                    &DistIterOpts {
                        tol: 1e-11,
                        max_iters: 10_000,
                        precond: kind.clone(),
                        ..Default::default()
                    },
                )
            })
        };
        let jac = run(DistPrecondKind::Jacobi);
        let amg = run(DistPrecondKind::BlockAmg);
        assert!(jac.iter().all(|r| r.converged));
        assert!(amg.iter().all(|r| r.converged));
        let x_amg: Vec<f64> = amg.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&a_perm.matvec(&x_amg), &b) < 1e-8);
        // convergence acceleration
        assert!(
            amg[0].iters * 3 < jac[0].iters,
            "block-AMG ({}) must beat Jacobi ({}) by >3x in iterations",
            amg[0].iters,
            jac[0].iters
        );
    }

    #[test]
    fn block_lu_precond_factors_once_per_rank_pattern_values() {
        // The factor-cache satellite: per-rank exact-block Schwarz must
        // cost ONE numeric factorization per (rank, pattern, values) —
        // warm rebuilds are numeric-tier hits, not refactorizations.
        let nparts = 3;
        let (_, _, parts) = dist_setup(18, nparts);
        let cache = FactorCache::new(u64::MAX);
        let reg = Registry::new();
        for p in 0..nparts {
            let _ = build_precond(&parts[p], &DistPrecondKind::BlockLu, &cache, Some(&reg));
        }
        assert_eq!(
            cache.stats().numeric_factorizations,
            nparts as u64,
            "cold pass: one factorization per rank block"
        );
        assert_eq!(reg.get("factor_cache.miss"), nparts as u64);
        // warm pass: same shares, same values -> numeric-tier hits only
        for p in 0..nparts {
            let _ = build_precond(&parts[p], &DistPrecondKind::BlockLu, &cache, Some(&reg));
        }
        assert_eq!(
            cache.stats().numeric_factorizations,
            nparts as u64,
            "warm pass must not refactor"
        );
        assert_eq!(reg.get("factor_cache.hit.numeric"), nparts as u64);
    }

    #[test]
    fn owned_diag_block_extracted_once_per_share() {
        // Satellite: warm preconditioner builds must reuse the share's
        // cached owned-block extraction — pinned by pointer identity.
        let (_, _, parts) = dist_setup(12, 2);
        let cache = FactorCache::new(u64::MAX);
        assert!(parts[0].cached_block().is_none(), "no block before first build");
        let _ = build_precond(&parts[0], &DistPrecondKind::BlockLu, &cache, None);
        let first = parts[0].cached_block().expect("block cached after build");
        let _ = build_precond(&parts[0], &DistPrecondKind::BlockLu, &cache, None);
        let second = parts[0].cached_block().unwrap();
        assert!(
            Arc::ptr_eq(&first, &second),
            "warm build must not re-extract the owned block"
        );
        assert!(Arc::ptr_eq(&first, &parts[0].owned_diag_block()));
    }

    #[test]
    fn block_direct_applications_do_not_allocate() {
        // Satellite: BlockDirect runs through solve_into — the factor-
        // solve allocation tally must not move across applications.
        // (The tally is process-global and monotonic; other tests bump
        // it concurrently, so pin via a PRIVATE precond apply loop with
        // the counter read inside a single-threaded region is not
        // reliable.  Instead pin the contract at the CachedFactor
        // level: solve_into leaves the tally unchanged.)
        let (_, _, parts) = dist_setup(10, 2);
        let cache = FactorCache::new(u64::MAX);
        let block = parts[0].owned_diag_block();
        let f = cache.factor(&block, u64::MAX, None).unwrap();
        let n = block.nrows;
        let mut out = vec![0.0; n];
        let mut scratch = Vec::new();
        let b = vec![1.0; n];
        // prime buffers, then measure: repeated solve_into adds nothing.
        // The tally is process-global, so a concurrent test can bump it
        // mid-window; require one clean window out of many rather than
        // asserting on a single racy read.
        f.solve_into(&b, &mut out, &mut scratch).unwrap();
        let mut clean = false;
        for _ in 0..20 {
            let before = crate::metrics::mem::factor_solve_alloc_bytes();
            for _ in 0..8 {
                f.solve_into(&b, &mut out, &mut scratch).unwrap();
            }
            if crate::metrics::mem::factor_solve_alloc_bytes() == before {
                clean = true;
                break;
            }
        }
        assert!(
            clean,
            "solve_into must not bump the factor-solve allocation tally"
        );
        // and the result matches the allocating path bitwise
        assert_eq!(f.solve(&b).unwrap(), out);
    }

    #[test]
    fn block_lu_precond_solves_and_beats_jacobi() {
        let g = 24;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(9);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);
        let run = |kind: DistPrecondKind| {
            let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
            run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                dist_cg(
                    &ps[p],
                    &bc[range],
                    &c,
                    &DistIterOpts {
                        tol: 1e-11,
                        max_iters: 10_000,
                        precond: kind.clone(),
                        ..Default::default()
                    },
                )
            })
        };
        let jac = run(DistPrecondKind::Jacobi);
        let blu = run(DistPrecondKind::BlockLu);
        assert!(blu.iter().all(|r| r.converged));
        let x: Vec<f64> = blu.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&a_perm.matvec(&x), &b) < 1e-8);
        assert!(
            blu[0].iters < jac[0].iters,
            "exact block solves ({}) must beat Jacobi ({})",
            blu[0].iters,
            jac[0].iters
        );
    }

    #[test]
    fn pipelined_cg_fixed_budget_unconverged() {
        let (_, part, parts) = dist_setup(24, 3);
        let part2 = Arc::new(part);
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let n_own = part2.rank_size(p);
            dist_cg_pipelined(
                &parts[p],
                &vec![1.0; n_own],
                &c,
                &DistIterOpts {
                    tol: 1e-14,
                    max_iters: 10,
                ..Default::default()
            },
            )
        });
        for r in &reports {
            assert!(!r.converged);
            assert!(r.residual > 0.0);
        }
    }

    #[test]
    fn dist_cg_fixed_budget_unconverged() {
        let (_, part, parts) = dist_setup(24, 3);
        let part2 = Arc::new(part);
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let n_own = part2.rank_size(p);
            dist_cg(
                &parts[p],
                &vec![1.0; n_own],
                &c,
                &DistIterOpts {
                    tol: 1e-14,
                    max_iters: 10,
                ..Default::default()
            },
            )
        });
        for r in &reports {
            assert!(!r.converged);
            assert_eq!(r.iters, 10);
            assert!(r.residual > 0.0);
        }
    }

    #[test]
    fn dist_bicgstab_solves_spd_too() {
        let g = 12;
        let (a_perm, part, parts) = dist_setup(g, 3);
        let n = g * g;
        let mut rng = Prng::new(1);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);
        let bc = b.clone();
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let range = part2.rank_range(p);
            dist_bicgstab(&parts[p], &bc[range], &c, &DistIterOpts::default())
        });
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&a_perm.matvec(&x), &b) < 1e-7);
    }

    #[test]
    fn dist_lobpcg_matches_serial() {
        let g = 10;
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), 3, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        let serial = crate::eigen::lanczos(
            &sys.matrix,
            2,
            crate::eigen::lanczos::Which::Smallest,
            80,
            0,
        );
        let vals = run_ranks(3, move |c| {
            let p = c.rank();
            let (values, _, _) = dist_lobpcg(&parts[p], 2, &c, 1e-9, 300, 7);
            values
        });
        for v in &vals {
            for (a, b) in v.iter().zip(&serial.values) {
                assert!((a - b).abs() < 1e-5 * b, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn dist_adjoint_matches_serial_adjoint() {
        let g = 10;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(2);
        let b = Arc::new(rng.normal_vec(n));
        let gy = Arc::new(rng.normal_vec(n));

        // serial reference
        let x_ref = crate::direct::direct_solve(&a_perm, &b).unwrap();
        let lam_ref = crate::direct::direct_solve(&a_perm, &gy).unwrap();

        let part2 = Arc::new(part);
        let (bc, gc, p2) = (b.clone(), gy.clone(), part2.clone());
        let results = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_solve_adjoint(
                &parts[p],
                &bc[range.clone()],
                &gc[range],
                &c,
                &DistIterOpts {
                    tol: 1e-12,
                    max_iters: 20_000,
                ..Default::default()
            },
            )
        });
        let x: Vec<f64> = results.iter().flat_map(|r| r.x_own.clone()).collect();
        let lam: Vec<f64> = results.iter().flat_map(|r| r.lambda_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6);
        assert!(util::rel_l2(&lam, &lam_ref) < 1e-6);
        // matrix gradient: every owned entry must equal -lambda_i x_j
        // (map local column indices back to global through the halo plan)
        let (_, part3, parts3) = dist_setup(g, nparts);
        for (p, res) in results.iter().enumerate() {
            let range = part3.rank_range(p);
            let share = &parts3[p];
            for r_local in 0..share.plan.n_own {
                let r_global = range.start + r_local;
                let lo = share.local.indptr[r_local];
                let hi = share.local.indptr[r_local + 1];
                for kk in lo..hi {
                    let lc = share.local.indices[kk];
                    let c_global = if lc < share.plan.n_own {
                        range.start + lc
                    } else {
                        share.plan.halo_globals[lc - share.plan.n_own]
                    };
                    let want = -lam_ref[r_global] * x_ref[c_global];
                    assert!(
                        (res.dvals_own[kk] - want).abs() < 1e-5 * (1.0 + want.abs()),
                        "rank {p} entry ({r_global},{c_global}): {} vs {want}",
                        res.dvals_own[kk]
                    );
                }
            }
        }
    }
}
