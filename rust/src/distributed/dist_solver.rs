//! Distributed Krylov solvers (paper §3.3 + Appendix C Algorithm 1) and
//! the distributed adjoint solve.
//!
//! Per CG iteration: ONE halo exchange (inside the SpMV) and TWO
//! all_reduce calls — the exact communication structure of the paper.

use super::comm::LocalComm;
use super::halo::{dist_spmv, DistCsr};
use crate::iterative::{Amg, AmgOpts, Jacobi, Precond};
use crate::util::dot;

/// Preconditioner for the distributed Krylov loops.  Application is
/// purely LOCAL (no communication), so both variants compose with the
/// transposed-halo backward pass unchanged.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum DistPrecondKind {
    /// Pointwise Jacobi — the paper's only option (§5), kept as the
    /// parity default.
    #[default]
    Jacobi,
    /// One-level additive Schwarz with an AMG V-cycle on each rank's
    /// owned diagonal block — the §5 "stronger preconditioner (e.g.
    /// algebraic multigrid)" future-work item, implemented.
    BlockAmg,
}

#[derive(Clone, Debug)]
pub struct DistIterOpts {
    pub tol: f64,
    pub max_iters: usize,
    pub precond: DistPrecondKind,
}

impl Default for DistIterOpts {
    fn default() -> Self {
        DistIterOpts {
            tol: 1e-10,
            max_iters: 10_000,
            precond: DistPrecondKind::Jacobi,
        }
    }
}

/// Build the local (per-rank) preconditioner over the owned diagonal
/// block of the share.
fn build_precond(a: &DistCsr, kind: &DistPrecondKind) -> Box<dyn Precond> {
    let n_own = a.plan.n_own;
    match kind {
        DistPrecondKind::Jacobi => {
            let diag: Vec<f64> = (0..n_own)
                .map(|r| {
                    let d = a.local.get(r, r);
                    if d != 0.0 {
                        d
                    } else {
                        1.0
                    }
                })
                .collect();
            Box::new(Jacobi::from_diag(&diag))
        }
        DistPrecondKind::BlockAmg => {
            // extract the owned diagonal block (rows x owned cols)
            let mut coo = crate::sparse::Coo::with_capacity(n_own, n_own, a.local.nnz());
            for r in 0..n_own {
                let (cols, vals) = a.local.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    if *c < n_own {
                        coo.push(r, *c, *v);
                    }
                }
            }
            let block = coo.to_csr();
            match Amg::new(&block, &AmgOpts::default()) {
                Ok(amg) => Box::new(amg),
                Err(_) => {
                    // degenerate block: fall back to Jacobi
                    let diag: Vec<f64> = (0..n_own)
                        .map(|r| {
                            let d = block.get(r, r);
                            if d != 0.0 {
                                d
                            } else {
                                1.0
                            }
                        })
                        .collect();
                    Box::new(Jacobi::from_diag(&diag))
                }
            }
        }
    }
}

/// Per-rank report after a distributed solve.
#[derive(Clone, Debug)]
pub struct DistSolveReport {
    pub x_own: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
    /// Bytes this rank sent during the solve.
    pub bytes_sent: u64,
    /// Peak per-rank working set (matrix share + vectors).
    pub peak_bytes: u64,
}

/// Distributed Jacobi-preconditioned CG; runs inside one rank's thread.
/// `b_own` is this rank's slice of the RHS.
pub fn dist_cg(
    a: &DistCsr,
    b_own: &[f64],
    comm: &LocalComm,
    opts: &DistIterOpts,
) -> DistSolveReport {
    let n_own = a.plan.n_own;
    let n_ext = n_own + a.plan.n_halo();
    assert_eq!(b_own.len(), n_own);
    let bytes0 = comm.bytes_sent();

    // local preconditioner (Jacobi, or block-AMG additive Schwarz)
    let m = build_precond(a, &opts.precond);

    let mut x = vec![0.0; n_own];
    let mut r: Vec<f64> = b_own.to_vec();
    let mut z = vec![0.0; n_own];
    m.apply(&r, &mut z);
    let mut p_ext = vec![0.0; n_ext];
    p_ext[..n_own].copy_from_slice(&z);
    let mut ap = vec![0.0; n_own];

    let mut rz = comm.all_reduce_sum(dot(&r, &z));
    let mut rr = comm.all_reduce_sum(dot(&r, &r));
    let tol2 = opts.tol * opts.tol;
    let mut iters = 0;
    while iters < opts.max_iters && rr > tol2 {
        dist_spmv(a, &mut p_ext, &mut ap, comm, 100 + iters as u64);
        let pap = comm.all_reduce_sum(dot(&p_ext[..n_own], &ap));
        if pap <= 0.0 || !pap.is_finite() {
            break;
        }
        let alpha = rz / pap;
        for i in 0..n_own {
            x[i] += alpha * p_ext[i];
            r[i] -= alpha * ap[i];
        }
        m.apply(&r, &mut z);
        // <r,z> and <r,r> are available at the same point of the
        // recurrence, so they ride ONE fused all_reduce (a packed
        // 2-scalar NCCL buffer) — Algorithm 1's "two all_reduce per
        // iteration" is exactly <p,Ap> plus this fused pair.
        // (§Perf L3: was three rounds; fusing saved one latency unit.)
        let fused = comm.all_reduce_sum_vec(&[dot(&r, &z), dot(&r, &r)]);
        let (rz_new, rr_new) = (fused[0], fused[1]);
        let beta = rz_new / rz;
        for i in 0..n_own {
            p_ext[i] = z[i] + beta * p_ext[i];
        }
        rz = rz_new;
        rr = rr_new;
        iters += 1;
    }

    let vec_bytes = ((n_own * 5 + n_ext) * 8) as u64;
    DistSolveReport {
        x_own: x,
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        bytes_sent: comm.bytes_sent() - bytes0,
        peak_bytes: a.bytes() + vec_bytes,
    }
}

/// Single-reduction distributed CG (Chronopoulos & Gear 1989; the
/// "pipelined / communication-avoiding CG" roadmap item of Appendix C).
///
/// Algebraically equivalent to [`dist_cg`] but restructured so the two
/// inner products of each iteration — `<r,u>` and `<w,u>` (plus the
/// `<r,r>` convergence check) — ride ONE fused `all_reduce` round,
/// halving the per-iteration reduction latency that dominates at large
/// P.  Composes with the same transposed-halo backward pass, since only
/// the reductions are reorganized, not the SpMV (Appendix C).
pub fn dist_cg_pipelined(
    a: &DistCsr,
    b_own: &[f64],
    comm: &LocalComm,
    opts: &DistIterOpts,
) -> DistSolveReport {
    let n_own = a.plan.n_own;
    let n_ext = n_own + a.plan.n_halo();
    assert_eq!(b_own.len(), n_own);
    let bytes0 = comm.bytes_sent();

    let m = build_precond(a, &opts.precond);

    let mut x = vec![0.0; n_own];
    let mut r: Vec<f64> = b_own.to_vec();
    // u = M^-1 r lives in the extended (owned + halo) layout: it is the
    // vector whose halo must be current for w = A u.
    let mut u_ext = vec![0.0; n_ext];
    let mut u_own = vec![0.0; n_own];
    m.apply(&r, &mut u_own);
    u_ext[..n_own].copy_from_slice(&u_own);
    let mut w = vec![0.0; n_own];
    dist_spmv(a, &mut u_ext, &mut w, comm, 50);

    let fused = comm.all_reduce_sum_vec(&[
        dot(&r, &u_ext[..n_own]),
        dot(&w, &u_ext[..n_own]),
        dot(&r, &r),
    ]);
    let (mut gamma, delta0, mut rr) = (fused[0], fused[1], fused[2]);

    let mut p = vec![0.0; n_own];
    let mut s = vec![0.0; n_own]; // s = A p
    let mut alpha = if delta0 > 0.0 { gamma / delta0 } else { 0.0 };
    let mut beta = 0.0_f64;
    let tol2 = opts.tol * opts.tol;
    let mut iters = 0;
    while iters < opts.max_iters && rr > tol2 && alpha.is_finite() && alpha != 0.0 {
        // p = u + beta p ; s = w + beta s  (beta = 0 on the first pass)
        for i in 0..n_own {
            p[i] = u_ext[i] + beta * p[i];
            s[i] = w[i] + beta * s[i];
        }
        // x += alpha p ; r -= alpha s ; u = M^-1 r
        for i in 0..n_own {
            x[i] += alpha * p[i];
            r[i] -= alpha * s[i];
        }
        m.apply(&r, &mut u_own);
        u_ext[..n_own].copy_from_slice(&u_own);
        // w = A u (one halo exchange)
        dist_spmv(a, &mut u_ext, &mut w, comm, 150 + iters as u64);
        // ONE fused reduction: gamma_new = <r,u>, delta = <w,u>, rr = <r,r>
        let fused = comm.all_reduce_sum_vec(&[
            dot(&r, &u_ext[..n_own]),
            dot(&w, &u_ext[..n_own]),
            dot(&r, &r),
        ]);
        let (gamma_new, delta, rr_new) = (fused[0], fused[1], fused[2]);
        rr = rr_new;
        iters += 1;
        if rr <= tol2 {
            break;
        }
        beta = gamma_new / gamma;
        let denom = delta - beta / alpha * gamma_new;
        if denom <= 0.0 || !denom.is_finite() {
            break; // breakdown: report current iterate
        }
        alpha = gamma_new / denom;
        gamma = gamma_new;
    }

    let vec_bytes = ((n_own * 6 + n_ext) * 8) as u64;
    DistSolveReport {
        x_own: x,
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        bytes_sent: comm.bytes_sent() - bytes0,
        peak_bytes: a.bytes() + vec_bytes,
    }
}

/// Distributed BiCGStab for general systems (same halo/reduce template).
pub fn dist_bicgstab(
    a: &DistCsr,
    b_own: &[f64],
    comm: &LocalComm,
    opts: &DistIterOpts,
) -> DistSolveReport {
    let n_own = a.plan.n_own;
    let n_ext = n_own + a.plan.n_halo();
    let bytes0 = comm.bytes_sent();

    let mut x = vec![0.0; n_own];
    let mut r: Vec<f64> = b_own.to_vec();
    let r0: Vec<f64> = b_own.to_vec();
    let mut p_ext = vec![0.0; n_ext];
    let mut s_ext = vec![0.0; n_ext];
    let mut v = vec![0.0; n_own];
    let mut t = vec![0.0; n_own];

    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut rr = comm.all_reduce_sum(dot(&r, &r));
    let tol2 = opts.tol * opts.tol;
    let mut iters = 0;
    let mut tag = 10_000u64;
    while iters < opts.max_iters && rr > tol2 {
        let rho_new = comm.all_reduce_sum(dot(&r0, &r));
        if rho_new == 0.0 {
            break;
        }
        if iters == 0 {
            p_ext[..n_own].copy_from_slice(&r);
        } else {
            let beta = (rho_new / rho) * (alpha / omega);
            for i in 0..n_own {
                p_ext[i] = r[i] + beta * (p_ext[i] - omega * v[i]);
            }
        }
        rho = rho_new;
        tag += 1;
        dist_spmv(a, &mut p_ext, &mut v, comm, tag);
        let r0v = comm.all_reduce_sum(dot(&r0, &v));
        if r0v == 0.0 {
            break;
        }
        alpha = rho / r0v;
        for i in 0..n_own {
            s_ext[i] = r[i] - alpha * v[i];
        }
        let ss = comm.all_reduce_sum(dot(&s_ext[..n_own], &s_ext[..n_own]));
        if ss <= tol2 {
            for i in 0..n_own {
                x[i] += alpha * p_ext[i];
            }
            rr = ss;
            iters += 1;
            break;
        }
        tag += 1;
        dist_spmv(a, &mut s_ext, &mut t, comm, tag);
        let tt = comm.all_reduce_sum(dot(&t, &t));
        if tt == 0.0 {
            break;
        }
        let ts = comm.all_reduce_sum(dot(&t, &s_ext[..n_own]));
        omega = ts / tt;
        for i in 0..n_own {
            x[i] += alpha * p_ext[i] + omega * s_ext[i];
            r[i] = s_ext[i] - omega * t[i];
        }
        rr = comm.all_reduce_sum(dot(&r, &r));
        iters += 1;
        if omega == 0.0 {
            break;
        }
    }

    let vec_bytes = ((n_own * 6 + 2 * n_ext) * 8) as u64;
    DistSolveReport {
        x_own: x,
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        bytes_sent: comm.bytes_sent() - bytes0,
        peak_bytes: a.bytes() + vec_bytes,
    }
}

/// Distributed LOBPCG for the k smallest eigenpairs (Jacobi
/// preconditioned).  Returns (values, per-rank vector slices, iters).
pub fn dist_lobpcg(
    a: &DistCsr,
    k: usize,
    comm: &LocalComm,
    tol: f64,
    max_iters: usize,
    seed: u64,
) -> (Vec<f64>, Vec<Vec<f64>>, usize) {
    let n_own = a.plan.n_own;
    let n_ext = n_own + a.plan.n_halo();
    // rank-deterministic start vectors: every rank generates ITS slice
    let mut rng = crate::util::Prng::new(seed ^ ((comm.rank() as u64) << 32));
    let inv_diag: Vec<f64> = (0..n_own)
        .map(|r| {
            let d = a.local.get(r, r);
            if d != 0.0 {
                1.0 / d
            } else {
                1.0
            }
        })
        .collect();

    let gdot = |comm: &LocalComm, a_: &[f64], b_: &[f64]| comm.all_reduce_sum(dot(a_, b_));
    let mut tag = 1_000_000u64;
    let mut spmv = |a: &DistCsr, x_own: &[f64], comm: &LocalComm| -> Vec<f64> {
        let mut x_ext = vec![0.0; n_ext];
        x_ext[..n_own].copy_from_slice(x_own);
        let mut y = vec![0.0; n_own];
        tag += 1;
        dist_spmv(a, &mut x_ext, &mut y, comm, tag);
        y
    };

    // distributed modified Gram-Schmidt
    let orthonormalize = |vs: &mut Vec<Vec<f64>>, comm: &LocalComm| {
        let mut out: Vec<Vec<f64>> = Vec::with_capacity(vs.len());
        for v in vs.drain(..) {
            let mut w = v;
            for _ in 0..2 {
                for u in &out {
                    let c = gdot(comm, &w, u);
                    for i in 0..w.len() {
                        w[i] -= c * u[i];
                    }
                }
            }
            let nw = gdot(comm, &w, &w).sqrt();
            if nw > 1e-10 {
                for x in w.iter_mut() {
                    *x /= nw;
                }
                out.push(w);
            }
        }
        *vs = out;
    };

    let mut x: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(n_own)).collect();
    orthonormalize(&mut x, comm);
    let mut p: Vec<Vec<f64>> = Vec::new();
    let mut values = vec![0.0; k];
    let mut iters = 0;

    for it in 0..max_iters {
        iters = it + 1;
        let ax: Vec<Vec<f64>> = x.iter().map(|xi| spmv(a, xi, comm)).collect();
        let mut ws: Vec<Vec<f64>> = Vec::with_capacity(k);
        let mut worst = 0.0f64;
        for j in 0..k {
            let lam = gdot(comm, &x[j], &ax[j]);
            values[j] = lam;
            let r: Vec<f64> = (0..n_own).map(|i| ax[j][i] - lam * x[j][i]).collect();
            let rn = gdot(comm, &r, &r).sqrt();
            worst = worst.max(rn / lam.abs().max(1.0));
            ws.push(r.iter().zip(&inv_diag).map(|(a, d)| a * d).collect());
        }
        if worst < tol {
            break;
        }
        let mut s: Vec<Vec<f64>> = Vec::with_capacity(3 * k);
        s.extend(x.iter().cloned());
        s.extend(ws);
        s.extend(p.iter().cloned());
        orthonormalize(&mut s, comm);
        let d = s.len();
        let as_: Vec<Vec<f64>> = s.iter().map(|si| spmv(a, si, comm)).collect();
        let mut t = vec![0f64; d * d];
        for i in 0..d {
            for j in i..d {
                let v = gdot(comm, &s[i], &as_[j]);
                t[i * d + j] = v;
                t[j * d + i] = v;
            }
        }
        // Rayleigh-Ritz is replicated on every rank (dense d x d)
        let (_tvals, tvecs) = crate::eigen::jacobi_eigh(&t, d);
        let x_new: Vec<Vec<f64>> = (0..k)
            .map(|j| {
                let mut v = vec![0.0; n_own];
                for (i, si) in s.iter().enumerate() {
                    let c = tvecs[j][i];
                    for l in 0..n_own {
                        v[l] += c * si[l];
                    }
                }
                v
            })
            .collect();
        let mut p_new = Vec::with_capacity(k);
        for j in 0..k {
            let mut pj = x_new[j].clone();
            for xi in &x {
                let c = gdot(comm, xi, &x_new[j]);
                for l in 0..n_own {
                    pj[l] -= c * xi[l];
                }
            }
            let np = gdot(comm, &pj, &pj).sqrt();
            if np > 1e-12 {
                for v in pj.iter_mut() {
                    *v /= np;
                }
                p_new.push(pj);
            }
        }
        x = x_new;
        orthonormalize(&mut x, comm);
        p = p_new;
    }

    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&i, &j| values[i].partial_cmp(&values[j]).unwrap());
    (
        order.iter().map(|&i| values[i]).collect(),
        order.iter().map(|&i| x[i].clone()).collect(),
        iters,
    )
}

/// Distributed adjoint linear solve (paper §3.3 "Autograd composition"):
/// forward dist CG for x, backward dist CG for lambda (A = A^T here),
/// local O(nnz_own) matrix-gradient assembly using one extra halo
/// exchange to refresh x's halo values.  No other communication.
pub struct DistAdjointResult {
    pub x_own: Vec<f64>,
    pub lambda_own: Vec<f64>,
    /// dL/db restricted to owned entries ( = lambda).
    pub db_own: Vec<f64>,
    /// dL/dA on this rank's owned non-zeros (local CSR layout).
    pub dvals_own: Vec<f64>,
    pub forward: DistSolveReport,
    pub backward: DistSolveReport,
}

pub fn dist_solve_adjoint(
    a: &DistCsr,
    b_own: &[f64],
    gy_own: &[f64],
    comm: &LocalComm,
    opts: &DistIterOpts,
) -> DistAdjointResult {
    let forward = dist_cg(a, b_own, comm, opts);
    let backward = dist_cg(a, gy_own, comm, opts); // A^T = A (SPD)
    let n_ext = a.plan.n_own + a.plan.n_halo();
    // refresh halo copies of x for the outer product
    let mut x_ext = vec![0.0; n_ext];
    x_ext[..a.plan.n_own].copy_from_slice(&forward.x_own);
    super::halo::halo_exchange(&a.plan, &mut x_ext, comm, 424_242);
    // dA_ij = -lambda_i x_j on owned rows (local indices)
    let mut dvals = vec![0.0; a.local.nnz()];
    for r in 0..a.plan.n_own {
        let lam_r = backward.x_own[r];
        let lo = a.local.indptr[r];
        let hi = a.local.indptr[r + 1];
        for kk in lo..hi {
            dvals[kk] = -lam_r * x_ext[a.local.indices[kk]];
        }
    }
    DistAdjointResult {
        x_own: forward.x_own.clone(),
        lambda_own: backward.x_own.clone(),
        db_own: backward.x_own.clone(),
        dvals_own: dvals,
        forward,
        backward,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;
    use crate::distributed::halo::distribute;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};
    use std::sync::Arc;

    fn dist_setup(g: usize, nparts: usize) -> (crate::sparse::Csr, super::super::Partition, Arc<Vec<DistCsr>>) {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        (a_perm, part, parts)
    }

    #[test]
    fn dist_cg_matches_serial_solution() {
        let g = 16;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(0);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);
        let bc = b.clone();
        let p2 = part2.clone();
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_cg(&parts[p], &bc[range], &c, &DistIterOpts::default())
        });
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(reports.iter().all(|r| r.converged));
        assert!(util::rel_l2(&a_perm.matvec(&x), &b) < 1e-8);
        // communication happened
        assert!(reports.iter().any(|r| r.bytes_sent > 0));
    }

    #[test]
    fn pipelined_cg_matches_standard_cg_with_half_the_reductions() {
        let g = 20;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(3);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);

        // standard two-reduction CG
        let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
        let std_out = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let rep = dist_cg(&ps[p], &bc[range], &c, &DistIterOpts::default());
            (rep, c.reduce_rounds())
        });
        // single-reduction (pipelined) CG
        let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
        let pip_out = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let rep = dist_cg_pipelined(&ps[p], &bc[range], &c, &DistIterOpts::default());
            (rep, c.reduce_rounds())
        });

        let x_std: Vec<f64> = std_out.iter().flat_map(|(r, _)| r.x_own.clone()).collect();
        let x_pip: Vec<f64> = pip_out.iter().flat_map(|(r, _)| r.x_own.clone()).collect();
        assert!(std_out.iter().all(|(r, _)| r.converged));
        assert!(pip_out.iter().all(|(r, _)| r.converged));
        assert!(util::rel_l2(&a_perm.matvec(&x_std), &b) < 1e-8);
        assert!(util::rel_l2(&a_perm.matvec(&x_pip), &b) < 1e-8);
        assert!(util::rel_l2(&x_pip, &x_std) < 1e-6);

        // iteration counts agree to within a couple (same Krylov space)
        let it_std = std_out[0].0.iters;
        let it_pip = pip_out[0].0.iters;
        assert!(
            (it_std as i64 - it_pip as i64).abs() <= 3,
            "iters diverged: std {it_std} vs pipelined {it_pip}"
        );

        // the headline: reduction ROUNDS per iteration drop from 2
        // (<p,Ap>; fused <r,z>+<r,r>) to 1 (everything fused)
        let rounds_std = std_out[0].1 as f64 / it_std as f64;
        let rounds_pip = pip_out[0].1 as f64 / it_pip as f64;
        assert!(
            rounds_std > 1.9 && rounds_std < 2.2,
            "standard CG should cost ~2 reduction rounds/iter, got {rounds_std:.2}"
        );
        assert!(
            rounds_pip < 1.2,
            "pipelined CG should cost ~1 reduction round/iter, got {rounds_pip:.2}"
        );
    }

    #[test]
    fn block_amg_precond_converges_much_faster_than_jacobi() {
        // The §5 future-work item: at fixed iteration budget the AMG
        // additive-Schwarz residual must be orders of magnitude below
        // Jacobi's (and it must still match the serial solution).
        let g = 32;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(5);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);

        let run = |kind: DistPrecondKind| {
            let (bc, p2, ps) = (b.clone(), part2.clone(), parts.clone());
            run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                dist_cg(
                    &ps[p],
                    &bc[range],
                    &c,
                    &DistIterOpts {
                        tol: 1e-11,
                        max_iters: 10_000,
                        precond: kind.clone(),
                    },
                )
            })
        };
        let jac = run(DistPrecondKind::Jacobi);
        let amg = run(DistPrecondKind::BlockAmg);
        assert!(jac.iter().all(|r| r.converged));
        assert!(amg.iter().all(|r| r.converged));
        let x_amg: Vec<f64> = amg.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&a_perm.matvec(&x_amg), &b) < 1e-8);
        // convergence acceleration
        assert!(
            amg[0].iters * 3 < jac[0].iters,
            "block-AMG ({}) must beat Jacobi ({}) by >3x in iterations",
            amg[0].iters,
            jac[0].iters
        );
    }

    #[test]
    fn pipelined_cg_fixed_budget_unconverged() {
        let (_, part, parts) = dist_setup(24, 3);
        let part2 = Arc::new(part);
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let n_own = part2.rank_size(p);
            dist_cg_pipelined(
                &parts[p],
                &vec![1.0; n_own],
                &c,
                &DistIterOpts {
                    tol: 1e-14,
                    max_iters: 10,
                ..Default::default()
            },
            )
        });
        for r in &reports {
            assert!(!r.converged);
            assert!(r.residual > 0.0);
        }
    }

    #[test]
    fn dist_cg_fixed_budget_unconverged() {
        let (_, part, parts) = dist_setup(24, 3);
        let part2 = Arc::new(part);
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let n_own = part2.rank_size(p);
            dist_cg(
                &parts[p],
                &vec![1.0; n_own],
                &c,
                &DistIterOpts {
                    tol: 1e-14,
                    max_iters: 10,
                ..Default::default()
            },
            )
        });
        for r in &reports {
            assert!(!r.converged);
            assert_eq!(r.iters, 10);
            assert!(r.residual > 0.0);
        }
    }

    #[test]
    fn dist_bicgstab_solves_spd_too() {
        let g = 12;
        let (a_perm, part, parts) = dist_setup(g, 3);
        let n = g * g;
        let mut rng = Prng::new(1);
        let b = Arc::new(rng.normal_vec(n));
        let part2 = Arc::new(part);
        let bc = b.clone();
        let reports = run_ranks(3, move |c| {
            let p = c.rank();
            let range = part2.rank_range(p);
            dist_bicgstab(&parts[p], &bc[range], &c, &DistIterOpts::default())
        });
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&a_perm.matvec(&x), &b) < 1e-7);
    }

    #[test]
    fn dist_lobpcg_matches_serial() {
        let g = 10;
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), 3, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        let serial = crate::eigen::lanczos(
            &sys.matrix,
            2,
            crate::eigen::lanczos::Which::Smallest,
            80,
            0,
        );
        let vals = run_ranks(3, move |c| {
            let p = c.rank();
            let (values, _, _) = dist_lobpcg(&parts[p], 2, &c, 1e-9, 300, 7);
            values
        });
        for v in &vals {
            for (a, b) in v.iter().zip(&serial.values) {
                assert!((a - b).abs() < 1e-5 * b, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn dist_adjoint_matches_serial_adjoint() {
        let g = 10;
        let nparts = 4;
        let (a_perm, part, parts) = dist_setup(g, nparts);
        let n = g * g;
        let mut rng = Prng::new(2);
        let b = Arc::new(rng.normal_vec(n));
        let gy = Arc::new(rng.normal_vec(n));

        // serial reference
        let x_ref = crate::direct::direct_solve(&a_perm, &b).unwrap();
        let lam_ref = crate::direct::direct_solve(&a_perm, &gy).unwrap();

        let part2 = Arc::new(part);
        let (bc, gc, p2) = (b.clone(), gy.clone(), part2.clone());
        let results = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_solve_adjoint(
                &parts[p],
                &bc[range.clone()],
                &gc[range],
                &c,
                &DistIterOpts {
                    tol: 1e-12,
                    max_iters: 20_000,
                ..Default::default()
            },
            )
        });
        let x: Vec<f64> = results.iter().flat_map(|r| r.x_own.clone()).collect();
        let lam: Vec<f64> = results.iter().flat_map(|r| r.lambda_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6);
        assert!(util::rel_l2(&lam, &lam_ref) < 1e-6);
        // matrix gradient: every owned entry must equal -lambda_i x_j
        // (map local column indices back to global through the halo plan)
        let (_, part3, parts3) = dist_setup(g, nparts);
        for (p, res) in results.iter().enumerate() {
            let range = part3.rank_range(p);
            let share = &parts3[p];
            for r_local in 0..share.plan.n_own {
                let r_global = range.start + r_local;
                let lo = share.local.indptr[r_local];
                let hi = share.local.indptr[r_local + 1];
                for kk in lo..hi {
                    let lc = share.local.indices[kk];
                    let c_global = if lc < share.plan.n_own {
                        range.start + lc
                    } else {
                        share.plan.halo_globals[lc - share.plan.n_own]
                    };
                    let want = -lam_ref[r_global] * x_ref[c_global];
                    assert!(
                        (res.dvals_own[kk] - want).abs() < 1e-5 * (1.0 + want.abs()),
                        "rank {p} entry ({r_global},{c_global}): {} vs {want}",
                        res.dvals_own[kk]
                    );
                }
            }
        }
    }
}
