//! [`DistOp`]: a rank's `DistCsr` share bound to its communicator as a
//! [`LinearOperator`] — the distributed instantiation of the unified
//! Krylov substrate.
//!
//! `apply` is the paper's Eq. 5 (`y_own = A_local H(x_own)`: ONE halo
//! exchange, then the local SpMV) and `apply_adjoint` is Eq. 6 (`gx =
//! H^T A_local^T gy`: the transposed halo exchange, sum-at-owner).
//! Message tags advance through an internal counter; every rank runs the
//! same kernel in lockstep, so the counters stay synchronized across
//! the team without coordination.

use std::cell::Cell;

use super::comm::Transport;
use super::halo::{dist_spmv, dist_spmv_adjoint, DistCsr};
use crate::krylov::LinearOperator;

/// One rank's distributed operator: matrix share + communicator + tag
/// sequence.  Build one per solve; sequential solves may reuse tag
/// ranges because the per-pair channels are FIFO and collectives keep
/// the team in lockstep.
pub struct DistOp<'a> {
    a: &'a DistCsr,
    comm: &'a dyn Transport,
    tag: Cell<u64>,
}

impl<'a> DistOp<'a> {
    pub fn new(a: &'a DistCsr, comm: &'a dyn Transport, base_tag: u64) -> Self {
        DistOp {
            a,
            comm,
            tag: Cell::new(base_tag),
        }
    }

    pub fn share(&self) -> &DistCsr {
        self.a
    }

    fn next_tag(&self) -> u64 {
        let t = self.tag.get();
        self.tag.set(t + 1);
        t
    }
}

impl LinearOperator for DistOp<'_> {
    fn n_own(&self) -> usize {
        self.a.plan.n_own
    }

    fn n_ext(&self) -> usize {
        self.a.plan.n_own + self.a.plan.n_halo()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        dist_spmv(self.a, x_ext, y_own, self.comm, self.next_tag());
    }

    fn apply_adjoint(&self, gy_own: &[f64], gx_own: &mut [f64]) {
        dist_spmv_adjoint(self.a, gy_own, gx_own, self.comm, self.next_tag());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;
    use crate::distributed::halo::distribute;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};
    use std::sync::Arc;

    #[test]
    fn dist_op_apply_matches_global_matvec() {
        let g = 12;
        let nparts = 3;
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        let n = g * g;
        let mut rng = Prng::new(0);
        let x = Arc::new(rng.normal_vec(n));
        let want = a_perm.matvec(&x);
        let want_t = {
            let mut y = vec![0.0; n];
            a_perm.spmv_t(&x, &mut y);
            y
        };
        let part2 = Arc::new(part);
        let (xc, ps) = (x.clone(), parts.clone());
        let results = run_ranks(nparts, move |c| {
            let p = c.rank();
            let op = DistOp::new(&ps[p], &c, 7);
            let range = part2.rank_range(p);
            let mut x_ext = vec![0.0; op.n_ext()];
            x_ext[..op.n_own()].copy_from_slice(&xc[range.clone()]);
            let mut y = vec![0.0; op.n_own()];
            op.apply(&mut x_ext, &mut y);
            let mut gt = vec![0.0; op.n_own()];
            op.apply_adjoint(&xc[range], &mut gt);
            (y, gt)
        });
        let got: Vec<f64> = results.iter().flat_map(|(y, _)| y.clone()).collect();
        let got_t: Vec<f64> = results.iter().flat_map(|(_, t)| t.clone()).collect();
        assert!(util::max_abs_diff(&got, &want) < 1e-12);
        assert!(util::max_abs_diff(&got_t, &want_t) < 1e-12);
    }
}
