//! Distributed layer (paper §3.3): row-block domain decomposition with
//! autograd-compatible halo exchange.
//!
//! The paper runs ranks as CUDA devices over NCCL; this testbed runs
//! ranks either as OS threads over an in-process [`comm::LocalComm`]
//! or as spawned worker PROCESSES over [`transport::ProcComm`]
//! (shared-memory rings with a localhost-socket fallback), all
//! byte-accounted identically.  Everything *structural* is identical:
//!
//! * each rank owns a contiguous row block (after a fill/cut-reducing
//!   permutation from [`partition`]) plus halo metadata;
//! * one halo exchange per SpMV, two `all_reduce` per CG iteration
//!   (Appendix C, Algorithm 1);
//! * the backward pass uses the TRANSPOSED halo exchange `H^T` — same
//!   neighbor graph and message sizes, reversed roles, sum-at-owner
//!   (Eq. 6) — so distributed solves compose with the adjoint framework;
//! * matrix gradients `-lambda_i x_j` are assembled locally on owned
//!   non-zeros with no extra communication.
//!
//! [`DSparseTensor`] / [`DSparseTensorList`] present the paper's typed
//! API on top (`from_global`, `.solve`, `.matvec`, `.eigsh`,
//! `gather_global`).

pub mod comm;
pub mod dist_solver;
pub mod halo;
pub mod newton;
pub mod op;
pub mod partition;
pub mod tensor;
pub mod transport;

pub use comm::{run_ranks, LocalComm, Transport, TransportStats};
pub use dist_solver::{
    dist_bicgstab, dist_cg, dist_cg_ca, dist_cg_pipelined, dist_gmres, dist_lobpcg, dist_minres,
    dist_solve_adjoint, DistAdjointResult, DistIterOpts, DistMethod, DistPrecondKind,
    DistSolveReport,
};
pub use halo::{DistCsr, HaloPlan};
pub use newton::DistPointwiseResidual;
pub use op::DistOp;
pub use partition::{Partition, PartitionStrategy};
pub use tensor::{DSparseTensor, DSparseTensorList};
pub use transport::{maybe_run_worker, proc_solve, CommBackend, ProcComm, ProcOpts, TransportKind};
