//! In-process communicator: the NCCL/Gloo stand-in.
//!
//! P ranks run as OS threads; point-to-point messages travel over
//! per-pair FIFO channels and `all_reduce` is a shared-state butterfly.
//! Every payload is byte-accounted so benches report communication
//! volume the way the paper reports NCCL traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use crate::util::lock_recover;

/// Message: (tag, payload).  Tags catch protocol mismatches early.
type Msg = (u64, Vec<f64>);

struct AllReduceState {
    sum: Vec<f64>,
    count: usize,
    generation: u64,
    result: Vec<f64>,
}

struct Shared {
    nranks: usize,
    ar: Mutex<AllReduceState>,
    cv: Condvar,
    bytes_sent: Vec<AtomicU64>,
    /// Completed all_reduce rounds (a fused multi-scalar reduction
    /// counts ONE round — the latency unit the pipelined-CG ablation
    /// measures).
    reduce_rounds: AtomicU64,
}

/// One rank's endpoint.
pub struct LocalComm {
    rank: usize,
    senders: Vec<Sender<Msg>>,
    receivers: Vec<Mutex<Receiver<Msg>>>,
    shared: Arc<Shared>,
}

impl LocalComm {
    /// Build endpoints for `nranks` ranks.
    pub fn create(nranks: usize) -> Vec<LocalComm> {
        let shared = Arc::new(Shared {
            nranks,
            ar: Mutex::new(AllReduceState {
                sum: Vec::new(),
                count: 0,
                generation: 0,
                result: Vec::new(),
            }),
            cv: Condvar::new(),
            bytes_sent: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            reduce_rounds: AtomicU64::new(0),
        });
        // channels[to][from]
        let mut txs: Vec<Vec<Option<Sender<Msg>>>> = (0..nranks)
            .map(|_| (0..nranks).map(|_| None).collect())
            .collect();
        let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> = (0..nranks)
            .map(|_| (0..nranks).map(|_| None).collect())
            .collect();
        for to in 0..nranks {
            for from in 0..nranks {
                let (tx, rx) = std::sync::mpsc::channel();
                txs[to][from] = Some(tx);
                rxs[to][from] = Some(rx);
            }
        }
        (0..nranks)
            .map(|rank| LocalComm {
                rank,
                senders: (0..nranks)
                    .map(|to| txs[to][rank].take().unwrap()) // rsla-lint: allow(L1, mesh wiring; each channel end is taken exactly once)
                    .collect(),
                receivers: rxs[rank]
                    .iter_mut()
                    .map(|r| Mutex::new(r.take().unwrap())) // rsla-lint: allow(L1, mesh wiring; each channel end is taken exactly once)
                    .collect(),
                shared: shared.clone(),
            })
            .collect()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.shared.nranks
    }

    /// Non-blocking send (unbounded channel: neighbor exchanges post all
    /// sends first, then drain receives — no deadlock by construction).
    pub fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        self.shared.bytes_sent[self.rank].fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        self.senders[to]
            .send((tag, data))
            .expect("receiver rank hung up"); // rsla-lint: allow(L1, a dropped peer rank is an unrecoverable protocol failure)
    }

    /// Blocking receive from a specific rank; asserts the tag matches.
    pub fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        let rx = lock_recover(&self.receivers[from]);
        let (got_tag, data) = rx.recv().expect("sender rank hung up"); // rsla-lint: allow(L1, a dropped peer rank is an unrecoverable protocol failure)
        assert_eq!(
            got_tag, tag,
            "rank {}: tag mismatch from {from} (protocol desync)",
            self.rank
        );
        data
    }

    /// Global sum (the NCCL all_reduce analog).
    pub fn all_reduce_sum(&self, x: f64) -> f64 {
        let mut buf = [x];
        self.all_reduce_inplace(&mut buf);
        buf[0]
    }

    /// FUSED in-place global sum of several scalars in ONE reduction
    /// round — the communication primitive behind single-reduction
    /// (Chronopoulos–Gear / pipelined) CG, which NCCL expresses as one
    /// `all_reduce` over a packed buffer.  The summed result lands
    /// directly in `xs`; the shared accumulation/result buffers are
    /// reused across rounds, so the steady state performs no heap
    /// allocation.
    pub fn all_reduce_inplace(&self, xs: &mut [f64]) {
        let mut s = lock_recover(&self.shared.ar);
        let gen = s.generation;
        if s.count == 0 {
            s.sum.clear();
            s.sum.extend_from_slice(xs);
        } else {
            assert_eq!(
                s.sum.len(),
                xs.len(),
                "rank {}: mismatched all_reduce payload width (protocol desync)",
                self.rank
            );
            for (a, b) in s.sum.iter_mut().zip(xs.iter()) {
                *a += *b;
            }
        }
        s.count += 1;
        if s.count == self.shared.nranks {
            let st = &mut *s;
            st.result.clear();
            st.result.extend_from_slice(&st.sum);
            st.count = 0;
            st.generation += 1;
            self.shared.reduce_rounds.fetch_add(1, Ordering::Relaxed);
            self.shared.cv.notify_all();
            xs.copy_from_slice(&st.result);
        } else {
            while s.generation == gen {
                s = self.shared.cv.wait(s).unwrap_or_else(|p| p.into_inner());
            }
            // a third round cannot start (it would need THIS rank), so
            // `result` still holds this generation's sum
            xs.copy_from_slice(&s.result);
        }
    }

    /// Allocating convenience over [`LocalComm::all_reduce_inplace`].
    pub fn all_reduce_sum_vec(&self, xs: &[f64]) -> Vec<f64> {
        let mut buf = xs.to_vec();
        self.all_reduce_inplace(&mut buf);
        buf
    }

    /// Completed all_reduce rounds across the team (latency units).
    pub fn reduce_rounds(&self) -> u64 {
        self.shared.reduce_rounds.load(Ordering::Relaxed)
    }

    pub fn barrier(&self) {
        self.all_reduce_sum(0.0);
    }

    /// Bytes sent by this rank so far.
    pub fn bytes_sent(&self) -> u64 {
        self.shared.bytes_sent[self.rank].load(Ordering::Relaxed)
    }

    /// Total bytes sent by all ranks.
    pub fn total_bytes(&self) -> u64 {
        self.shared
            .bytes_sent
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }
}

/// [`LocalComm`] is the rank-team [`crate::krylov::Communicator`]: the
/// generic Krylov kernels run distributed by pairing the halo-exchanged
/// operator with this impl, and its round/byte counters are what the
/// reduction-structure tests and the `dist_scaling` bench read.
impl crate::krylov::Communicator for LocalComm {
    fn rank(&self) -> usize {
        LocalComm::rank(self)
    }

    fn size(&self) -> usize {
        LocalComm::size(self)
    }

    fn all_reduce(&self, xs: &mut [f64]) {
        self.all_reduce_inplace(xs);
    }

    fn bytes_sent(&self) -> u64 {
        LocalComm::bytes_sent(self)
    }

    fn reduce_rounds(&self) -> u64 {
        LocalComm::reduce_rounds(self)
    }
}

/// Spawn `nranks` threads, one per communicator endpoint, run `f`, and
/// collect the per-rank results in rank order.  Panics in any rank are
/// propagated (a rank crash must not silently hang the job).
pub fn run_ranks<T, F>(nranks: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(LocalComm) -> T + Send + Sync + 'static,
{
    let comms = LocalComm::create(nranks);
    let f = Arc::new(f);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            std::thread::Builder::new()
                .name(format!("rsla-rank-{}", c.rank()))
                .spawn(move || f(c))
                .expect("spawn rank") // rsla-lint: allow(L1, spawn fails only on OS thread exhaustion)
        })
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(r, h)| h.join().unwrap_or_else(|_| panic!("rank {r} panicked"))) // rsla-lint: allow(L1, run_ranks re-raises rank panics to the caller by design)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_sums_across_ranks() {
        let results = run_ranks(4, |c| c.all_reduce_sum((c.rank() + 1) as f64));
        assert_eq!(results, vec![10.0; 4]);
    }

    #[test]
    fn repeated_all_reduce_generations() {
        let results = run_ranks(3, |c| {
            let mut acc = 0.0;
            for round in 0..50 {
                acc += c.all_reduce_sum((c.rank() * round) as f64);
            }
            acc
        });
        assert!(results.iter().all(|&r| (r - results[0]).abs() < 1e-12));
    }

    #[test]
    fn point_to_point_ring() {
        let results = run_ranks(4, |c| {
            let next = (c.rank() + 1) % 4;
            let prev = (c.rank() + 3) % 4;
            c.send(next, 7, vec![c.rank() as f64]);
            let got = c.recv(prev, 7);
            got[0] as usize
        });
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn bytes_are_accounted() {
        let results = run_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![0.0; 100]);
            } else {
                let _ = c.recv(0, 1);
            }
            c.barrier();
            c.total_bytes()
        });
        assert_eq!(results[0], 800);
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn rank_panic_propagates() {
        run_ranks(2, |c| {
            if c.rank() == 1 {
                panic!("boom");
            }
        });
    }
}
