//! In-process communicator: the NCCL/Gloo stand-in.
//!
//! P ranks run as OS threads; point-to-point messages travel over
//! per-pair FIFO channels and `all_reduce` is a shared-state butterfly.
//! Every payload is byte-accounted so benches report communication
//! volume the way the paper reports NCCL traffic.
//!
//! Reduction order is CANONICAL: every backend folds per-rank
//! contributions in rank-ascending order (`((c0 + c1) + c2) + ...`),
//! never arrival order, so a solve's floating-point trajectory is a
//! function of the partition alone — identical across [`LocalComm`]
//! and the process-separated `transport::ProcComm`, pinned bitwise by
//! tests here and in `tests/proc_comm.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use crate::util::lock_recover;

/// Message: (tag, payload).  Tags catch protocol mismatches early.
type Msg = (u64, Vec<f64>);

/// Wire-level statistics reported by [`Transport::transport_stats`].
///
/// `bytes_sent`/`reduce_rounds` on [`crate::krylov::Communicator`] count
/// ALGORITHMIC traffic (halo payloads, latency rounds) identically on
/// every backend; this struct exposes what the PHYSICAL transport did
/// on top — reduction wire traffic, per-message overhead, and doorbell
/// wait latency.  In-process backends report zeros.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TransportStats {
    /// Bytes that crossed the physical transport (rings or sockets),
    /// including reduction traffic and framing headers.
    pub wire_bytes: u64,
    /// Messages pushed onto the wire.
    pub wire_msgs: u64,
    /// Blocking waits observed by the receive path (doorbell polls
    /// that did not complete immediately).
    pub doorbell_waits: u64,
    /// Doorbell wait-time percentiles, microseconds.
    pub doorbell_p50_us: f64,
    pub doorbell_p99_us: f64,
    pub doorbell_max_us: f64,
}

/// Point-to-point transport surface shared by every rank-team backend.
///
/// Extends [`crate::krylov::Communicator`] with the tagged send/recv
/// pair that halo exchanges ride, so distributed kernels are written
/// once against `&dyn Transport` and the backend — in-process
/// [`LocalComm`] threads or process-separated
/// [`super::transport::ProcComm`] workers — is chosen at the call
/// site.  MPI/NCCL slot in later by implementing this trait.
pub trait Transport: crate::krylov::Communicator {
    /// Non-blocking tagged send of `data` to rank `to`.
    fn send(&self, to: usize, tag: u64, data: Vec<f64>);
    /// Blocking tagged receive from rank `from`; implementations must
    /// verify the tag and treat a mismatch as a protocol failure.
    fn recv(&self, from: usize, tag: u64) -> Vec<f64>;
    /// Wire-level statistics (zeros for in-process transports).
    fn transport_stats(&self) -> TransportStats {
        TransportStats::default()
    }
}

struct AllReduceState {
    /// Per-rank contributions for the in-flight round, folded in
    /// rank-ascending order once the last rank arrives (canonical
    /// reduction order — see module docs).
    contribs: Vec<Vec<f64>>,
    width: usize,
    count: usize,
    generation: u64,
    result: Vec<f64>,
}

struct Shared {
    nranks: usize,
    ar: Mutex<AllReduceState>,
    cv: Condvar,
    bytes_sent: Vec<AtomicU64>,
    /// Completed all_reduce rounds (a fused multi-scalar reduction
    /// counts ONE round — the latency unit the pipelined-CG ablation
    /// measures).
    reduce_rounds: AtomicU64,
}

/// One rank's endpoint.
pub struct LocalComm {
    rank: usize,
    senders: Vec<Sender<Msg>>,
    receivers: Vec<Mutex<Receiver<Msg>>>,
    shared: Arc<Shared>,
}

impl LocalComm {
    /// Build endpoints for `nranks` ranks.
    pub fn create(nranks: usize) -> Vec<LocalComm> {
        let shared = Arc::new(Shared {
            nranks,
            ar: Mutex::new(AllReduceState {
                contribs: (0..nranks).map(|_| Vec::new()).collect(),
                width: 0,
                count: 0,
                generation: 0,
                result: Vec::new(),
            }),
            cv: Condvar::new(),
            bytes_sent: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            reduce_rounds: AtomicU64::new(0),
        });
        // channels[to][from]
        let mut txs: Vec<Vec<Option<Sender<Msg>>>> = (0..nranks)
            .map(|_| (0..nranks).map(|_| None).collect())
            .collect();
        let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> = (0..nranks)
            .map(|_| (0..nranks).map(|_| None).collect())
            .collect();
        for to in 0..nranks {
            for from in 0..nranks {
                let (tx, rx) = std::sync::mpsc::channel();
                txs[to][from] = Some(tx);
                rxs[to][from] = Some(rx);
            }
        }
        (0..nranks)
            .map(|rank| LocalComm {
                rank,
                senders: (0..nranks)
                    .map(|to| txs[to][rank].take().unwrap()) // rsla-lint: allow(L1, mesh wiring; each channel end is taken exactly once)
                    .collect(),
                receivers: rxs[rank]
                    .iter_mut()
                    .map(|r| Mutex::new(r.take().unwrap())) // rsla-lint: allow(L1, mesh wiring; each channel end is taken exactly once)
                    .collect(),
                shared: shared.clone(),
            })
            .collect()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.shared.nranks
    }

    /// Non-blocking send (unbounded channel: neighbor exchanges post all
    /// sends first, then drain receives — no deadlock by construction).
    pub fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        self.shared.bytes_sent[self.rank].fetch_add((data.len() * 8) as u64, Ordering::Relaxed);
        self.senders[to]
            .send((tag, data))
            .expect("receiver rank hung up"); // rsla-lint: allow(L1, a dropped peer rank is an unrecoverable protocol failure)
    }

    /// Blocking receive from a specific rank; asserts the tag matches.
    pub fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        let rx = lock_recover(&self.receivers[from]);
        let (got_tag, data) = rx.recv().expect("sender rank hung up"); // rsla-lint: allow(L1, a dropped peer rank is an unrecoverable protocol failure)
        assert_eq!(
            got_tag, tag,
            "rank {}: tag mismatch from {from} (protocol desync)",
            self.rank
        );
        data
    }

    /// Global sum (the NCCL all_reduce analog).
    pub fn all_reduce_sum(&self, x: f64) -> f64 {
        let mut buf = [x];
        self.all_reduce_inplace(&mut buf);
        buf[0]
    }

    /// FUSED in-place global sum of several scalars in ONE reduction
    /// round — the communication primitive behind single-reduction
    /// (Chronopoulos–Gear / pipelined) CG and the per-outer-step packed
    /// Gram reduction of s-step CA-CG, which NCCL expresses as one
    /// `all_reduce` over a packed buffer.  Contributions are buffered
    /// per rank and folded in rank-ascending order by the last arriver,
    /// so the result is bitwise independent of thread scheduling.  The
    /// summed result lands directly in `xs`; the shared per-rank/result
    /// buffers are reused across rounds, so the steady state performs
    /// no heap allocation.
    pub fn all_reduce_inplace(&self, xs: &mut [f64]) {
        let mut s = lock_recover(&self.shared.ar);
        let gen = s.generation;
        if s.count == 0 {
            s.width = xs.len();
        } else {
            assert_eq!(
                s.width,
                xs.len(),
                "rank {}: mismatched all_reduce payload width (protocol desync)",
                self.rank
            );
        }
        {
            let slot = &mut s.contribs[self.rank];
            slot.clear();
            slot.extend_from_slice(xs);
        }
        s.count += 1;
        if s.count == self.shared.nranks {
            let st = &mut *s;
            st.result.clear();
            st.result.extend_from_slice(&st.contribs[0]);
            for c in st.contribs.iter().skip(1) {
                for (acc, v) in st.result.iter_mut().zip(c.iter()) {
                    *acc += *v;
                }
            }
            st.count = 0;
            st.generation += 1;
            self.shared.reduce_rounds.fetch_add(1, Ordering::Relaxed);
            self.shared.cv.notify_all();
            xs.copy_from_slice(&st.result);
        } else {
            while s.generation == gen {
                s = self.shared.cv.wait(s).unwrap_or_else(|p| p.into_inner());
            }
            // a third round cannot start (it would need THIS rank), so
            // `result` still holds this generation's sum
            xs.copy_from_slice(&s.result);
        }
    }

    /// Allocating convenience over [`LocalComm::all_reduce_inplace`].
    pub fn all_reduce_sum_vec(&self, xs: &[f64]) -> Vec<f64> {
        let mut buf = xs.to_vec();
        self.all_reduce_inplace(&mut buf);
        buf
    }

    /// Completed all_reduce rounds across the team (latency units).
    pub fn reduce_rounds(&self) -> u64 {
        self.shared.reduce_rounds.load(Ordering::Relaxed)
    }

    pub fn barrier(&self) {
        self.all_reduce_sum(0.0);
    }

    /// Bytes sent by this rank so far.
    pub fn bytes_sent(&self) -> u64 {
        self.shared.bytes_sent[self.rank].load(Ordering::Relaxed)
    }

    /// Total bytes sent by all ranks.
    pub fn total_bytes(&self) -> u64 {
        self.shared
            .bytes_sent
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }
}

/// [`LocalComm`] is the rank-team [`crate::krylov::Communicator`]: the
/// generic Krylov kernels run distributed by pairing the halo-exchanged
/// operator with this impl, and its round/byte counters are what the
/// reduction-structure tests and the `dist_scaling` bench read.
impl crate::krylov::Communicator for LocalComm {
    fn rank(&self) -> usize {
        LocalComm::rank(self)
    }

    fn size(&self) -> usize {
        LocalComm::size(self)
    }

    fn all_reduce(&self, xs: &mut [f64]) {
        self.all_reduce_inplace(xs);
    }

    fn bytes_sent(&self) -> u64 {
        LocalComm::bytes_sent(self)
    }

    fn reduce_rounds(&self) -> u64 {
        LocalComm::reduce_rounds(self)
    }
}

/// [`LocalComm`] is also the in-process [`Transport`]: tagged sends
/// ride the per-pair FIFO channels and wire stats stay zero (nothing
/// crosses a process boundary).
impl Transport for LocalComm {
    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        LocalComm::send(self, to, tag, data);
    }

    fn recv(&self, from: usize, tag: u64) -> Vec<f64> {
        LocalComm::recv(self, from, tag)
    }
}

/// Spawn `nranks` threads, one per communicator endpoint, run `f`, and
/// collect the per-rank results in rank order.  Panics in any rank are
/// propagated (a rank crash must not silently hang the job).
pub fn run_ranks<T, F>(nranks: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(LocalComm) -> T + Send + Sync + 'static,
{
    let comms = LocalComm::create(nranks);
    let f = Arc::new(f);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|c| {
            let f = f.clone();
            std::thread::Builder::new()
                .name(format!("rsla-rank-{}", c.rank()))
                .spawn(move || f(c))
                .expect("spawn rank") // rsla-lint: allow(L1, spawn fails only on OS thread exhaustion)
        })
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(r, h)| h.join().unwrap_or_else(|_| panic!("rank {r} panicked"))) // rsla-lint: allow(L1, run_ranks re-raises rank panics to the caller by design)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reduce_sums_across_ranks() {
        let results = run_ranks(4, |c| c.all_reduce_sum((c.rank() + 1) as f64));
        assert_eq!(results, vec![10.0; 4]);
    }

    #[test]
    fn repeated_all_reduce_generations() {
        let results = run_ranks(3, |c| {
            let mut acc = 0.0;
            for round in 0..50 {
                acc += c.all_reduce_sum((c.rank() * round) as f64);
            }
            acc
        });
        assert!(results.iter().all(|&r| (r - results[0]).abs() < 1e-12));
    }

    /// Canonical rank-ascending fold: with catastrophic-cancellation
    /// payloads the result depends on summation order, so this pins
    /// BOTH determinism across repeats and the exact fold order
    /// (((c0 + c1) + c2) + c3 — any other association differs
    /// bitwise).
    #[test]
    fn all_reduce_order_is_rank_ascending_and_deterministic() {
        let contrib = [1e16, 1.0, -1e16, 1.0];
        let mut expect = contrib[0];
        for c in &contrib[1..] {
            expect += *c;
        }
        for trial in 0..20 {
            let results = run_ranks(4, move |c| {
                // stagger arrival order differently each trial
                if (c.rank() + trial) % 4 == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
                c.all_reduce_sum(contrib[c.rank()])
            });
            for r in results {
                assert_eq!(r.to_bits(), expect.to_bits());
            }
        }
    }

    #[test]
    fn point_to_point_ring() {
        let results = run_ranks(4, |c| {
            let next = (c.rank() + 1) % 4;
            let prev = (c.rank() + 3) % 4;
            c.send(next, 7, vec![c.rank() as f64]);
            let got = c.recv(prev, 7);
            got[0] as usize
        });
        assert_eq!(results, vec![3, 0, 1, 2]);
    }

    #[test]
    fn bytes_are_accounted() {
        let results = run_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![0.0; 100]);
            } else {
                let _ = c.recv(0, 1);
            }
            c.barrier();
            c.total_bytes()
        });
        assert_eq!(results[0], 800);
    }

    #[test]
    #[should_panic(expected = "rank 1 panicked")]
    fn rank_panic_propagates() {
        run_ranks(2, |c| {
            if c.rank() == 1 {
                panic!("boom");
            }
        });
    }
}
