//! Halo plan + distributed SpMV with forward (H) and transposed (H^T)
//! exchanges (paper §3.3, Eqs. 5-6).
//!
//! Local index space of rank p: `[0, n_own)` are owned rows (new/global
//! indices `offsets[p]..offsets[p+1]`), `[n_own, n_own + n_halo)` are
//! halo copies of remote entries referenced by locally owned rows.

use super::comm::Transport;
use super::partition::Partition;
use crate::sparse::{Coo, Csr};

/// Communication plan for one rank.
#[derive(Clone, Debug)]
pub struct HaloPlan {
    pub rank: usize,
    pub n_own: usize,
    /// Global (new-space) indices of halo slots, grouped by owner.
    pub halo_globals: Vec<usize>,
    /// (neighbor rank, local-owned indices to SEND to that neighbor).
    pub send: Vec<(usize, Vec<usize>)>,
    /// (neighbor rank, halo-slot offsets to RECEIVE into), aligned with
    /// the neighbor's send list for us.
    pub recv: Vec<(usize, Vec<usize>)>,
}

impl HaloPlan {
    pub fn n_halo(&self) -> usize {
        self.halo_globals.len()
    }

    /// Bytes moved by one forward exchange from this rank.
    pub fn send_bytes(&self) -> u64 {
        self.send.iter().map(|(_, v)| (v.len() * 8) as u64).sum()
    }
}

/// One rank's share of the matrix: owned rows with columns remapped to
/// the local index space.
#[derive(Clone, Debug)]
pub struct DistCsr {
    pub local: Csr,
    pub plan: HaloPlan,
    /// Lazily-extracted owned diagonal block (owned rows x owned cols),
    /// built at most once per share: warm `BlockLu`/`BlockAmg`
    /// preconditioner builds skip the per-call O(nnz) rebuild (cloning
    /// a share clones the cached block, not the extraction work).
    block: std::sync::OnceLock<std::sync::Arc<Csr>>,
}

impl DistCsr {
    pub fn new(local: Csr, plan: HaloPlan) -> Self {
        debug_assert!(
            local.validate().is_ok(),
            "dist share: invalid local CSR: {:?}",
            local.validate()
        );
        debug_assert_eq!(local.nrows, plan.n_own, "dist share: local rows != owned rows");
        debug_assert_eq!(
            local.ncols,
            plan.n_own + plan.halo_globals.len(),
            "dist share: local cols != owned + halo columns"
        );
        DistCsr {
            local,
            plan,
            block: std::sync::OnceLock::new(),
        }
    }

    /// Bytes held by this rank's matrix share (per-GPU memory column in
    /// Table 4).
    pub fn bytes(&self) -> u64 {
        crate::metrics::mem::csr_bytes(self.local.nrows, self.local.nnz())
    }

    /// The owned diagonal block (owned rows x owned cols) of this
    /// share, extracted once and cached.  Block preconditioners
    /// (`BlockLu`, `BlockAmg`) key their factorizations on this matrix;
    /// caching it makes the warm path O(1) instead of O(nnz).
    pub fn owned_diag_block(&self) -> std::sync::Arc<Csr> {
        self.block
            .get_or_init(|| {
                let n_own = self.plan.n_own;
                let mut coo = Coo::with_capacity(n_own, n_own, self.local.nnz());
                for r in 0..n_own {
                    let (cols, vals) = self.local.row(r);
                    for (c, v) in cols.iter().zip(vals) {
                        if *c < n_own {
                            coo.push(r, *c, *v);
                        }
                    }
                }
                std::sync::Arc::new(coo.to_csr())
            })
            .clone()
    }

    /// The cached block, if one has been extracted (tests pin the
    /// skip-rebuild satellite by pointer identity through this).
    pub fn cached_block(&self) -> Option<std::sync::Arc<Csr>> {
        self.block.get().cloned()
    }
}

/// Partition the (already permuted) global matrix into per-rank shares.
/// `a_perm` must be `a.permute_sym(&partition.perm)`.
pub fn distribute(a_perm: &Csr, part: &Partition) -> Vec<DistCsr> {
    let nparts = part.nparts;
    // 1. per-rank halo sets
    let mut halos: Vec<Vec<usize>> = vec![Vec::new(); nparts];
    for p in 0..nparts {
        let range = part.rank_range(p);
        let mut set = std::collections::BTreeSet::new();
        for r in range.clone() {
            for &c in a_perm.row(r).0 {
                if !range.contains(&c) {
                    set.insert(c);
                }
            }
        }
        halos[p] = set.into_iter().collect();
    }
    // 2. send/recv lists: p receives halo g from owner q; so q sends its
    //    local (g - offset_q) to p.
    let mut send: Vec<std::collections::BTreeMap<usize, Vec<usize>>> =
        vec![std::collections::BTreeMap::new(); nparts];
    let mut recv: Vec<std::collections::BTreeMap<usize, Vec<usize>>> =
        vec![std::collections::BTreeMap::new(); nparts];
    for p in 0..nparts {
        for (slot, &g) in halos[p].iter().enumerate() {
            let q = part.owner_of_new(g);
            debug_assert_ne!(p, q);
            send[q].entry(p).or_default().push(g - part.offsets[q]);
            recv[p].entry(q).or_default().push(slot);
        }
    }
    // 3. local matrices with remapped columns
    (0..nparts)
        .map(|p| {
            let range = part.rank_range(p);
            let n_own = range.len();
            let halo_index: std::collections::HashMap<usize, usize> = halos[p]
                .iter()
                .enumerate()
                .map(|(slot, &g)| (g, n_own + slot))
                .collect();
            let mut coo = Coo::with_capacity(n_own, n_own + halos[p].len(), a_perm.nnz() / nparts + 1);
            for (li, r) in range.clone().enumerate() {
                let (cols, vals) = a_perm.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    let lc = if range.contains(c) {
                        c - range.start
                    } else {
                        halo_index[c]
                    };
                    coo.push(li, lc, *v);
                }
            }
            DistCsr::new(
                coo.to_csr(),
                HaloPlan {
                    rank: p,
                    n_own,
                    halo_globals: halos[p].clone(),
                    send: send[p].iter().map(|(k, v)| (*k, v.clone())).collect(),
                    recv: recv[p].iter().map(|(k, v)| (*k, v.clone())).collect(),
                },
            )
        })
        .collect()
}

/// Forward halo exchange H: fill `x_ext[n_own..]` with neighbor-owned
/// values.  `x_ext` holds owned values in `[0, n_own)`.
pub fn halo_exchange(plan: &HaloPlan, x_ext: &mut [f64], comm: &dyn Transport, tag: u64) {
    for (q, idxs) in &plan.send {
        let payload: Vec<f64> = idxs.iter().map(|&i| x_ext[i]).collect();
        comm.send(*q, tag, payload);
    }
    for (q, slots) in &plan.recv {
        let data = comm.recv(*q, tag);
        debug_assert_eq!(data.len(), slots.len());
        for (&slot, &v) in slots.iter().zip(&data) {
            x_ext[plan.n_own + slot] = v;
        }
    }
}

/// Transposed halo exchange H^T (paper Eq. 6): send halo-slot gradients
/// BACK to their owners, which SUM them into owned entries.  Same
/// neighbor graph and message sizes as H, reversed roles.
pub fn halo_exchange_adjoint(plan: &HaloPlan, g_ext: &mut [f64], comm: &dyn Transport, tag: u64) {
    // reverse of recv: we send the halo gradients to the owner q
    for (q, slots) in &plan.recv {
        let payload: Vec<f64> = slots.iter().map(|&s| g_ext[plan.n_own + s]).collect();
        comm.send(*q, tag, payload);
    }
    // reverse of send: owners receive and accumulate into owned entries
    for (q, idxs) in &plan.send {
        let data = comm.recv(*q, tag);
        debug_assert_eq!(data.len(), idxs.len());
        for (&i, &v) in idxs.iter().zip(&data) {
            g_ext[i] += v;
        }
    }
}

/// Distributed SpMV: y_own = A_local * H(x_own) (Eq. 5).
/// `x_ext` is the rank's (n_own + n_halo) workspace with owned values
/// already in place; halo slots are refreshed here.
pub fn dist_spmv(
    a: &DistCsr,
    x_ext: &mut [f64],
    y_own: &mut [f64],
    comm: &dyn Transport,
    tag: u64,
) {
    halo_exchange(&a.plan, x_ext, comm, tag);
    a.local.spmv(x_ext, y_own);
}

/// Adjoint of the distributed SpMV: given dL/dy_own, produce dL/dx_own
/// = H^T (A_local^T dL/dy) — the backward path of Eq. 6.
pub fn dist_spmv_adjoint(
    a: &DistCsr,
    gy_own: &[f64],
    gx_own: &mut [f64],
    comm: &dyn Transport,
    tag: u64,
) {
    let n_ext = a.plan.n_own + a.plan.n_halo();
    let mut g_ext = vec![0.0; n_ext];
    a.local.spmv_t(gy_own, &mut g_ext);
    halo_exchange_adjoint(&a.plan, &mut g_ext, comm, tag);
    gx_own.copy_from_slice(&g_ext[..a.plan.n_own]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::comm::run_ranks;
    use crate::distributed::partition::{partition, PartitionStrategy};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, dot, Prng};
    use std::sync::Arc;

    fn setup(g: usize, nparts: usize) -> (Csr, Partition, Vec<DistCsr>) {
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = distribute(&a_perm, &part);
        (a_perm, part, parts)
    }

    #[test]
    fn distributed_spmv_matches_global() {
        let (a_perm, part, parts) = setup(12, 4);
        let n = a_perm.nrows;
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(n);
        let want = a_perm.matvec(&x);

        let parts = Arc::new(parts);
        let part2 = Arc::new(part);
        let x2 = Arc::new(x);
        let results = run_ranks(4, move |c| {
            let p = c.rank();
            let a = &parts[p];
            let range = part2.rank_range(p);
            let mut x_ext = vec![0.0; a.plan.n_own + a.plan.n_halo()];
            x_ext[..a.plan.n_own].copy_from_slice(&x2[range.clone()]);
            let mut y = vec![0.0; a.plan.n_own];
            dist_spmv(a, &mut x_ext, &mut y, &c, 1);
            y
        });
        let got: Vec<f64> = results.concat();
        assert!(util::max_abs_diff(&got, &want) < 1e-12);
    }

    /// THE adjoint identity: <H x, y> = <x, H^T y> lifted to the full
    /// SpMV — <A x, y>_global = <x, A^T y>_global when computed via
    /// dist_spmv and dist_spmv_adjoint.
    #[test]
    fn halo_adjoint_identity() {
        let (a_perm, part, parts) = setup(10, 3);
        let n = a_perm.nrows;
        let mut rng = Prng::new(1);
        let x = Arc::new(rng.normal_vec(n));
        let y = Arc::new(rng.normal_vec(n));
        let parts = Arc::new(parts);
        let part2 = Arc::new(part);

        let (xc, yc) = (x.clone(), y.clone());
        let lhs_rhs = run_ranks(3, move |c| {
            let p = c.rank();
            let a = &parts[p];
            let range = part2.rank_range(p);
            // forward: <A x, y> on this rank's rows
            let mut x_ext = vec![0.0; a.plan.n_own + a.plan.n_halo()];
            x_ext[..a.plan.n_own].copy_from_slice(&xc[range.clone()]);
            let mut ax = vec![0.0; a.plan.n_own];
            dist_spmv(a, &mut x_ext, &mut ax, &c, 1);
            let lhs_local = dot(&ax, &yc[range.clone()]);
            let lhs = c.all_reduce_sum(lhs_local);
            // adjoint: <x, A^T y> via dist_spmv_adjoint
            let mut gx = vec![0.0; a.plan.n_own];
            dist_spmv_adjoint(a, &yc[range.clone()], &mut gx, &c, 2);
            let rhs_local = dot(&gx, &xc[range.clone()]);
            let rhs = c.all_reduce_sum(rhs_local);
            (lhs, rhs)
        });
        for (lhs, rhs) in lhs_rhs {
            assert!(
                (lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0),
                "<Ax,y>={lhs} vs <x,A^Ty>={rhs}"
            );
        }
    }

    #[test]
    fn halo_sizes_follow_surface_law() {
        // |H_p| ~ O((n/P)^(1/2)) on 2D grids (paper §3.3)
        let (_, _, parts16) = setup(16, 4);
        let (_, _, parts32) = setup(32, 4);
        let h16: usize = parts16.iter().map(|p| p.plan.n_halo()).max().unwrap();
        let h32: usize = parts32.iter().map(|p| p.plan.n_halo()).max().unwrap();
        // n quadruples; halo should ~double (sqrt growth), allow slack
        assert!(
            h32 <= 3 * h16,
            "halo grew superlinearly: {h16} -> {h32}"
        );
    }

    #[test]
    fn rcb_partition_also_correct() {
        let g = 12;
        let sys = poisson2d(g, None);
        let part = partition(&sys.matrix, Some(&sys.coords), 4, PartitionStrategy::Rcb);
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(distribute(&a_perm, &part));
        let n = g * g;
        let mut rng = Prng::new(2);
        let x = Arc::new(rng.normal_vec(n));
        let want = a_perm.matvec(&x);
        let part2 = Arc::new(part);
        let results = run_ranks(4, move |c| {
            let p = c.rank();
            let a = &parts[p];
            let range = part2.rank_range(p);
            let mut x_ext = vec![0.0; a.plan.n_own + a.plan.n_halo()];
            x_ext[..a.plan.n_own].copy_from_slice(&x[range.clone()]);
            let mut y = vec![0.0; a.plan.n_own];
            dist_spmv(a, &mut x_ext, &mut y, &c, 3);
            y
        });
        let got: Vec<f64> = results.concat();
        assert!(util::max_abs_diff(&got, &want) < 1e-12);
    }
}
