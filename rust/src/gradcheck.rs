//! Central finite-difference gradient verification (paper §4.3, Eq. 7).
//!
//! `rel_error` reproduces the paper's metric: relative error between an
//! analytic directional derivative and the centered difference
//! `(L(theta + eps d) - L(theta - eps d)) / (2 eps)` along random
//! perturbation directions.

use crate::util::{dot, Prng};

/// Result of a directional gradient check.
#[derive(Clone, Debug)]
pub struct GradCheck {
    pub analytic: f64,
    pub numeric: f64,
    pub rel_error: f64,
}

/// Check an analytic gradient `grad` of `loss(theta)` along `trials`
/// random directions; returns the worst-case relative error.
pub fn check_direction<F>(
    loss: F,
    theta0: &[f64],
    grad: &[f64],
    eps: f64,
    trials: usize,
    seed: u64,
) -> GradCheck
where
    F: Fn(&[f64]) -> f64,
{
    assert_eq!(theta0.len(), grad.len());
    let mut rng = Prng::new(seed);
    let mut worst = GradCheck {
        analytic: 0.0,
        numeric: 0.0,
        rel_error: 0.0,
    };
    for _ in 0..trials {
        let d = rng.normal_vec(theta0.len());
        let analytic = dot(grad, &d);
        let mut tp = theta0.to_vec();
        let mut tm = theta0.to_vec();
        for i in 0..theta0.len() {
            tp[i] += eps * d[i];
            tm[i] -= eps * d[i];
        }
        let numeric = (loss(&tp) - loss(&tm)) / (2.0 * eps);
        let rel = (analytic - numeric).abs() / numeric.abs().max(1e-12);
        if rel > worst.rel_error {
            worst = GradCheck {
                analytic,
                numeric,
                rel_error: rel,
            };
        }
    }
    worst
}

/// Like [`check_direction`], but the perturbation directions live on a
/// *symmetric* sparsity pattern (d_ij = d_ji on the stored entries).
/// Needed for eigenvalue gradients, which are defined only on the
/// symmetric manifold: an asymmetric perturbation would leave it and
/// the Hellmann–Feynman formula would not apply.
pub fn check_symmetric_direction<F>(
    loss: F,
    pattern: &crate::sparse::Pattern,
    vals0: &[f64],
    grad: &[f64],
    eps: f64,
    seed: u64,
) -> GradCheck
where
    F: Fn(&[f64]) -> f64,
{
    assert_eq!(vals0.len(), pattern.nnz());
    assert_eq!(grad.len(), pattern.nnz());
    let mut rng = Prng::new(seed);
    let raw = rng.normal_vec(pattern.nnz());
    // symmetrize: d_k(r,c) = (raw_k + raw_{k'}) / 2 where k' stores (c,r)
    let mut d = vec![0.0; pattern.nnz()];
    for r in 0..pattern.nrows {
        for k in pattern.indptr[r]..pattern.indptr[r + 1] {
            let c = pattern.indices[k];
            let kt = pattern
                .find(c, r)
                .expect("pattern must be structurally symmetric"); // rsla-lint: allow(L1, gradcheck requires structurally symmetric patterns by contract)
            d[k] = 0.5 * (raw[k] + raw[kt]);
        }
    }
    let analytic = dot(grad, &d);
    let mut vp = vals0.to_vec();
    let mut vm = vals0.to_vec();
    for i in 0..vals0.len() {
        vp[i] += eps * d[i];
        vm[i] -= eps * d[i];
    }
    let numeric = (loss(&vp) - loss(&vm)) / (2.0 * eps);
    GradCheck {
        analytic,
        numeric,
        rel_error: (analytic - numeric).abs() / numeric.abs().max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_gradient_checks_clean() {
        // L = ||theta||^2, grad = 2 theta
        let theta: Vec<f64> = vec![1.0, -2.0, 3.0];
        let grad: Vec<f64> = theta.iter().map(|t| 2.0 * t).collect();
        let r = check_direction(
            |t| t.iter().map(|x| x * x).sum(),
            &theta,
            &grad,
            1e-6,
            5,
            0,
        );
        assert!(r.rel_error < 1e-8, "rel {}", r.rel_error);
    }

    #[test]
    fn wrong_gradient_is_detected() {
        let theta = vec![1.0, 2.0];
        let wrong = vec![1.0, 1.0];
        let r = check_direction(|t| t.iter().map(|x| x * x).sum(), &theta, &wrong, 1e-6, 5, 0);
        assert!(r.rel_error > 1e-2);
    }
}
