//! MINRES (Paige & Saunders 1975): Krylov solver for symmetric —
//! possibly *indefinite* — systems.
//!
//! The paper's Appendix A notes "additional Krylov variants (e.g.
//! GMRES, LGMRES, MINRES, QMR, LSQR) are wrapped where the underlying
//! library provides them"; our substrate IS the underlying library, so
//! MINRES is implemented directly.  It fills the gap between CG
//! (requires SPD) and GMRES (no symmetry exploited, O(m n) memory for
//! the Arnoldi basis): symmetric Lanczos three-term recurrence, O(n)
//! memory, monotone residual.

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::metrics::MemTracker;
use crate::util::dot;

/// Solve A x = b for symmetric (indefinite OK) A with preconditioned
/// MINRES, x0 = 0.  The preconditioner must be SPD.
pub fn minres(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.nrows();
    assert_eq!(n, a.ncols(), "minres needs a square operator");
    assert_eq!(n, b.len());

    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);

    let mut x = mem.buf(n);
    let mut r1 = mem.buf(n); // v_{k-1} (unscaled Lanczos vectors)
    let mut r2 = mem.buf(n); // v_k
    let mut y = mem.buf(n); // M^{-1} r2
    let mut w = mem.buf(n);
    let mut w1 = mem.buf(n);
    let mut w2 = mem.buf(n);
    let mut v = mem.buf(n);

    r2.data.copy_from_slice(b);
    m.apply(&r2, &mut y);
    let mut beta1 = dot(&r2, &y);
    if beta1 < 0.0 {
        // preconditioner not SPD
        return IterResult {
            x: x.data.clone(),
            iters: 0,
            residual: crate::util::norm2(b),
            converged: false,
            breakdown: true,
            history: vec![],
        };
    }
    if beta1 == 0.0 {
        return IterResult {
            x: x.data.clone(),
            iters: 0,
            residual: 0.0,
            converged: true,
            breakdown: false,
            history: vec![0.0],
        };
    }
    beta1 = beta1.sqrt();

    // QR of the tridiagonal via Givens rotations, updated incrementally.
    let (mut oldb, mut beta) = (0.0_f64, beta1);
    let mut dbar = 0.0_f64;
    let mut epsln = 0.0_f64;
    let mut phibar = beta1;
    let (mut cs, mut sn) = (-1.0_f64, 0.0_f64);

    let mut history = Vec::new();
    if opts.record_history {
        history.push(phibar);
    }

    let mut iters = 0;
    let mut converged = false;
    let mut breakdown = false;
    while iters < opts.max_iters {
        iters += 1;
        // --- Lanczos step ---
        let s = 1.0 / beta;
        for i in 0..n {
            v.data[i] = y.data[i] * s;
        }
        a.apply(&v, &mut y);
        if iters >= 2 {
            let c = beta / oldb;
            for i in 0..n {
                y.data[i] -= c * r1.data[i];
            }
        }
        let alfa = dot(&v, &y);
        {
            let c = alfa / beta;
            for i in 0..n {
                y.data[i] -= c * r2.data[i];
            }
        }
        r1.data.copy_from_slice(&r2.data);
        r2.data.copy_from_slice(&y.data);
        m.apply(&r2, &mut y);
        oldb = beta;
        let betasq = dot(&r2, &y);
        if betasq < 0.0 {
            breakdown = true;
            break; // preconditioner lost positive-definiteness
        }
        beta = betasq.sqrt();

        // --- update QR factorization ---
        let oldeps = epsln;
        let delta = cs * dbar + sn * alfa;
        let gbar = sn * dbar - cs * alfa;
        epsln = sn * beta;
        dbar = -cs * beta;

        let gamma = (gbar * gbar + beta * beta).sqrt().max(f64::MIN_POSITIVE);
        cs = gbar / gamma;
        sn = beta / gamma;
        let phi = cs * phibar;
        phibar *= sn;

        // --- update solution ---
        let denom = 1.0 / gamma;
        for i in 0..n {
            w1.data[i] = w2.data[i];
            w2.data[i] = w.data[i];
            w.data[i] = (v.data[i] - oldeps * w1.data[i] - delta * w2.data[i]) * denom;
            x.data[i] += phi * w.data[i];
        }

        if opts.record_history {
            history.push(phibar);
        }
        if phibar <= opts.tol {
            converged = true;
            break;
        }
    }

    // true residual (phibar tracks the preconditioned norm)
    let mut ax = vec![0.0; n];
    a.apply(&x.data, &mut ax);
    let mut rr = 0.0;
    for i in 0..n {
        let d = b[i] - ax[i];
        rr += d * d;
    }
    let residual = rr.sqrt();

    let converged = converged || residual <= opts.tol * 10.0;
    IterResult {
        x: x.data.clone(),
        iters,
        residual,
        converged,
        breakdown: breakdown && !converged,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::{Identity, Jacobi};
    use crate::sparse::poisson::poisson2d;
    use crate::sparse::Coo;
    use crate::util::{rel_l2, Prng};

    #[test]
    fn solves_spd_poisson() {
        let g = 16;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let r = minres(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-10,
                max_iters: 5000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged, "residual {}", r.residual);
        assert!(rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn solves_symmetric_indefinite_where_cg_breaks() {
        // A = Poisson - sigma I with sigma inside the spectrum: symmetric
        // but indefinite.  CG's pAp > 0 assumption fails; MINRES converges.
        let g = 10;
        let n = g * g;
        let sys = poisson2d(g, None);
        let sigma = 30.0; // between eigenvalues of the 10x10 grid Laplacian
        let mut coo = Coo::with_capacity(n, n, sys.matrix.nnz());
        for r in 0..n {
            let (cols, vals) = sys.matrix.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, if *c == r { v - sigma } else { *v });
            }
        }
        let a = coo.to_csr();
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(n);

        let mr = minres(
            &a,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-9,
                max_iters: 20_000,
                record_history: false,
            },
            None,
        );
        assert!(mr.converged, "minres residual {}", mr.residual);
        assert!(rel_l2(&a.matvec(&mr.x), &b) < 1e-7);

        let cgr = crate::iterative::cg(
            &a,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-9,
                max_iters: 20_000,
                record_history: false,
            },
            None,
        );
        assert!(
            !cgr.converged,
            "CG should break down on an indefinite system"
        );
    }

    #[test]
    fn jacobi_preconditioning_reduces_iterations() {
        let g = 24;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let opts = IterOpts {
            tol: 1e-8,
            max_iters: 10_000,
            record_history: false,
        };
        let plain = minres(&sys.matrix, &b, &Identity, &opts, None);
        let jac = Jacobi::new(&sys.matrix).unwrap();
        let pre = minres(&sys.matrix, &b, &jac, &opts, None);
        assert!(plain.converged && pre.converged);
        assert!(
            pre.iters <= plain.iters,
            "jacobi {} vs identity {}",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = minres(
            &sys.matrix,
            &vec![0.0; g * g],
            &Identity,
            &IterOpts::default(),
            None,
        );
        assert!(r.converged);
        assert!(crate::util::norm2(&r.x) == 0.0);
    }

    #[test]
    fn residual_history_is_monotone_nonincreasing() {
        let g = 12;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(3);
        let b = rng.normal_vec(g * g);
        let r = minres(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-10,
                max_iters: 2000,
                record_history: true,
            },
            None,
        );
        for w in r.history.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-12),
                "MINRES residual must be monotone: {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}
