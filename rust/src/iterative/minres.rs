//! MINRES (Paige & Saunders 1975): Krylov solver for symmetric —
//! possibly *indefinite* — systems.
//!
//! The paper's Appendix A notes "additional Krylov variants (e.g.
//! GMRES, LGMRES, MINRES, QMR, LSQR) are wrapped where the underlying
//! library provides them"; our substrate IS the underlying library, so
//! MINRES is implemented directly.  It fills the gap between CG
//! (requires SPD) and GMRES (no symmetry exploited, O(m n) memory for
//! the Arnoldi basis): symmetric Lanczos three-term recurrence, O(n)
//! memory, monotone residual.

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::krylov::{NullComm, SerialOp};
use crate::metrics::MemTracker;

/// Solve A x = b for symmetric (indefinite OK) A with preconditioned
/// MINRES, x0 = 0.  The preconditioner must be SPD.  Serial entry point
/// over the generic kernel in [`crate::krylov::minres`] — a transcription
/// of the historical serial loop whose reductions become identities
/// under [`NullComm`], preserving the serial FP schedule.
pub fn minres(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    assert_eq!(a.nrows(), a.ncols(), "minres needs a square operator");
    assert_eq!(a.nrows(), b.len());
    crate::krylov::minres(&SerialOp(a), b, m, &NullComm, opts, mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::{Identity, Jacobi};
    use crate::sparse::poisson::poisson2d;
    use crate::sparse::Coo;
    use crate::util::{rel_l2, Prng};

    #[test]
    fn solves_spd_poisson() {
        let g = 16;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let r = minres(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-10,
                max_iters: 5000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged, "residual {}", r.residual);
        assert!(rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn solves_symmetric_indefinite_where_cg_breaks() {
        // A = Poisson - sigma I with sigma inside the spectrum: symmetric
        // but indefinite.  CG's pAp > 0 assumption fails; MINRES converges.
        let g = 10;
        let n = g * g;
        let sys = poisson2d(g, None);
        let sigma = 30.0; // between eigenvalues of the 10x10 grid Laplacian
        let mut coo = Coo::with_capacity(n, n, sys.matrix.nnz());
        for r in 0..n {
            let (cols, vals) = sys.matrix.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, if *c == r { v - sigma } else { *v });
            }
        }
        let a = coo.to_csr();
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(n);

        let mr = minres(
            &a,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-9,
                max_iters: 20_000,
                record_history: false,
            },
            None,
        );
        assert!(mr.converged, "minres residual {}", mr.residual);
        assert!(rel_l2(&a.matvec(&mr.x), &b) < 1e-7);

        let cgr = crate::iterative::cg(
            &a,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-9,
                max_iters: 20_000,
                record_history: false,
            },
            None,
        );
        assert!(
            !cgr.converged,
            "CG should break down on an indefinite system"
        );
    }

    #[test]
    fn jacobi_preconditioning_reduces_iterations() {
        let g = 24;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let opts = IterOpts {
            tol: 1e-8,
            max_iters: 10_000,
            record_history: false,
        };
        let plain = minres(&sys.matrix, &b, &Identity, &opts, None);
        let jac = Jacobi::new(&sys.matrix).unwrap();
        let pre = minres(&sys.matrix, &b, &jac, &opts, None);
        assert!(plain.converged && pre.converged);
        assert!(
            pre.iters <= plain.iters,
            "jacobi {} vs identity {}",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let g = 8;
        let sys = poisson2d(g, None);
        let r = minres(
            &sys.matrix,
            &vec![0.0; g * g],
            &Identity,
            &IterOpts::default(),
            None,
        );
        assert!(r.converged);
        assert!(crate::util::norm2(&r.x) == 0.0);
    }

    #[test]
    fn residual_history_is_monotone_nonincreasing() {
        let g = 12;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(3);
        let b = rng.normal_vec(g * g);
        let r = minres(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-10,
                max_iters: 2000,
                record_history: true,
            },
            None,
        );
        for w in r.history.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-12),
                "MINRES residual must be monotone: {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}
