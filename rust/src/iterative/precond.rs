//! Preconditioners: Jacobi (the paper's default), SSOR, ILU(0), and
//! IC(0).
//!
//! The paper notes its pytorch-native backend "currently supports only
//! Jacobi preconditioning" (§5) — we ship Jacobi for parity plus SSOR,
//! ILU(0), and IC(0) as the ablation axis
//! (`cargo bench --bench ablations`); algebraic multigrid lives in
//! [`crate::iterative::amg`] (the paper's headline future-work item).

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// z = M^{-1} r.
pub trait Precond {
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// No preconditioning.
pub struct Identity;

impl Precond for Identity {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }
}

/// Diagonal (Jacobi) preconditioner.
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    pub fn new(a: &Csr) -> Result<Self> {
        let d = a.diag();
        if d.iter().any(|&x| x == 0.0) {
            return Err(Error::InvalidProblem("zero diagonal entry".into()));
        }
        Ok(Jacobi {
            inv_diag: d.iter().map(|x| 1.0 / x).collect(),
        })
    }

    pub fn from_diag(diag: &[f64]) -> Self {
        Jacobi {
            inv_diag: diag.iter().map(|x| 1.0 / x).collect(),
        }
    }
}

impl Precond for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }
}

/// Symmetric SOR: M = (D/w + L) (D/w)^{-1} (D/w + U) scaled; applied via
/// one forward and one backward Gauss–Seidel sweep on the matrix itself.
pub struct Ssor {
    a: Csr,
    omega: f64,
    diag: Vec<f64>,
}

impl Ssor {
    pub fn new(a: &Csr, omega: f64) -> Result<Self> {
        let diag = a.diag();
        if diag.iter().any(|&x| x == 0.0) {
            return Err(Error::InvalidProblem("zero diagonal entry".into()));
        }
        Ok(Ssor {
            a: a.clone(),
            omega,
            diag,
        })
    }
}

impl Precond for Ssor {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = self.a.nrows;
        let w = self.omega;
        // forward sweep: (D/w + L) y = r
        for i in 0..n {
            let (cols, vals) = self.a.row(i);
            let mut s = r[i];
            for (c, v) in cols.iter().zip(vals) {
                if *c < i {
                    s -= v * z[*c];
                }
            }
            z[i] = s * w / self.diag[i];
        }
        // scale: y <- (D/w) y
        for i in 0..n {
            z[i] *= self.diag[i] / w;
        }
        // backward sweep: (D/w + U) z = y
        for i in (0..n).rev() {
            let (cols, vals) = self.a.row(i);
            let mut s = z[i];
            for (c, v) in cols.iter().zip(vals) {
                if *c > i {
                    s -= v * z[*c];
                }
            }
            z[i] = s * w / self.diag[i];
        }
    }
}

/// ILU(0): incomplete LU restricted to the pattern of A.  L (unit lower)
/// and U share one CSR with A's structure.
pub struct Ilu0 {
    lu: Csr,
}

impl Ilu0 {
    pub fn new(a: &Csr) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("ilu0 needs square".into()));
        }
        let n = a.nrows;
        let mut lu = a.clone();
        // position of each (row, col) for fast a_kj lookup
        let diag_pos: Vec<usize> = (0..n)
            .map(|r| {
                let lo = lu.indptr[r];
                let hi = lu.indptr[r + 1];
                lu.indices[lo..hi]
                    .binary_search(&r)
                    .map(|off| lo + off)
                    .map_err(|_| Error::InvalidProblem(format!("ilu0: missing diagonal at row {r}")))
            })
            .collect::<Result<_>>()?;
        for i in 0..n {
            let (lo, hi) = (lu.indptr[i], lu.indptr[i + 1]);
            let mut k_idx = lo;
            while k_idx < hi {
                let k = lu.indices[k_idx];
                if k >= i {
                    break;
                }
                let pivot = lu.vals[diag_pos[k]];
                if pivot == 0.0 {
                    return Err(Error::Breakdown {
                        at: k,
                        reason: "ilu0 zero pivot".into(),
                    });
                }
                let lik = lu.vals[k_idx] / pivot;
                lu.vals[k_idx] = lik;
                // row_i[j] -= lik * row_k[j] for j > k, restricted to pattern
                let (klo, khi) = (lu.indptr[k], lu.indptr[k + 1]);
                let mut kj = diag_pos[k] + 1;
                let mut ij = k_idx + 1;
                let _ = klo;
                while kj < khi && ij < hi {
                    let ck = lu.indices[kj];
                    let ci = lu.indices[ij];
                    match ck.cmp(&ci) {
                        std::cmp::Ordering::Less => kj += 1,
                        std::cmp::Ordering::Greater => ij += 1,
                        std::cmp::Ordering::Equal => {
                            lu.vals[ij] -= lik * lu.vals[kj];
                            kj += 1;
                            ij += 1;
                        }
                    }
                }
                k_idx += 1;
            }
        }
        Ok(Ilu0 { lu })
    }
}

impl Precond for Ilu0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = self.lu.nrows;
        // forward: unit-lower solve
        for i in 0..n {
            let (cols, vals) = self.lu.row(i);
            let mut s = r[i];
            for (c, v) in cols.iter().zip(vals) {
                if *c >= i {
                    break;
                }
                s -= v * z[*c];
            }
            z[i] = s;
        }
        // backward: upper solve
        for i in (0..n).rev() {
            let (cols, vals) = self.lu.row(i);
            let mut s = z[i];
            let mut diag = 1.0;
            for (c, v) in cols.iter().zip(vals) {
                if *c > i {
                    s -= v * z[*c];
                } else if *c == i {
                    diag = *v;
                }
            }
            z[i] = s / diag;
        }
    }
}

/// IC(0): incomplete Cholesky restricted to the lower-triangular part of
/// A's pattern (the SPD sibling of ILU(0); paper §2 lists it among the
/// "pattern-based preconditioners" torch-sla's explicit representation
/// enables).  Stores L with L L^T ≈ A.
pub struct Ic0 {
    /// lower-triangular factor in CSR (diagonal stored last per row).
    l: Csr,
}

impl Ic0 {
    pub fn new(a: &Csr) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("ic0 needs square".into()));
        }
        let n = a.nrows;
        // extract the lower triangle (including diagonal) into CSR
        let mut indptr = vec![0usize; n + 1];
        for r in 0..n {
            let (cols, _) = a.row(r);
            indptr[r + 1] = indptr[r] + cols.iter().filter(|c| **c <= r).count();
        }
        let lnnz = indptr[n];
        let mut indices = vec![0usize; lnnz];
        let mut vals = vec![0.0; lnnz];
        for r in 0..n {
            let (cols, avals) = a.row(r);
            let mut k = indptr[r];
            for (c, v) in cols.iter().zip(avals) {
                if *c <= r {
                    indices[k] = *c;
                    vals[k] = *v;
                    k += 1;
                }
            }
        }
        let mut l = Csr {
            nrows: n,
            ncols: n,
            indptr,
            indices,
            vals,
        };
        // up-looking IC(0): for each row i, eliminate against prior rows
        // restricted to the pattern.
        for i in 0..n {
            let (lo, hi) = (l.indptr[i], l.indptr[i + 1]);
            if hi == lo || l.indices[hi - 1] != i {
                return Err(Error::InvalidProblem(format!(
                    "ic0: missing diagonal at row {i}"
                )));
            }
            for kk in lo..hi {
                let j = l.indices[kk];
                // L[i,j] = (A[i,j] - sum_{p<j, p on both patterns} L[i,p] L[j,p]) / L[j,j]
                let mut s = l.vals[kk];
                let (jlo, jhi) = (l.indptr[j], l.indptr[j + 1]);
                let mut pi = lo;
                let mut pj = jlo;
                while pi < kk && pj < jhi - 1 {
                    let ci = l.indices[pi];
                    let cj = l.indices[pj];
                    match ci.cmp(&cj) {
                        std::cmp::Ordering::Less => pi += 1,
                        std::cmp::Ordering::Greater => pj += 1,
                        std::cmp::Ordering::Equal => {
                            if ci < j {
                                s -= l.vals[pi] * l.vals[pj];
                            }
                            pi += 1;
                            pj += 1;
                        }
                    }
                }
                if j == i {
                    if s <= 0.0 {
                        return Err(Error::Breakdown {
                            at: i,
                            reason: format!("ic0: non-positive pivot {s:.3e}"),
                        });
                    }
                    l.vals[kk] = s.sqrt();
                } else {
                    let ljj = l.vals[l.indptr[j + 1] - 1];
                    l.vals[kk] = s / ljj;
                }
            }
        }
        Ok(Ic0 { l })
    }
}

impl Precond for Ic0 {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = self.l.nrows;
        // forward: L y = r
        for i in 0..n {
            let (cols, vals) = self.l.row(i);
            let mut s = r[i];
            let last = cols.len() - 1;
            for k in 0..last {
                s -= vals[k] * z[cols[k]];
            }
            z[i] = s / vals[last];
        }
        // backward: L^T z = y (column sweep over L rows in reverse)
        for i in (0..n).rev() {
            let (cols, vals) = self.l.row(i);
            let last = cols.len() - 1;
            let zi = z[i] / vals[last];
            z[i] = zi;
            for k in 0..last {
                z[cols[k]] -= vals[k] * zi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::{cg, IterOpts};
    use crate::sparse::poisson::poisson2d;
    use crate::util::Prng;

    fn cg_iters_with(p: &dyn Precond) -> usize {
        let g = 24;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let r = cg(
            &sys.matrix,
            &b,
            p,
            &IterOpts {
                tol: 1e-8,
                max_iters: 5000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged);
        r.iters
    }

    #[test]
    fn ilu0_beats_jacobi_beats_identity() {
        let g = 24;
        let sys = poisson2d(g, None);
        let ident = cg_iters_with(&Identity);
        let jac = cg_iters_with(&Jacobi::new(&sys.matrix).unwrap());
        let ssor = cg_iters_with(&Ssor::new(&sys.matrix, 1.5).unwrap());
        let ilu = cg_iters_with(&Ilu0::new(&sys.matrix).unwrap());
        assert!(jac <= ident, "jacobi {jac} vs identity {ident}");
        assert!(ssor < jac, "ssor {ssor} vs jacobi {jac}");
        assert!(ilu < jac, "ilu {ilu} vs jacobi {jac}");
    }

    #[test]
    fn jacobi_rejects_zero_diag() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        assert!(Jacobi::new(&coo.to_csr()).is_err());
    }

    #[test]
    fn ilu0_exact_for_triangular_pattern() {
        // on a lower-triangular matrix ILU(0) is exact LU
        use crate::sparse::Coo;
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 1, 1.0);
        coo.push(2, 2, 4.0);
        let a = coo.to_csr();
        let p = Ilu0::new(&a).unwrap();
        let b = vec![2.0, 5.0, 10.0];
        let mut z = vec![0.0; 3];
        p.apply(&b, &mut z);
        let ax = a.matvec(&z);
        for i in 0..3 {
            assert!((ax[i] - b[i]).abs() < 1e-12);
        }
    }
}
