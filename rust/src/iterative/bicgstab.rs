//! BiCGStab (van der Vorst 1992) for general (nonsymmetric) systems,
//! with right preconditioning — the serial entry point over the generic
//! kernel in [`crate::krylov::bicgstab`] (paired with [`NullComm`],
//! which reproduces the historical serial loop bit for bit — pinned
//! against a frozen reference body in `tests/krylov_equivalence.rs`).

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::krylov::{NullComm, SerialOp};
use crate::metrics::MemTracker;

/// Solve A x = b with preconditioned BiCGStab, x0 = 0.
pub fn bicgstab(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    assert_eq!(a.nrows(), a.ncols());
    assert_eq!(a.nrows(), b.len());
    crate::krylov::bicgstab(&SerialOp(a), b, m, &NullComm, opts, mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Ilu0, Jacobi};
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 100, 5);
        let b = rng.normal_vec(100);
        let m = Jacobi::new(&a).unwrap();
        let r = bicgstab(&a, &b, &m, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn solves_spd_too() {
        let g = 16;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = bicgstab(&sys.matrix, &b, &m, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn ilu0_accelerates() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 200, 6);
        let b = rng.normal_vec(200);
        let opts = IterOpts {
            tol: 1e-9,
            max_iters: 1000,
            record_history: false,
        };
        let plain = bicgstab(&a, &b, &Identity, &opts, None);
        let ilu = bicgstab(&a, &b, &Ilu0::new(&a).unwrap(), &opts, None);
        assert!(plain.converged && ilu.converged);
        assert!(ilu.iters <= plain.iters);
    }

    #[test]
    fn respects_budget() {
        let g = 24;
        let sys = poisson2d(g, None);
        let b = vec![1.0; g * g];
        let r = bicgstab(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 3,
                record_history: false,
            },
            None,
        );
        assert!(!r.converged);
        assert!(r.iters <= 3);
    }
}
