//! BiCGStab (van der Vorst 1992) for general (nonsymmetric) systems,
//! with right preconditioning.

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::metrics::MemTracker;
use crate::util::{axpy_inplace, dot};

/// Solve A x = b with preconditioned BiCGStab, x0 = 0.
pub fn bicgstab(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.nrows();
    assert_eq!(n, a.ncols());
    assert_eq!(n, b.len());

    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut r0 = mem.buf(n);
    let mut p = mem.buf(n);
    let mut v = mem.buf(n);
    let mut s = mem.buf(n);
    let mut t = mem.buf(n);
    let mut phat = mem.buf(n);
    let mut shat = mem.buf(n);

    r.data.copy_from_slice(b);
    r0.data.copy_from_slice(b);
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut rr = dot(&r, &r);
    let tol2 = opts.tol * opts.tol;

    let mut history = Vec::new();
    if opts.record_history {
        history.push(rr.sqrt());
    }

    let mut iters = 0;
    let mut breakdown = false;
    while iters < opts.max_iters && rr > tol2 {
        let rho_new = dot(&r0, &r);
        if rho_new == 0.0 {
            breakdown = true;
            break;
        }
        if iters == 0 {
            p.data.copy_from_slice(&r);
        } else {
            let beta = (rho_new / rho) * (alpha / omega);
            // p = r + beta * (p - omega * v)
            for i in 0..n {
                p.data[i] = r[i] + beta * (p[i] - omega * v[i]);
            }
        }
        rho = rho_new;
        m.apply(&p, &mut phat);
        a.apply(&phat, &mut v);
        let r0v = dot(&r0, &v);
        if r0v == 0.0 {
            breakdown = true;
            break;
        }
        alpha = rho / r0v;
        // s = r - alpha v
        for i in 0..n {
            s.data[i] = r[i] - alpha * v[i];
        }
        let ss = dot(&s, &s);
        if ss <= tol2 {
            axpy_inplace(alpha, &phat, &mut x);
            rr = ss;
            iters += 1;
            if opts.record_history {
                history.push(rr.sqrt());
            }
            break;
        }
        m.apply(&s, &mut shat);
        a.apply(&shat, &mut t);
        let tt = dot(&t, &t);
        if tt == 0.0 {
            breakdown = true;
            break;
        }
        omega = dot(&t, &s) / tt;
        // x += alpha * phat + omega * shat
        axpy_inplace(alpha, &phat, &mut x);
        axpy_inplace(omega, &shat, &mut x);
        // r = s - omega t
        for i in 0..n {
            r.data[i] = s[i] - omega * t[i];
        }
        rr = dot(&r, &r);
        iters += 1;
        if opts.record_history {
            history.push(rr.sqrt());
        }
        if omega == 0.0 {
            breakdown = true;
            break;
        }
    }

    IterResult {
        x: x.take(),
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        breakdown: breakdown && rr > tol2,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Ilu0, Jacobi};
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 100, 5);
        let b = rng.normal_vec(100);
        let m = Jacobi::new(&a).unwrap();
        let r = bicgstab(&a, &b, &m, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn solves_spd_too() {
        let g = 16;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = bicgstab(&sys.matrix, &b, &m, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn ilu0_accelerates() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 200, 6);
        let b = rng.normal_vec(200);
        let opts = IterOpts {
            tol: 1e-9,
            max_iters: 1000,
            record_history: false,
        };
        let plain = bicgstab(&a, &b, &Identity, &opts, None);
        let ilu = bicgstab(&a, &b, &Ilu0::new(&a).unwrap(), &opts, None);
        assert!(plain.converged && ilu.converged);
        assert!(ilu.iters <= plain.iters);
    }

    #[test]
    fn respects_budget() {
        let g = 24;
        let sys = poisson2d(g, None);
        let b = vec![1.0; g * g];
        let r = bicgstab(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 3,
                record_history: false,
            },
            None,
        );
        assert!(!r.converged);
        assert!(r.iters <= 3);
    }
}
