//! Restarted GMRES(m) with modified Gram–Schmidt Arnoldi and Givens
//! rotations — the general-purpose fallback for indefinite /
//! nonsymmetric systems where BiCGStab stalls.  Serial entry point over
//! the generic kernel in [`crate::krylov::gmres`] — the kernel body is
//! the transcribed historical serial loop, and under [`NullComm`] every
//! reduction is the identity, so the serial FP schedule is preserved
//! (the frozen-reference parity suite pins this for CG/BiCGStab; the
//! GMRES/MINRES/LOBPCG transcriptions are covered by their
//! behavior-pinning unit tests).

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::krylov::{NullComm, SerialOp};
use crate::metrics::MemTracker;

/// Solve A x = b with right-preconditioned restarted GMRES(m), x0 = 0.
pub fn gmres(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    restart: usize,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    assert_eq!(a.nrows(), a.ncols());
    assert_eq!(a.nrows(), b.len());
    crate::krylov::gmres(&SerialOp(a), b, m, restart, &NullComm, opts, mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 4);
        let b = rng.normal_vec(80);
        let r = gmres(&a, &b, &Identity, 30, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn restart_still_converges() {
        let mut rng = Prng::new(2);
        let a = random_nonsymmetric(&mut rng, 60, 4);
        let b = rng.normal_vec(60);
        let r = gmres(
            &a,
            &b,
            &Jacobi::new(&a).unwrap(),
            5, // aggressive restart
            &IterOpts {
                tol: 1e-8,
                max_iters: 5000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged, "residual {}", r.residual);
    }

    #[test]
    fn solves_spd_poisson() {
        let g = 12;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(3);
        let b = rng.normal_vec(g * g);
        let r = gmres(&sys.matrix, &b, &Identity, 50, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn identity_system_converges_in_one() {
        use crate::sparse::Csr;
        let a = Csr::identity(10);
        let b = vec![2.0; 10];
        let r = gmres(&a, &b, &Identity, 10, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(r.iters <= 2);
        assert!(util::max_abs_diff(&r.x, &b) < 1e-12);
    }
}
