//! Restarted GMRES(m) with modified Gram–Schmidt Arnoldi and Givens
//! rotations — the general-purpose fallback for indefinite /
//! nonsymmetric systems where BiCGStab stalls.

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::metrics::MemTracker;
use crate::util::{dot, norm2};

/// Solve A x = b with right-preconditioned restarted GMRES(m), x0 = 0.
pub fn gmres(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    restart: usize,
    opts: &IterOpts,
    mem: Option<&MemTracker>,
) -> IterResult {
    let n = a.nrows();
    assert_eq!(n, a.ncols());
    assert_eq!(n, b.len());
    let restart = restart.max(1).min(n);

    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut w = mem.buf(n);
    let mut z = mem.buf(n);
    // Krylov basis (restart+1 vectors)
    let _basis_guard = mem.hold(((restart + 1) * n * 8) as u64);
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(restart + 1);

    let mut history = Vec::new();
    let mut total_iters = 0usize;
    let mut beta;

    r.data.copy_from_slice(b);
    beta = norm2(&r);
    if opts.record_history {
        history.push(beta);
    }

    'outer: while beta > opts.tol && total_iters < opts.max_iters {
        basis.clear();
        let mut v0 = r.data.clone();
        for vi in v0.iter_mut() {
            *vi /= beta;
        }
        basis.push(v0);

        // Hessenberg (restart+1 x restart), Givens cos/sin, residual vec g
        let mut h = vec![vec![0f64; restart]; restart + 1];
        let mut cs = vec![0f64; restart];
        let mut sn = vec![0f64; restart];
        let mut g = vec![0f64; restart + 1];
        g[0] = beta;

        let mut k_used = 0;
        for k in 0..restart {
            if total_iters >= opts.max_iters {
                break;
            }
            // w = A M^{-1} v_k
            m.apply(&basis[k], &mut z);
            a.apply(&z, &mut w);
            // modified Gram–Schmidt
            for (i, vi) in basis.iter().enumerate() {
                h[i][k] = dot(&w, vi);
                for j in 0..n {
                    w.data[j] -= h[i][k] * vi[j];
                }
            }
            h[k + 1][k] = norm2(&w);
            if h[k + 1][k] > 1e-300 {
                let mut vk1 = w.data.clone();
                for vi in vk1.iter_mut() {
                    *vi /= h[k + 1][k];
                }
                basis.push(vk1);
            }
            // apply previous rotations to column k
            for i in 0..k {
                let t = cs[i] * h[i][k] + sn[i] * h[i + 1][k];
                h[i + 1][k] = -sn[i] * h[i][k] + cs[i] * h[i + 1][k];
                h[i][k] = t;
            }
            // new rotation
            let denom = (h[k][k] * h[k][k] + h[k + 1][k] * h[k + 1][k]).sqrt();
            if denom == 0.0 {
                k_used = k;
                break;
            }
            cs[k] = h[k][k] / denom;
            sn[k] = h[k + 1][k] / denom;
            h[k][k] = denom;
            h[k + 1][k] = 0.0;
            g[k + 1] = -sn[k] * g[k];
            g[k] *= cs[k];
            total_iters += 1;
            k_used = k + 1;
            let res = g[k + 1].abs();
            if opts.record_history {
                history.push(res);
            }
            if res <= opts.tol {
                break;
            }
            if basis.len() <= k + 1 {
                break; // lucky breakdown: exact solution in span
            }
        }
        // back-substitute y from H y = g
        let kk = k_used;
        let mut y = vec![0f64; kk];
        for i in (0..kk).rev() {
            let mut s = g[i];
            for j in i + 1..kk {
                s -= h[i][j] * y[j];
            }
            y[i] = s / h[i][i];
        }
        // x += M^{-1} (V y)
        let mut vy = vec![0f64; n];
        for (j, yj) in y.iter().enumerate() {
            for i in 0..n {
                vy[i] += yj * basis[j][i];
            }
        }
        m.apply(&vy, &mut z);
        for i in 0..n {
            x.data[i] += z[i];
        }
        // true residual for restart
        a.apply(&x, &mut w);
        for i in 0..n {
            r.data[i] = b[i] - w[i];
        }
        beta = norm2(&r);
        if beta <= opts.tol {
            break 'outer;
        }
    }

    IterResult {
        x: x.take(),
        iters: total_iters,
        residual: beta,
        converged: beta <= opts.tol,
        breakdown: false,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::graphs::random_nonsymmetric;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 4);
        let b = rng.normal_vec(80);
        let r = gmres(&a, &b, &Identity, 30, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&a.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn restart_still_converges() {
        let mut rng = Prng::new(2);
        let a = random_nonsymmetric(&mut rng, 60, 4);
        let b = rng.normal_vec(60);
        let r = gmres(
            &a,
            &b,
            &Jacobi::new(&a).unwrap(),
            5, // aggressive restart
            &IterOpts {
                tol: 1e-8,
                max_iters: 5000,
                record_history: false,
            },
            None,
        );
        assert!(r.converged, "residual {}", r.residual);
    }

    #[test]
    fn solves_spd_poisson() {
        let g = 12;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(3);
        let b = rng.normal_vec(g * g);
        let r = gmres(&sys.matrix, &b, &Identity, 50, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-8);
    }

    #[test]
    fn identity_system_converges_in_one() {
        use crate::sparse::Csr;
        let a = Csr::identity(10);
        let b = vec![2.0; 10];
        let r = gmres(&a, &b, &Identity, 10, &IterOpts::default(), None);
        assert!(r.converged);
        assert!(r.iters <= 2);
        assert!(util::max_abs_diff(&r.x, &b) < 1e-12);
    }
}
