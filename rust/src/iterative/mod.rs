//! Iterative Krylov solvers — the Eigen/pytorch-native backend substrate.
//!
//! Everything is written against the [`LinOp`] trait so the same CG runs
//! on CSR matrices, matrix-free stencil operators, and Jacobians applied
//! via autograd JVPs (nonlinear adjoints).  The recurrences themselves
//! live in [`crate::krylov`], written once over `LinearOperator x
//! Communicator`; the entry points here are the serial instantiations
//! (`NullComm`), and the distributed layer instantiates the SAME kernels
//! over halo-exchanged operators and rank teams (see
//! `docs/solver_architecture.md`).

pub mod amg;
pub mod bicgstab;
pub mod cg;
pub mod gmres;
pub mod minres;
pub mod precond;

pub use amg::{Amg, AmgOpts};
pub use bicgstab::bicgstab;
pub use cg::cg;
pub use gmres::gmres;
pub use minres::minres;
pub use precond::{Ic0, Identity, Ilu0, Jacobi, Precond, Ssor};

use crate::sparse::poisson::StencilCoeffs;
use crate::sparse::Csr;

/// A linear operator y = A x (and optionally y = A^T x).
pub trait LinOp {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// Transpose apply; default panics for operators without one.
    fn apply_t(&self, _x: &[f64], _y: &mut [f64]) {
        panic!("apply_t not implemented for this operator"); // rsla-lint: allow(L1, documented contract: operators without a transpose must not be applied transposed)
    }
}

impl LinOp for Csr {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_t(x, y);
    }
}

impl LinOp for StencilCoeffs {
    fn nrows(&self) -> usize {
        self.n()
    }
    fn ncols(&self) -> usize {
        self.n()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y);
    }
}

/// Options shared by all Krylov loops.
#[derive(Clone, Debug)]
pub struct IterOpts {
    /// Absolute residual tolerance on ||b - A x||_2.
    pub tol: f64,
    pub max_iters: usize,
    /// Record ||r|| per iteration (benches/plots).
    pub record_history: bool,
}

impl Default for IterOpts {
    fn default() -> Self {
        IterOpts {
            tol: 1e-10,
            max_iters: 10_000,
            record_history: false,
        }
    }
}

/// Outcome of an iterative solve.  `converged == false` is not an error
/// at this layer: Table 4 runs a fixed iteration budget on purpose.
///
/// `breakdown` distinguishes "the recurrence broke down" (CG's
/// `p^T A p <= 0` on a non-SPD operator, BiCGStab's rho/omega
/// degeneracies, a non-SPD MINRES preconditioner) from "ran out of
/// iteration budget" — callers and the dispatcher's runtime-fallback
/// path react differently to the two.
#[derive(Clone, Debug)]
pub struct IterResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual: f64,
    pub converged: bool,
    /// True when the iteration stopped on a breakdown condition rather
    /// than the iteration budget.  Always false when `converged`.
    pub breakdown: bool,
    pub history: Vec<f64>,
}

impl IterResult {
    /// Convert to a hard error when convergence was required.
    pub fn require_converged(self, tol: f64) -> crate::error::Result<Self> {
        if self.converged {
            Ok(self)
        } else {
            Err(crate::error::Error::NotConverged {
                iters: self.iters,
                residual: self.residual,
                tol,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn stencil_and_csr_linop_agree() {
        let g = 10;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(g * g);
        let mut y1 = vec![0.0; g * g];
        let mut y2 = vec![0.0; g * g];
        LinOp::apply(&sys.matrix, &x, &mut y1);
        LinOp::apply(&sys.coeffs, &x, &mut y2);
        assert!(util::max_abs_diff(&y1, &y2) < 1e-11);
    }

    #[test]
    fn require_converged_errors() {
        let r = IterResult {
            x: vec![],
            iters: 5,
            residual: 1.0,
            converged: false,
            breakdown: false,
            history: vec![],
        };
        assert!(r.require_converged(1e-10).is_err());
    }
}
