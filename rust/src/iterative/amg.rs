//! Smoothed-aggregation algebraic multigrid (Vaněk, Mandel & Brezina
//! 1996) as a preconditioner — the paper's headline future-work item
//! (§5: "Reaching a meaningful tolerance at this scale needs a stronger
//! preconditioner (e.g. algebraic multigrid via AmgX/hypre), which we
//! leave to future work").
//!
//! This is the full pattern-based construction that torch-sla's
//! *explicit* sparse representation enables (paper Appendix E: "ILU/IC/
//! AMG need the explicit non-zeros"):
//!
//! 1. strength-of-connection graph `|a_ij| > theta sqrt(a_ii a_jj)`;
//! 2. greedy aggregation of strongly-connected nodes;
//! 3. tentative piecewise-constant prolongator P0, smoothed by one
//!    damped-Jacobi step `P = (I - omega D^-1 A) P0`;
//! 4. Galerkin coarse operator `A_c = P^T A P`;
//! 5. recursion until the coarse problem is small enough for a direct
//!    solve.
//!
//! `apply` runs one V(1,1)-cycle with damped-Jacobi smoothing — an SPD
//! operation, so it is admissible inside CG.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::factor_cache::FactorCache;
use crate::iterative::Precond;
use crate::sparse::{Coo, Csr};
use crate::util::lock_recover;

/// AMG construction options.
#[derive(Clone, Debug)]
pub struct AmgOpts {
    /// Strength-of-connection threshold theta.
    pub theta: f64,
    /// Prolongator smoothing weight (typically 2/3 for Poisson-like).
    pub omega: f64,
    /// Jacobi smoothing weight inside the V-cycle.
    pub smooth_omega: f64,
    /// Pre-/post-smoothing sweeps.
    pub sweeps: usize,
    /// Stop coarsening below this size and solve directly.
    pub coarse_n: usize,
    /// Hard cap on hierarchy depth.
    pub max_levels: usize,
}

impl Default for AmgOpts {
    fn default() -> Self {
        AmgOpts {
            theta: 0.08,
            omega: 2.0 / 3.0,
            smooth_omega: 2.0 / 3.0,
            sweeps: 1,
            coarse_n: 64,
            max_levels: 12,
        }
    }
}

struct Level {
    a: Csr,
    /// prolongator: n_fine x n_coarse (absent on the coarsest level).
    p: Option<Csr>,
    /// restriction = P^T, stored explicitly for fast SpMV.
    r: Option<Csr>,
    inv_diag: Vec<f64>,
}

/// The assembled hierarchy.
pub struct Amg {
    levels: Vec<Level>,
    /// Coarse-grid direct factorization, served through the pattern-
    /// keyed cache: rebuilding an AMG hierarchy over an unchanged (or
    /// same-pattern) coarse operator — the Newton-loop case — reuses
    /// the numeric factor or at least its symbolic analysis.
    coarse: Arc<crate::direct::CachedFactor>,
    /// Scratch for the coarse `solve_into` sweeps, reused across
    /// V-cycles so the coarse correction allocates nothing per
    /// application (pinned by the factor-solve allocation tally).
    coarse_scratch: std::sync::Mutex<Vec<f64>>,
    opts: AmgOpts,
}

/// Greedy aggregation over the strength graph.  Returns (aggregate id
/// per node, number of aggregates).
fn aggregate(a: &Csr, theta: f64) -> (Vec<usize>, usize) {
    let n = a.nrows;
    let diag = a.diag();
    let strong = |r: usize, c: usize, v: f64| -> bool {
        r != c && v.abs() > theta * (diag[r].abs() * diag[c].abs()).sqrt()
    };

    const UNASSIGNED: usize = usize::MAX;
    let mut agg = vec![UNASSIGNED; n];
    let mut n_agg = 0;

    // pass 1: roots — nodes whose strong neighborhood is fully unassigned
    for i in 0..n {
        if agg[i] != UNASSIGNED {
            continue;
        }
        let (cols, vals) = a.row(i);
        let mut free = true;
        for (c, v) in cols.iter().zip(vals) {
            if strong(i, *c, *v) && agg[*c] != UNASSIGNED {
                free = false;
                break;
            }
        }
        if free {
            agg[i] = n_agg;
            for (c, v) in cols.iter().zip(vals) {
                if strong(i, *c, *v) {
                    agg[*c] = n_agg;
                }
            }
            n_agg += 1;
        }
    }
    // pass 2: attach stragglers to the strongest neighboring aggregate
    for i in 0..n {
        if agg[i] != UNASSIGNED {
            continue;
        }
        let (cols, vals) = a.row(i);
        let mut best = (0.0_f64, UNASSIGNED);
        for (c, v) in cols.iter().zip(vals) {
            if *c != i && agg[*c] != UNASSIGNED && v.abs() > best.0 {
                best = (v.abs(), agg[*c]);
            }
        }
        if best.1 != UNASSIGNED {
            agg[i] = best.1;
        } else {
            // isolated node: its own aggregate
            agg[i] = n_agg;
            n_agg += 1;
        }
    }
    (agg, n_agg)
}

/// Tentative prolongator (piecewise constant over aggregates, columns
/// normalized) smoothed by one damped-Jacobi step.
fn smoothed_prolongator(a: &Csr, agg: &[usize], n_agg: usize, omega: f64) -> Result<Csr> {
    let n = a.nrows;
    // column norms of the tentative prolongator
    let mut count = vec![0usize; n_agg];
    for &g in agg {
        count[g] += 1;
    }
    // P0[i, agg[i]] = 1/sqrt(|agg|)
    let inv_diag: Vec<f64> = a
        .diag()
        .iter()
        .map(|d| if *d != 0.0 { 1.0 / d } else { 0.0 })
        .collect();
    // P = (I - omega D^-1 A) P0: row i of P touches agg[j] for every
    // entry a_ij, plus agg[i].
    let mut coo = Coo::with_capacity(n, n_agg, a.nnz());
    for i in 0..n {
        let (cols, vals) = a.row(i);
        // accumulate per-aggregate contributions of this row
        let mut touched: Vec<(usize, f64)> = Vec::with_capacity(cols.len());
        let push = |g: usize, v: f64, touched: &mut Vec<(usize, f64)>| {
            for t in touched.iter_mut() {
                if t.0 == g {
                    t.1 += v;
                    return;
                }
            }
            touched.push((g, v));
        };
        push(
            agg[i],
            1.0 / (count[agg[i]] as f64).sqrt(),
            &mut touched,
        );
        for (c, v) in cols.iter().zip(vals) {
            let w = -omega * inv_diag[i] * v / (count[agg[*c]] as f64).sqrt();
            push(agg[*c], w, &mut touched);
        }
        for (g, v) in touched {
            if v != 0.0 {
                coo.push(i, g, v);
            }
        }
    }
    if coo.nnz() == 0 {
        return Err(Error::InvalidProblem("amg: empty prolongator".into()));
    }
    Ok(coo.to_csr())
}

impl Amg {
    pub fn new(a: &Csr, opts: &AmgOpts) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("amg needs square".into()));
        }
        let mut levels = Vec::new();
        let mut cur = a.clone();
        for _ in 0..opts.max_levels {
            if cur.nrows <= opts.coarse_n {
                break;
            }
            let (agg, n_agg) = aggregate(&cur, opts.theta);
            if n_agg >= cur.nrows {
                break; // coarsening stalled
            }
            let p = smoothed_prolongator(&cur, &agg, n_agg, opts.omega)?;
            let r = p.transpose();
            let ap = cur.spmm(&p)?;
            let a_c = r.spmm(&ap)?;
            let inv_diag: Vec<f64> = cur
                .diag()
                .iter()
                .map(|d| if *d != 0.0 { 1.0 / d } else { 0.0 })
                .collect();
            levels.push(Level {
                a: cur,
                p: Some(p),
                r: Some(r),
                inv_diag,
            });
            cur = a_c;
        }
        let coarse = FactorCache::global().factor(&cur, u64::MAX, None)?;
        let inv_diag: Vec<f64> = cur
            .diag()
            .iter()
            .map(|d| if *d != 0.0 { 1.0 / d } else { 0.0 })
            .collect();
        levels.push(Level {
            a: cur,
            p: None,
            r: None,
            inv_diag,
        });
        Ok(Amg {
            levels,
            coarse,
            coarse_scratch: std::sync::Mutex::new(Vec::new()),
            opts: opts.clone(),
        })
    }

    /// Hierarchy depth including the coarse level.
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Grid complexity: sum of level sizes / fine size.
    pub fn grid_complexity(&self) -> f64 {
        let fine = self.levels[0].a.nrows as f64;
        self.levels.iter().map(|l| l.a.nrows as f64).sum::<f64>() / fine
    }

    /// Operator complexity: sum of level nnz / fine nnz.
    pub fn operator_complexity(&self) -> f64 {
        let fine = self.levels[0].a.nnz() as f64;
        self.levels.iter().map(|l| l.a.nnz() as f64).sum::<f64>() / fine
    }

    fn smooth(&self, lev: &Level, x: &mut [f64], b: &[f64], tmp: &mut [f64]) {
        for _ in 0..self.opts.sweeps {
            lev.a.spmv(x, tmp);
            for i in 0..x.len() {
                x[i] += self.opts.smooth_omega * lev.inv_diag[i] * (b[i] - tmp[i]);
            }
        }
    }

    fn vcycle(&self, depth: usize, b: &[f64], x: &mut [f64]) {
        let lev = &self.levels[depth];
        let n = lev.a.nrows;
        if depth + 1 == self.levels.len() {
            let mut scratch = lock_recover(&self.coarse_scratch);
            if self.coarse.solve_into(b, x, &mut scratch).is_err() {
                // a singular coarse factor degrades to an identity
                // coarse correction instead of aborting the solve
                x.copy_from_slice(b);
            }
            return;
        }
        let mut tmp = vec![0.0; n];
        // pre-smooth from zero initial guess
        self.smooth(lev, x, b, &mut tmp);
        // residual
        lev.a.spmv(x, &mut tmp);
        let mut res = vec![0.0; n];
        for i in 0..n {
            res[i] = b[i] - tmp[i];
        }
        // restrict
        let Some(r) = lev.r.as_ref() else {
            // non-coarse levels always carry restriction/prolongation;
            // degrade to the smoothed iterate if one is missing
            return;
        };
        let nc = r.nrows;
        let mut bc = vec![0.0; nc];
        r.spmv(&res, &mut bc);
        // coarse correction
        let mut xc = vec![0.0; nc];
        self.vcycle(depth + 1, &bc, &mut xc);
        // prolong + correct
        let Some(p) = lev.p.as_ref() else {
            return;
        };
        p.spmv(&xc, &mut tmp);
        for i in 0..n {
            x[i] += tmp[i];
        }
        // post-smooth
        let mut t2 = vec![0.0; n];
        self.smooth(lev, x, b, &mut t2);
    }
}

impl Precond for Amg {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for zi in z.iter_mut() {
            *zi = 0.0;
        }
        self.vcycle(0, r, z);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::{cg, IterOpts, Jacobi};
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{rel_l2, Prng};

    #[test]
    fn hierarchy_coarsens_geometrically() {
        let g = 48;
        let sys = poisson2d(g, None);
        let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
        assert!(amg.n_levels() >= 3, "expected >= 3 levels, got {}", amg.n_levels());
        assert!(
            amg.grid_complexity() < 1.6,
            "grid complexity {} too high",
            amg.grid_complexity()
        );
        assert!(
            amg.operator_complexity() < 3.0,
            "operator complexity {} too high",
            amg.operator_complexity()
        );
    }

    #[test]
    fn amg_cg_converges_in_near_constant_iterations() {
        // The multigrid signature: iterations roughly flat in n, while
        // Jacobi-CG grows like sqrt(kappa) ~ g.
        let opts = IterOpts {
            tol: 1e-8,
            max_iters: 2000,
            record_history: false,
        };
        let mut amg_iters = Vec::new();
        let mut jac_iters = Vec::new();
        for g in [16usize, 32, 64] {
            let sys = poisson2d(g, Some(&kappa_star(g)));
            let mut rng = Prng::new(g as u64);
            let b = rng.normal_vec(g * g);
            let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
            let r1 = cg(&sys.matrix, &b, &amg, &opts, None);
            assert!(r1.converged);
            assert!(rel_l2(&sys.matrix.matvec(&r1.x), &b) < 1e-6);
            amg_iters.push(r1.iters);
            let jac = Jacobi::new(&sys.matrix).unwrap();
            let r2 = cg(&sys.matrix, &b, &jac, &opts, None);
            jac_iters.push(r2.iters);
        }
        // AMG: near-flat growth; Jacobi: ~2x per grid doubling
        assert!(
            amg_iters[2] <= amg_iters[0] * 3,
            "AMG iters must be near-constant: {amg_iters:?}"
        );
        assert!(
            amg_iters[2] * 4 < jac_iters[2],
            "AMG ({:?}) must beat Jacobi ({:?}) at g=64",
            amg_iters,
            jac_iters
        );
    }

    #[test]
    fn vcycle_is_spd_like() {
        // <x, M^{-1} y> == <M^{-1} x, y> within roundoff — required for CG.
        let g = 16;
        let n = g * g;
        let sys = poisson2d(g, None);
        let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let mut mx = vec![0.0; n];
        let mut my = vec![0.0; n];
        amg.apply(&x, &mut mx);
        amg.apply(&y, &mut my);
        let lhs = crate::util::dot(&x, &my);
        let rhs = crate::util::dot(&mx, &y);
        assert!(
            (lhs - rhs).abs() < 1e-8 * lhs.abs().max(rhs.abs()).max(1.0),
            "V-cycle not symmetric: {lhs} vs {rhs}"
        );
    }

    #[test]
    fn small_matrix_degenerates_to_direct() {
        let g = 6; // 36 <= coarse_n
        let sys = poisson2d(g, None);
        let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
        assert_eq!(amg.n_levels(), 1);
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let mut z = vec![0.0; g * g];
        amg.apply(&b, &mut z);
        // single level == exact solve
        assert!(rel_l2(&sys.matrix.matvec(&z), &b) < 1e-10);
    }

    #[test]
    fn rejects_nonsquare() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        assert!(Amg::new(&coo.to_csr(), &AmgOpts::default()).is_err());
    }
}
