//! Preconditioned conjugate gradient (Hestenes & Stiefel 1952).
//!
//! The native analogue of the fused ``cg_poisson_*`` XLA artifact; also
//! the building block the distributed layer re-implements with halo
//! exchange + all_reduce (Appendix C, Algorithm 1).  The loop is
//! allocation-free after setup; working vectors are accounted against an
//! optional [`MemTracker`].

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::metrics::MemTracker;
use crate::util::{axpy_inplace, dot, xpby_inplace};

/// Solve A x = b with preconditioned CG, x0 = 0.
pub fn cg(a: &dyn LinOp, b: &[f64], m: &dyn Precond, opts: &IterOpts, mem: Option<&MemTracker>) -> IterResult {
    let n = a.nrows();
    assert_eq!(n, a.ncols(), "cg needs a square operator");
    assert_eq!(n, b.len());

    let default_tracker = MemTracker::new();
    let mem = mem.unwrap_or(&default_tracker);
    let mut x = mem.buf(n);
    let mut r = mem.buf(n);
    let mut z = mem.buf(n);
    let mut p = mem.buf(n);
    let mut ap = mem.buf(n);

    r.data.copy_from_slice(b); // r = b - A*0
    m.apply(&r, &mut z);
    p.data.copy_from_slice(&z);
    let mut rz = dot(&r, &z);
    let mut rr = dot(&r, &r);
    let tol2 = opts.tol * opts.tol;

    let mut history = Vec::new();
    if opts.record_history {
        history.push(rr.sqrt());
    }

    let mut iters = 0;
    let mut breakdown = false;
    while iters < opts.max_iters && rr > tol2 {
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // operator not SPD (or breakdown): stop with current
            // iterate, and SAY SO — callers must be able to tell this
            // apart from an exhausted iteration budget
            breakdown = true;
            break;
        }
        let alpha = rz / pap;
        axpy_inplace(alpha, &p, &mut x);
        axpy_inplace(-alpha, &ap, &mut r);
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        xpby_inplace(&z, beta, &mut p);
        rz = rz_new;
        rr = dot(&r, &r);
        iters += 1;
        if opts.record_history {
            history.push(rr.sqrt());
        }
    }

    IterResult {
        x: x.take(),
        iters,
        residual: rr.sqrt(),
        converged: rr <= tol2,
        breakdown: breakdown && rr > tol2,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn solves_poisson() {
        let g = 20;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = cg(&sys.matrix, &b, &m, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-9);
    }

    #[test]
    fn fixed_budget_reports_unconverged() {
        let g = 32;
        let sys = poisson2d(g, None);
        let b = vec![1.0; g * g];
        let r = cg(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 5,
                record_history: true,
            },
            None,
        );
        assert!(!r.converged);
        assert_eq!(r.iters, 5);
        assert_eq!(r.history.len(), 6);
        // CG minimizes the A-norm; the 2-norm residual may transiently
        // rise, so only require a well-formed, finite history here.
        assert!(r.history.iter().all(|h| h.is_finite()));
        assert!(r.residual > 0.0);
    }

    #[test]
    fn memory_is_five_vectors() {
        let g = 16;
        let n = g * g;
        let sys = poisson2d(g, None);
        let b = vec![1.0; n];
        let mem = crate::metrics::MemTracker::new();
        let _ = cg(&sys.matrix, &b, &Identity, &IterOpts::default(), Some(&mem));
        assert_eq!(mem.peak(), (5 * n * 8) as u64);
        assert_eq!(mem.current(), 0);
    }

    #[test]
    fn matches_direct_solver() {
        let mut rng = Prng::new(1);
        let a = random_spd(&mut rng, 50, 3, 2.0);
        let b = rng.normal_vec(50);
        let m = Jacobi::new(&a).unwrap();
        let r = cg(
            &a,
            &b,
            &m,
            &IterOpts {
                tol: 1e-12,
                max_iters: 10_000,
                record_history: false,
            },
            None,
        );
        let xd = crate::direct::direct_solve(&a, &b).unwrap();
        assert!(util::max_abs_diff(&r.x, &xd) < 1e-8);
    }

    #[test]
    fn indefinite_operator_reports_breakdown_not_budget() {
        use crate::sparse::Coo;
        // symmetric, positive diagonal (passes the SPD screen) but
        // indefinite: p^T A p goes negative on the first iteration
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let r = cg(&a, &[1.0, -1.0], &Identity, &IterOpts::default(), None);
        assert!(!r.converged);
        assert!(r.breakdown, "pAp <= 0 must be reported as breakdown");
        assert!(r.x.iter().all(|v| v.is_finite()));
        // budget exhaustion, by contrast, is NOT a breakdown
        let sys = crate::sparse::poisson::poisson2d(16, None);
        let r = cg(
            &sys.matrix,
            &vec![1.0; 256],
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 3,
                record_history: false,
            },
            None,
        );
        assert!(!r.converged && !r.breakdown);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let g = 8;
        let sys = poisson2d(g, None);
        let b = vec![0.0; g * g];
        let r = cg(&sys.matrix, &b, &Identity, &IterOpts::default(), None);
        assert!(r.converged);
        assert_eq!(r.iters, 0);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }
}
