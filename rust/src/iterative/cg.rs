//! Preconditioned conjugate gradient (Hestenes & Stiefel 1952) — the
//! serial entry point.
//!
//! The native analogue of the fused ``cg_poisson_*`` XLA artifact.  The
//! recurrence itself lives in [`crate::krylov::cg`], written once over
//! `LinearOperator x Communicator`; this wrapper pairs the caller's
//! [`LinOp`] with the zero-cost [`NullComm`], which reproduces the
//! historical serial loop's floating-point schedule exactly (pinned by
//! `tests/krylov_equivalence.rs`).  The loop is allocation-free after
//! setup; working vectors are accounted against an optional
//! [`MemTracker`].

use super::{IterOpts, IterResult, LinOp, Precond};
use crate::krylov::{NullComm, SerialOp};
use crate::metrics::MemTracker;

/// Solve A x = b with preconditioned CG, x0 = 0.
pub fn cg(a: &dyn LinOp, b: &[f64], m: &dyn Precond, opts: &IterOpts, mem: Option<&MemTracker>) -> IterResult {
    assert_eq!(a.nrows(), a.ncols(), "cg needs a square operator");
    assert_eq!(a.nrows(), b.len());
    crate::krylov::cg(&SerialOp(a), b, m, &NullComm, opts, mem)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::precond::{Identity, Jacobi};
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn solves_poisson() {
        let g = 20;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let r = cg(&sys.matrix, &b, &m, &IterOpts::default(), None);
        assert!(r.converged, "residual {}", r.residual);
        assert!(util::rel_l2(&sys.matrix.matvec(&r.x), &b) < 1e-9);
    }

    #[test]
    fn fixed_budget_reports_unconverged() {
        let g = 32;
        let sys = poisson2d(g, None);
        let b = vec![1.0; g * g];
        let r = cg(
            &sys.matrix,
            &b,
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 5,
                record_history: true,
            },
            None,
        );
        assert!(!r.converged);
        assert_eq!(r.iters, 5);
        assert_eq!(r.history.len(), 6);
        // CG minimizes the A-norm; the 2-norm residual may transiently
        // rise, so only require a well-formed, finite history here.
        assert!(r.history.iter().all(|h| h.is_finite()));
        assert!(r.residual > 0.0);
    }

    #[test]
    fn memory_is_five_vectors() {
        let g = 16;
        let n = g * g;
        let sys = poisson2d(g, None);
        let b = vec![1.0; n];
        let mem = crate::metrics::MemTracker::new();
        let _ = cg(&sys.matrix, &b, &Identity, &IterOpts::default(), Some(&mem));
        assert_eq!(mem.peak(), (5 * n * 8) as u64);
        assert_eq!(mem.current(), 0);
    }

    #[test]
    fn matches_direct_solver() {
        let mut rng = Prng::new(1);
        let a = random_spd(&mut rng, 50, 3, 2.0);
        let b = rng.normal_vec(50);
        let m = Jacobi::new(&a).unwrap();
        let r = cg(
            &a,
            &b,
            &m,
            &IterOpts {
                tol: 1e-12,
                max_iters: 10_000,
                record_history: false,
            },
            None,
        );
        let xd = crate::direct::direct_solve(&a, &b).unwrap();
        assert!(util::max_abs_diff(&r.x, &xd) < 1e-8);
    }

    #[test]
    fn indefinite_operator_reports_breakdown_not_budget() {
        use crate::sparse::Coo;
        // symmetric, positive diagonal (passes the SPD screen) but
        // indefinite: p^T A p goes negative on the first iteration
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let r = cg(&a, &[1.0, -1.0], &Identity, &IterOpts::default(), None);
        assert!(!r.converged);
        assert!(r.breakdown, "pAp <= 0 must be reported as breakdown");
        assert!(r.x.iter().all(|v| v.is_finite()));
        // budget exhaustion, by contrast, is NOT a breakdown
        let sys = crate::sparse::poisson::poisson2d(16, None);
        let r = cg(
            &sys.matrix,
            &vec![1.0; 256],
            &Identity,
            &IterOpts {
                tol: 1e-14,
                max_iters: 3,
                record_history: false,
            },
            None,
        );
        assert!(!r.converged && !r.breakdown);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let g = 8;
        let sys = poisson2d(g, None);
        let b = vec![0.0; g * g];
        let r = cg(&sys.matrix, &b, &Identity, &IterOpts::default(), None);
        assert!(r.converged);
        assert_eq!(r.iters, 0);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }
}
