//! Sparse direct solvers — the SciPy-SuperLU/UMFPACK analog (paper §3.1).
//!
//! * [`cholesky::EnvelopeCholesky`] — envelope (profile/skyline) Cholesky
//!   for SPD systems; with [`ordering::rcm`] reordering the profile of a
//!   2D 5-point grid is O(n^1.5), the same fill asymptotics the paper
//!   cites for direct solvers (George 1973), so the direct-solver memory
//!   wall in Table 3 emerges from *measured* factor size.
//! * [`lu::SparseLu`] — Gilbert–Peierls left-looking sparse LU with
//!   partial pivoting (the non-supernodal SuperLU algorithm) for general
//!   square systems.
//! * [`supernodal::SnCholesky`] — elimination-tree supernode detection
//!   with relaxed amalgamation, feeding a blocked numeric phase that
//!   factors dense column panels with rank-k descendant updates; LU gets
//!   the same treatment through [`lu::LuPanels`] /
//!   [`lu::SparseLu::refactor_blocked`].  The cached symbolic tier
//!   ([`cache`]) engages these automatically when panels are wide enough
//!   to pay off and falls back to the scalar kernels otherwise.
//!
//! Both factorizations separate symbolic-ish setup from numeric refactor
//! where possible and report their fill so backends can enforce the
//! device-memory budget *before* factorizing.

pub mod cache;
pub mod cholesky;
pub mod lu;
pub mod ordering;
pub mod supernodal;
pub mod triangular;

pub use cache::{build_factor, refactor, CachedFactor, Symbolic};
pub use cholesky::{CholSymbolic, EnvelopeCholesky};
pub use lu::{LuPanels, LuSymbolic, SparseLu};
pub use supernodal::{SnCholSymbolic, SnCholesky, SupernodalOpts, SN_MAX_WIDTH};

use crate::error::Result;
use crate::sparse::Csr;

/// Factorize-and-solve convenience: Cholesky when the matrix looks SPD
/// (with LU fallback on breakdown), LU otherwise.  RCM is applied for the
/// Cholesky path.
pub fn direct_solve(a: &Csr, b: &[f64]) -> Result<Vec<f64>> {
    if a.looks_spd() {
        match EnvelopeCholesky::factor_rcm(a) {
            Ok(f) => return Ok(f.solve(b)),
            Err(_) => { /* fall through to LU */ }
        }
    }
    let f = SparseLu::factor(a)?;
    f.solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_nonsymmetric, random_spd};
    use crate::util::{self, Prng};

    #[test]
    fn direct_solve_routes_spd_and_general() {
        let mut rng = Prng::new(11);
        let spd = random_spd(&mut rng, 40, 3, 1.0);
        let b = rng.normal_vec(40);
        let x = direct_solve(&spd, &b).unwrap();
        assert!(util::rel_l2(&spd.matvec(&x), &b) < 1e-10);

        let gen = random_nonsymmetric(&mut rng, 40, 4);
        let x = direct_solve(&gen, &b).unwrap();
        assert!(util::rel_l2(&gen.matvec(&x), &b) < 1e-10);
    }
}
